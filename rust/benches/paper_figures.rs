//! `cargo bench --bench paper_figures` — regenerate every table and
//! figure of the thesis' evaluation at a reduced (steady-state) scale.
//! Pass full paper scale via `FDB_FIG_SCALE=1.0` (slow).

fn main() {
    let scale: f64 = std::env::var("FDB_FIG_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05);
    let only = std::env::var("FDB_FIG_ONLY").ok();
    println!("== paper figures (scale {scale}) ==\n");
    let mut ids = fdbr::bench::figures::all_ids();
    ids.extend(fdbr::bench::ablations::ablation_ids());
    for id in ids {
        if let Some(ref f) = only {
            if f != id {
                continue;
            }
        }
        let t0 = std::time::Instant::now();
        let fig = fdbr::bench::figures::run_figure(id, scale)
            .or_else(|| fdbr::bench::ablations::run_ablation(id, scale))
            .expect("known id");
        print!("{}", fig.render());
        println!("   [{:.1}s wall]\n", t0.elapsed().as_secs_f64());
    }
}
