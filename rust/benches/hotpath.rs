//! Wall-clock microbenchmarks of the L3 hot paths (custom harness — no
//! criterion offline). Reports ns/op mean over timed batches after
//! warmup; results feed EXPERIMENTS.md §Perf.

use std::time::Instant;

use fdbr::fdb::datahandle::DataHandle;
use fdbr::fdb::key::Key;
use fdbr::fdb::location::FieldLocation;
use fdbr::fdb::posix::index::{self, IndexEntry};
use fdbr::sim::exec::Sim;
use fdbr::sim::resource::Resource;
use fdbr::sim::time::SimTime;
use fdbr::util::content::{Bytes, Content};

fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed();
    let per = dt.as_nanos() as f64 / iters as f64;
    let rate = 1e9 / per;
    println!("{name:<44} {per:>12.0} ns/op {rate:>14.0} op/s");
}

fn main() {
    println!("== hotpath microbenchmarks (wall clock) ==");

    // DES engine throughput: events/sec through sleep+resource ops
    bench("sim: spawn+run 1000 tasks × 3 awaits", 20, || {
        let sim = Sim::new();
        let res = Resource::new("r", 4);
        for i in 0..1000u64 {
            let s = sim.clone();
            let r = res.clone();
            sim.spawn(async move {
                s.sleep(SimTime::nanos(i)).await;
                r.serve(&s, SimTime::nanos(100)).await;
                s.sleep(SimTime::nanos(50)).await;
            });
        }
        sim.run();
    });

    // Key canonicalization (every archive/retrieve calls this)
    let id = Key::of(&[
        ("class", "od"), ("expver", "0001"), ("stream", "oper"),
        ("date", "20231201"), ("time", "1200"), ("type", "ef"),
        ("levtype", "sfc"), ("step", "42"), ("number", "13"),
        ("levelist", "100"), ("param", "v"),
    ]);
    bench("key: canonical() of 11-dim identifier", 200_000, || {
        std::hint::black_box(id.canonical());
    });
    let canon = id.canonical();
    bench("key: parse canonical", 100_000, || {
        std::hint::black_box(Key::parse(&canon).unwrap());
    });

    // Index serialization + lookup (the POSIX catalogue hot path)
    let entries: Vec<IndexEntry> = {
        let mut es: Vec<IndexEntry> = (0..10_000)
            .map(|i| IndexEntry {
                elem: format!("param=p{},step={}", i % 20, i / 20),
                uri_id: 0,
                offset: i as u64 * 1024,
                length: 1024,
            })
            .collect();
        es.sort_by(|a, b| a.elem.cmp(&b.elem));
        es
    };
    bench("index: serialize 10k entries", 50, || {
        std::hint::black_box(index::serialize(&entries));
    });
    let blob = index::serialize(&entries);
    let (hl, count) = index::parse_prelude(&blob[..12]).unwrap();
    bench("index: parse header (10k entries)", 2_000, || {
        std::hint::black_box(
            index::parse_header(&blob[12..12 + hl as usize], count).unwrap(),
        );
    });
    let header = index::parse_header(&blob[12..12 + hl as usize], count).unwrap();
    bench("index: point lookup via page dir", 20_000, || {
        let p = index::page_for(&header, "param=p7,step=200").unwrap();
        let es = index::parse_page(&blob[p.off as usize..(p.off + p.len) as usize]).unwrap();
        std::hint::black_box(es.iter().find(|e| e.elem == "param=p7,step=200"));
    });

    // DataHandle merging (PGEN's retrieve path)
    let handles: Vec<DataHandle> = (0..1000)
        .map(|i| {
            DataHandle::from_location(&FieldLocation::PosixFile {
                path: format!("/f{}", i % 4),
                offset: (i / 4) * 1024,
                length: 1024,
                checksum: None,
            })
        })
        .collect();
    bench("datahandle: merge 1000 → 4 files", 500, || {
        std::hint::black_box(DataHandle::merge_all(handles.clone()));
    });

    // Content store ops (virtual-payload data plane)
    bench("content: 1000 × 1MiB virtual appends", 200, || {
        let mut c = Content::new();
        for i in 0..1000u64 {
            c.append(Bytes::virt(1 << 20, i));
        }
        std::hint::black_box(c.len());
    });
    let mut big = Content::new();
    for i in 0..10_000u64 {
        big.append(Bytes::virt(1 << 20, i));
    }
    bench("content: random 1MiB read of 10k-seg file", 20_000, || {
        std::hint::black_box(big.read(4242 << 20, 1 << 20));
    });

    // end-to-end simulated archive op rate (DAOS hammer, small run)
    let t0 = Instant::now();
    let dep = fdbr::bench::scenario::deploy(
        fdbr::hw::profiles::Testbed::Gcp,
        fdbr::bench::scenario::SystemKind::Daos,
        2,
        4,
        fdbr::bench::scenario::RedundancyOpt::None,
    );
    let (_, _) = fdbr::bench::hammer::run(
        &dep,
        fdbr::bench::hammer::HammerConfig {
            procs_per_node: 8,
            nsteps: 10,
            nparams: 5,
            nlevels: 4,
            field_size: 1 << 20,
            check: false,
            contention: false,
            faults_ok: false,
        },
    );
    let ops = 2 * 4 * 8 * 10 * 5 * 4; // write+read phases
    let dt = t0.elapsed();
    println!(
        "{:<44} {:>12.0} ns/op {:>14.0} op/s",
        "e2e: simulated hammer archive+retrieve",
        dt.as_nanos() as f64 / ops as f64,
        ops as f64 / dt.as_secs_f64()
    );
    println!("done");
}
