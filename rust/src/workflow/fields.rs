//! Synthetic weather fields + GRIB-style *simple packing* (the Rust
//! mirror of the L1 Pallas kernel in `python/compile/kernels/pack.py`).
//!
//! Fields are smooth pseudo-random f32 grids (red-noise: seeded white
//! noise passed through a few diffusion sweeps). Simple packing follows
//! GRIB2 template 5.0 with 16-bit integers: `v ≈ ref + scale * n`.

use crate::util::content::Bytes;
use crate::util::rng::Rng;

/// Generate a smooth H×W field from a seed (ensemble member/param/step).
pub fn synth_field(h: usize, w: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut f: Vec<f32> = (0..h * w)
        .map(|_| rng.f32() * 40.0 - 10.0) // ~[-10, 30] "temperature"
        .collect();
    // three 5-point diffusion sweeps → spatially-correlated field
    for _ in 0..3 {
        let snap = f.clone();
        for y in 0..h {
            for x in 0..w {
                let idx = y * w + x;
                let up = snap[y.saturating_sub(1) * w + x];
                let dn = snap[(y + 1).min(h - 1) * w + x];
                let lf = snap[y * w + x.saturating_sub(1)];
                let rt = snap[y * w + (x + 1).min(w - 1)];
                f[idx] = 0.5 * snap[idx] + 0.125 * (up + dn + lf + rt);
            }
        }
    }
    f
}

/// f32 grid → raw little-endian bytes.
pub fn to_bytes(field: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(field.len() * 4);
    for v in field {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Raw little-endian bytes → f32 grid.
pub fn from_bytes(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn to_payload(field: &[f32]) -> Bytes {
    Bytes::real(to_bytes(field))
}

/// GRIB simple packing (16-bit): header `[ref f32][scale f32][n u32]`
/// then `n` little-endian u16 quantized values.
pub fn pack_simple(field: &[f32]) -> Vec<u8> {
    let lo = field.iter().copied().fold(f32::INFINITY, f32::min);
    let hi = field.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(f32::MIN_POSITIVE);
    let scale = span / 65535.0;
    let mut out = Vec::with_capacity(12 + field.len() * 2);
    out.extend_from_slice(&lo.to_le_bytes());
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&(field.len() as u32).to_le_bytes());
    for v in field {
        let q = (((v - lo) / scale).round() as u32).min(65535) as u16;
        out.extend_from_slice(&q.to_le_bytes());
    }
    out
}

/// Inverse of [`pack_simple`].
pub fn unpack_simple(packed: &[u8]) -> Option<Vec<f32>> {
    if packed.len() < 12 {
        return None;
    }
    let lo = f32::from_le_bytes(packed[0..4].try_into().unwrap());
    let scale = f32::from_le_bytes(packed[4..8].try_into().unwrap());
    let n = u32::from_le_bytes(packed[8..12].try_into().unwrap()) as usize;
    if packed.len() < 12 + 2 * n {
        return None;
    }
    Some(
        packed[12..12 + 2 * n]
            .chunks_exact(2)
            .map(|c| lo + scale * u16::from_le_bytes(c.try_into().unwrap()) as f32)
            .collect(),
    )
}

/// Max quantization error bound for a field under simple packing.
pub fn packing_error_bound(field: &[f32]) -> f32 {
    let lo = field.iter().copied().fold(f32::INFINITY, f32::min);
    let hi = field.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    (hi - lo).max(f32::MIN_POSITIVE) / 65535.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_field_is_deterministic_and_smooth() {
        let a = synth_field(32, 32, 7);
        let b = synth_field(32, 32, 7);
        assert_eq!(a, b);
        assert_ne!(a, synth_field(32, 32, 8));
        // smoothness: mean |neighbor diff| far below the value range
        let mut diffs = 0.0f32;
        let mut n = 0;
        for y in 0..32 {
            for x in 0..31 {
                diffs += (a[y * 32 + x + 1] - a[y * 32 + x]).abs();
                n += 1;
            }
        }
        assert!(diffs / (n as f32) < 5.0, "mean diff {}", diffs / n as f32);
    }

    #[test]
    fn bytes_roundtrip() {
        let f = synth_field(16, 16, 3);
        assert_eq!(from_bytes(&to_bytes(&f)), f);
    }

    #[test]
    fn pack_roundtrip_within_error_bound() {
        let f = synth_field(64, 64, 11);
        let packed = pack_simple(&f);
        assert_eq!(packed.len(), 12 + f.len() * 2); // ~2x compression
        let back = unpack_simple(&packed).unwrap();
        let bound = packing_error_bound(&f) * 0.51 + 1e-4;
        for (a, b) in f.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }

    #[test]
    fn pack_constant_field() {
        let f = vec![5.0f32; 100];
        let back = unpack_simple(&pack_simple(&f)).unwrap();
        for v in back {
            assert!((v - 5.0).abs() < 1e-3);
        }
    }

    #[test]
    fn unpack_rejects_truncated() {
        let f = synth_field(8, 8, 1);
        let packed = pack_simple(&f);
        assert!(unpack_simple(&packed[..10]).is_none());
        assert!(unpack_simple(&packed[..packed.len() - 1]).is_none());
    }
}
