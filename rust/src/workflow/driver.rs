//! The operational-run driver: wires I/O servers, per-step flush
//! barriers, and staggered PGEN jobs over any deployed storage system
//! (thesis Figs 2.11 / 3.3).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;
use std::task::Waker;

use super::ioserver::{self, IoServerConfig};
use super::pgen::{self, PgenConfig};
use super::Compute;
use crate::bench::scenario::Deployment;
use crate::fdb::Fdb;
use crate::sim::exec::{Sim, WaitGroup};
use crate::sim::time::SimTime;
use crate::sim::trace::Trace;

/// Synchronisation point: PGEN for step `s` starts once every writer
/// process has flushed step `s` (the workflow-manager signal).
pub struct StepBarrier {
    writers: usize,
    arrived: RefCell<HashMap<u32, usize>>,
    wakers: RefCell<HashMap<u32, Vec<Waker>>>,
}

impl StepBarrier {
    pub fn new(writers: usize) -> Rc<StepBarrier> {
        Rc::new(StepBarrier {
            writers,
            arrived: RefCell::new(HashMap::new()),
            wakers: RefCell::new(HashMap::new()),
        })
    }

    /// A writer finished flushing `step`.
    pub async fn arrive(&self, step: u32) {
        let done = {
            let mut a = self.arrived.borrow_mut();
            let e = a.entry(step).or_insert(0);
            *e += 1;
            *e == self.writers
        };
        if done {
            for w in self
                .wakers
                .borrow_mut()
                .remove(&step)
                .unwrap_or_default()
            {
                w.wake();
            }
        }
    }

    fn is_complete(&self, step: u32) -> bool {
        self.arrived
            .borrow()
            .get(&step)
            .map(|&n| n == self.writers)
            .unwrap_or(false)
    }

    /// Wait until all writers flushed `step`.
    pub fn wait(self: &Rc<Self>, step: u32) -> StepWait {
        StepWait {
            barrier: self.clone(),
            step,
        }
    }
}

pub struct StepWait {
    barrier: Rc<StepBarrier>,
    step: u32,
}

impl std::future::Future for StepWait {
    type Output = ();
    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<()> {
        if self.barrier.is_complete(self.step) {
            std::task::Poll::Ready(())
        } else {
            self.barrier
                .wakers
                .borrow_mut()
                .entry(self.step)
                .or_default()
                .push(cx.waker().clone());
            std::task::Poll::Pending
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct OperationalConfig {
    /// ensemble members (each gets `procs_per_member` writer processes)
    pub members: usize,
    pub procs_per_member: usize,
    pub steps: u32,
    /// fields archived per writer process per step (65 operationally)
    pub fields_per_proc_step: u32,
    /// field grid side (side² × 4 bytes per field)
    pub grid: usize,
    /// decode f32 grids and run the PGEN compute (vs seed verification)
    pub real_compute: bool,
}

impl Default for OperationalConfig {
    fn default() -> Self {
        OperationalConfig {
            members: 2,
            procs_per_member: 4,
            steps: 4,
            fields_per_proc_step: 8,
            grid: 64,
            real_compute: false,
        }
    }
}

pub struct RunReport {
    pub makespan: SimTime,
    pub fields_written: u64,
    pub fields_read: u64,
    pub bytes: u64,
    pub products: usize,
    pub trace: Trace,
}

fn make_fdb(dep: &Deployment, node: &Rc<crate::hw::node::Node>, trace: &Trace) -> Fdb {
    dep.fdb_traced(node, trace)
}

/// Run a full operational cycle: all steps written, all steps
/// post-processed, everything verified.
pub fn run(dep: &Deployment, cfg: OperationalConfig, compute: Compute) -> RunReport {
    let trace = Trace::new();
    let clients = dep.client_nodes();
    assert!(
        !clients.is_empty(),
        "operational run needs client nodes for I/O servers + PGEN"
    );
    let writers = cfg.members * cfg.procs_per_member;
    let barrier = StepBarrier::new(writers);
    let products = Rc::new(Cell::new(0usize));
    let fields_read = Rc::new(Cell::new(0u64));
    let bytes_read = Rc::new(Cell::new(0u64));
    // everything joins through this group: writers + one PGEN per step
    let wg = WaitGroup::new(writers + cfg.steps as usize);

    // ---- I/O servers
    let mut slot = 0usize;
    for member in 0..cfg.members {
        for proc in 0..cfg.procs_per_member {
            let node = clients[slot % clients.len()].clone();
            slot += 1;
            let fdb = make_fdb(dep, &node, &trace);
            let sim: Sim = dep.sim.clone();
            let barrier = barrier.clone();
            let wg = wg.clone();
            let io_cfg = IoServerConfig {
                member,
                proc,
                steps: cfg.steps,
                fields_per_step: cfg.fields_per_proc_step,
                grid: cfg.grid,
            };
            dep.sim.spawn(async move {
                ioserver::run(fdb, sim, io_cfg, barrier, cfg.real_compute).await;
                wg.done();
            });
        }
    }

    // ---- PGEN jobs: one per step, started on the barrier signal
    for step in 1..=cfg.steps {
        let node = clients[(step as usize) % clients.len()].clone();
        let fdb = make_fdb(dep, &node, &trace);
        let sim: Sim = dep.sim.clone();
        let barrier = barrier.clone();
        let wg = wg.clone();
        let compute = compute.clone();
        let products = products.clone();
        let fields_read = fields_read.clone();
        let bytes_read = bytes_read.clone();
        let pg_cfg = PgenConfig {
            step,
            members: cfg.members,
            procs_per_member: cfg.procs_per_member,
            fields_per_proc_step: cfg.fields_per_proc_step,
            grid: cfg.grid,
            verify_only: !cfg.real_compute,
        };
        dep.sim.spawn(async move {
            barrier.wait(step).await;
            let report = pgen::run(fdb, sim, pg_cfg, compute).await;
            products.set(products.get() + report.products);
            fields_read.set(fields_read.get() + report.fields_read);
            bytes_read.set(bytes_read.get() + report.bytes_read);
            wg.done();
        });
    }

    let makespan = dep.sim.run();
    let fields_written =
        writers as u64 * cfg.steps as u64 * cfg.fields_per_proc_step as u64;
    RunReport {
        makespan,
        fields_written,
        fields_read: fields_read.get(),
        bytes: bytes_read.get(),
        products: products.get(),
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::scenario::{deploy, RedundancyOpt, SystemKind};
    use crate::hw::profiles::Testbed;
    use crate::workflow::NullCompute;

    #[test]
    fn operational_run_on_all_backends() {
        for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
            let dep = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
            let cfg = OperationalConfig::default();
            let report = run(&dep, cfg, Rc::new(NullCompute));
            assert_eq!(
                report.fields_read, report.fields_written,
                "{kind:?}: every archived field must be post-processed"
            );
            assert!(report.makespan > SimTime::ZERO);
        }
    }

    #[test]
    fn pgen_overlaps_with_writing() {
        // PGEN for step 1 must complete before the last step's flush:
        // the makespan should be well below (write_all + read_all) serial
        let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 4, RedundancyOpt::None);
        let cfg = OperationalConfig {
            steps: 6,
            ..Default::default()
        };
        let report = run(&dep, cfg, Rc::new(NullCompute));
        // serial lower bound if nothing overlapped: bytes written+read
        // at the 2-node ceiling (~6 GiB/s)
        let serial = (2.0 * report.bytes as f64) / (6.0 * (1u64 << 30) as f64);
        assert!(
            report.makespan.as_secs_f64() < serial * 1.5 + 1.0,
            "makespan {} suggests no overlap (serial est {serial})",
            report.makespan
        );
    }

    #[test]
    fn step_barrier_orders_pgen() {
        let sim = crate::sim::exec::Sim::new();
        let b = StepBarrier::new(2);
        let seen = Rc::new(Cell::new(0u32));
        {
            let b = b.clone();
            let seen = seen.clone();
            let s = sim.clone();
            sim.spawn(async move {
                b.wait(1).await;
                seen.set(s.now().as_nanos() as u32);
            });
        }
        for d in [10u64, 20] {
            let b = b.clone();
            let s = sim.clone();
            sim.spawn(async move {
                s.sleep(SimTime::micros(d)).await;
                b.arrive(1).await;
            });
        }
        sim.run();
        assert_eq!(seen.get(), 20_000, "pgen starts at the straggler flush");
    }
}
