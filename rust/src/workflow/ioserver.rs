//! I/O server processes (thesis Fig 2.11): receive model fields and
//! archive them step by step, flushing at step end and signalling the
//! workflow manager so PGEN can start.

use std::rc::Rc;

use super::driver::StepBarrier;
use crate::fdb::{Fdb, Key};
use crate::sim::exec::Sim;
use crate::workflow::fields;

#[derive(Clone, Copy, Debug)]
pub struct IoServerConfig {
    pub member: usize,
    pub proc: usize,
    pub steps: u32,
    /// fields archived per process per step (65 operationally)
    pub fields_per_step: u32,
    /// grid side (fields are side×side f32)
    pub grid: usize,
}

/// Identifier for one model output field.
pub fn model_field_id(member: usize, proc: usize, step: u32, f: u32) -> Key {
    Key::of(&[
        ("class", "od"),
        ("expver", "0001"),
        ("stream", "oper"),
        ("date", "20231201"),
        ("time", "0000"),
        ("type", "fc"),
        ("levtype", "ml"),
    ])
    .with("number", member.to_string())
    .with("levelist", (proc + 1).to_string())
    .with("step", step.to_string())
    .with("param", format!("p{f}"))
}

/// Payload seed so readers can verify content without re-generating grids.
pub fn model_field_seed(id: &Key) -> u64 {
    crate::ceph::hash_name(&id.canonical())
}

/// Run one I/O server process to completion. Each step's fields go
/// through the batched `archive_many` path (one Store pass, one
/// Catalogue pass), then the step flush + barrier signal.
pub async fn run(
    mut fdb: Fdb,
    sim: Sim,
    cfg: IoServerConfig,
    barrier: Rc<StepBarrier>,
    real_fields: bool,
) {
    for step in 1..=cfg.steps {
        let mut batch = Vec::with_capacity(cfg.fields_per_step as usize);
        for f in 0..cfg.fields_per_step {
            let id = model_field_id(cfg.member, cfg.proc, step, f);
            let payload = if real_fields {
                // actual f32 grid bytes (PGEN will compute on them)
                let grid = fields::synth_field(
                    cfg.grid,
                    cfg.grid,
                    model_field_seed(&id),
                );
                fields::to_payload(&grid)
            } else {
                crate::util::content::Bytes::virt(
                    (cfg.grid * cfg.grid * 4) as u64,
                    model_field_seed(&id),
                )
            };
            batch.push((id, payload));
        }
        fdb.archive_many(batch).await.expect("archive_many");
        fdb.flush().await.expect("flush");
        barrier.arrive(step).await;
    }
    fdb.close().await.expect("close");
    let _ = sim;
}
