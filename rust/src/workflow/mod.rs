//! The operational NWP I/O pattern (thesis §2.7.2 / Fig 2.11): I/O server
//! processes archiving per-step fields with flush barriers, and staggered
//! PGEN (product generation) jobs reading each step back while the model
//! still writes — the write+read contention the evaluation centres on.

pub mod driver;
pub mod fields;
pub mod ioserver;
pub mod pgen;

use std::rc::Rc;

use crate::sim::time::SimTime;

/// The PGEN compute hook: derived-product generation over a step's
/// ensemble fields. The production implementation executes the
/// AOT-compiled JAX/Pallas graph via PJRT (`runtime::PgenPipeline`);
/// tests use [`NullCompute`].
pub trait PgenCompute {
    /// Consume the step's fields (each a f32 grid), produce derived
    /// products (e.g. ensemble mean/spread/exceedance probability).
    fn run(&self, fields: &[Vec<f32>]) -> Vec<Vec<f32>>;
    /// Virtual-time cost charged to the simulation for one invocation.
    fn cost(&self) -> SimTime;
}

/// No-op compute (I/O-only workflows, like fdb-hammer).
pub struct NullCompute;

impl PgenCompute for NullCompute {
    fn run(&self, _fields: &[Vec<f32>]) -> Vec<Vec<f32>> {
        Vec::new()
    }
    fn cost(&self) -> SimTime {
        SimTime::ZERO
    }
}

pub type Compute = Rc<dyn PgenCompute>;
