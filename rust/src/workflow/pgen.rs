//! PGEN: product-generation jobs (thesis Fig 2.11). One job per model
//! step, launched once every I/O server has flushed that step. The job
//! retrieves the step's fields across all members (the transposed
//! access), runs the derived-product computation (PJRT at production),
//! and reports what it read.

use super::ioserver::{model_field_id, model_field_seed};
use super::Compute;
use crate::fdb::Fdb;
use crate::sim::exec::Sim;
use crate::sim::trace::OpClass;
use crate::workflow::fields;

#[derive(Clone, Copy, Debug)]
pub struct PgenConfig {
    pub step: u32,
    pub members: usize,
    pub procs_per_member: usize,
    pub fields_per_proc_step: u32,
    pub grid: usize,
    /// verify payload seeds instead of decoding f32 grids
    pub verify_only: bool,
}

/// Output of one PGEN job.
pub struct PgenReport {
    pub step: u32,
    pub fields_read: u64,
    pub bytes_read: u64,
    pub products: usize,
}

/// Run one PGEN job as a single simulated process that fans its reads
/// over the step's whole ensemble (operationally 4–8 nodes × 8 procs;
/// the fan-out is represented by this process' sequential retrieves over
/// the merged handles, which the DES charges identically).
pub async fn run(
    mut fdb: Fdb,
    sim: Sim,
    cfg: PgenConfig,
    compute: Compute,
) -> PgenReport {
    // make this step's flushes visible to a fresh view (thesis: PGEN jobs
    // are new processes, so no stale preload)
    let sample = model_field_id(0, 0, cfg.step, 0);
    let ds = sample
        .project(&fdb.schema.dataset.clone())
        .expect("dataset dims");
    fdb.invalidate_preload(&ds);

    // the transposed access: every member/proc's fields for this step,
    // fetched through the batched path (catalogue lookups pipelined
    // with store reads)
    let mut ids = Vec::new();
    for member in 0..cfg.members {
        for proc in 0..cfg.procs_per_member {
            for f in 0..cfg.fields_per_proc_step {
                ids.push(model_field_id(member, proc, cfg.step, f));
            }
        }
    }
    let fetched = fdb.retrieve_many(&ids).await.expect("retrieve_many");
    if fetched.len() != ids.len() {
        let found: std::collections::HashSet<&crate::fdb::Key> =
            fetched.iter().map(|(id, _)| id).collect();
        let missing = ids
            .iter()
            .find(|id| !found.contains(id))
            .expect("some id must be missing");
        panic!("PGEN step {}: missing {missing}", cfg.step);
    }
    let mut fields_read = 0u64;
    let mut bytes_read = 0u64;
    let mut grids: Vec<Vec<f32>> = Vec::new();
    for (id, data) in &fetched {
        bytes_read += data.len();
        fields_read += 1;
        if cfg.verify_only {
            let expect = crate::util::content::Bytes::virt(
                (cfg.grid * cfg.grid * 4) as u64,
                model_field_seed(id),
            );
            assert!(
                data.content_eq(&expect),
                "PGEN consistency check failed for {id}"
            );
        } else {
            grids.push(fields::from_bytes(&data.to_vec()));
        }
    }
    // derived products over the ensemble
    let t0 = sim.now();
    let products = if grids.is_empty() {
        0
    } else {
        compute.run(&grids).len()
    };
    sim.sleep(compute.cost()).await;
    fdb.trace.record(OpClass::Compute, sim.now() - t0);
    PgenReport {
        step: cfg.step,
        fields_read,
        bytes_read,
        products,
    }
}
