//! Testbed profiles: NEXTGenIO (SCM + Omni-Path) and GCP (NVMe + VPC TCP).
//!
//! These encode the calibration constants in DESIGN.md. The figure
//! harness builds clusters from a profile + node counts, matching the
//! paper's deployments (e.g. "16 server VMs + 32 client VMs, 2:1").

use std::rc::Rc;

use crate::hw::cluster::Cluster;
use crate::hw::device::DeviceSpec;
use crate::hw::fabric::{Fabric, FabricKind};
use crate::hw::node::{Node, NodeRole};

/// Which testbed a deployment models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Testbed {
    /// NEXTGenIO: Optane DCPMM nodes, Omni-Path (PSM2 for DAOS, TCP-capable).
    NextGenIo,
    /// GCP: n2-custom-36-153600 VMs with 6 TiB local NVMe, VPC TCP.
    Gcp,
}

impl Testbed {
    pub fn storage_device(self) -> DeviceSpec {
        match self {
            Testbed::NextGenIo => DeviceSpec::scm_node(),
            Testbed::Gcp => DeviceSpec::nvme_gcp_node(),
        }
    }

    /// The fabric a given storage system can exploit on this testbed.
    /// Ceph cannot use PSM2/RDMA (thesis §2.4) — always TCP.
    pub fn fabric_for(self, tcp_only: bool) -> FabricKind {
        match (self, tcp_only) {
            (Testbed::NextGenIo, false) => FabricKind::Psm2,
            (Testbed::NextGenIo, true) => FabricKind::TcpOpa,
            (Testbed::Gcp, _) => FabricKind::TcpGcp,
        }
    }

    pub fn cores(self) -> usize {
        match self {
            Testbed::NextGenIo => 48,
            Testbed::Gcp => 36,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Testbed::NextGenIo => "NEXTGenIO",
            Testbed::Gcp => "GCP n2-custom-36",
        }
    }
}

/// Build a cluster: `servers` storage nodes, `clients` client nodes, and
/// optionally one extra metadata/monitor node (Lustre MDS / Ceph Mon).
pub fn build_cluster(
    testbed: Testbed,
    servers: usize,
    clients: usize,
    extra_md_node: bool,
    tcp_only: bool,
) -> Cluster {
    let fabric = Fabric::new(testbed.fabric_for(tcp_only));
    let mut nodes: Vec<Rc<Node>> = Vec::new();
    let mut id = 0;
    for _ in 0..servers {
        nodes.push(Node::new(
            id,
            NodeRole::Storage,
            testbed.cores(),
            vec![testbed.storage_device()],
        ));
        id += 1;
    }
    if extra_md_node {
        nodes.push(Node::new(
            id,
            NodeRole::Metadata,
            testbed.cores(),
            vec![DeviceSpec::mdt_ssd()],
        ));
        id += 1;
    }
    for _ in 0..clients {
        nodes.push(Node::new(id, NodeRole::Client, testbed.cores(), vec![]));
        id += 1;
    }
    Cluster::new(fabric, nodes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nextgenio_uses_psm2_unless_tcp_only() {
        assert_eq!(
            Testbed::NextGenIo.fabric_for(false),
            FabricKind::Psm2
        );
        assert_eq!(Testbed::NextGenIo.fabric_for(true), FabricKind::TcpOpa);
        assert_eq!(Testbed::Gcp.fabric_for(false), FabricKind::TcpGcp);
    }

    #[test]
    fn cluster_layout() {
        let c = build_cluster(Testbed::Gcp, 4, 8, true, false);
        assert_eq!(c.storage_nodes().count(), 4);
        assert_eq!(c.client_nodes().count(), 8);
        assert_eq!(c.metadata_nodes().count(), 1);
        assert_eq!(c.nodes.len(), 13);
    }
}
