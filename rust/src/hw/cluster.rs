//! A set of nodes joined by a fabric — the deployment unit benchmarks run on.

use std::rc::Rc;

use crate::hw::fabric::Fabric;
use crate::hw::node::{Node, NodeRole};
use crate::sim::exec::Sim;

pub struct Cluster {
    pub fabric: Rc<Fabric>,
    pub nodes: Vec<Rc<Node>>,
}

impl Cluster {
    pub fn new(fabric: Rc<Fabric>, nodes: Vec<Rc<Node>>) -> Cluster {
        Cluster { fabric, nodes }
    }

    pub fn storage_nodes(&self) -> impl Iterator<Item = &Rc<Node>> {
        self.nodes
            .iter()
            .filter(|n| n.role == NodeRole::Storage)
    }

    pub fn client_nodes(&self) -> impl Iterator<Item = &Rc<Node>> {
        self.nodes.iter().filter(|n| n.role == NodeRole::Client)
    }

    pub fn metadata_nodes(&self) -> impl Iterator<Item = &Rc<Node>> {
        self.nodes
            .iter()
            .filter(|n| n.role == NodeRole::Metadata)
    }

    pub fn node(&self, id: usize) -> &Rc<Node> {
        &self.nodes[id]
    }

    /// Bulk transfer helper between two nodes of this cluster.
    pub async fn xfer(&self, sim: &Sim, src: &Rc<Node>, dst: &Rc<Node>, bytes: u64) {
        self.fabric.xfer(sim, &src.nic, &dst.nic, bytes).await;
    }

    /// RPC round trip between two nodes (latency only, no payload).
    pub async fn rpc(&self, sim: &Sim, _src: &Rc<Node>, _dst: &Rc<Node>) {
        self.fabric.rpc_rtt(sim).await;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::fabric::FabricKind;
    use crate::hw::node::NodeRole;

    #[test]
    fn role_filters() {
        let fabric = Fabric::new(FabricKind::Psm2);
        let nodes = vec![
            Node::new(0, NodeRole::Storage, 4, vec![]),
            Node::new(1, NodeRole::Client, 4, vec![]),
            Node::new(2, NodeRole::Client, 4, vec![]),
        ];
        let c = Cluster::new(fabric, nodes);
        assert_eq!(c.storage_nodes().count(), 1);
        assert_eq!(c.client_nodes().count(), 2);
        assert_eq!(c.metadata_nodes().count(), 0);
    }
}
