//! A simulated cluster node: NIC + CPU pool + storage device(s).

use std::rc::Rc;

use crate::hw::device::{Device, DeviceSpec};
use crate::hw::fabric::Nic;
use crate::sim::exec::Sim;
use crate::sim::resource::Resource;
use crate::sim::time::SimTime;

/// Node role — informational, used by deployments and reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRole {
    Client,
    Storage,
    Metadata,
    Monitor,
}

pub struct Node {
    pub id: usize,
    pub role: NodeRole,
    pub nic: Rc<Nic>,
    /// CPU service pool for server-side request handling.
    pub cpu: Rc<Resource>,
    /// Storage devices (empty for pure clients).
    pub devices: Vec<Rc<Device>>,
}

impl Node {
    pub fn new(id: usize, role: NodeRole, cores: usize, devs: Vec<DeviceSpec>) -> Rc<Node> {
        Rc::new(Node {
            id,
            role,
            nic: Nic::new(id),
            cpu: Resource::new(format!("node{id}/cpu"), cores.max(1)),
            devices: devs
                .into_iter()
                .enumerate()
                .map(|(i, spec)| Device::new(spec, &format!("node{id}/dev{i}")))
                .collect(),
        })
    }

    /// Primary device (most nodes have exactly one storage pool).
    pub fn dev(&self) -> &Rc<Device> {
        &self.devices[0]
    }

    /// Charge server-side CPU for handling one request.
    pub async fn cpu_serve(&self, sim: &Sim, dur: SimTime) {
        self.cpu.serve(sim, dur).await;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_construction() {
        let n = Node::new(3, NodeRole::Storage, 36, vec![DeviceSpec::scm_node()]);
        assert_eq!(n.id, 3);
        assert_eq!(n.devices.len(), 1);
        assert_eq!(n.dev().spec.name, "optane-dcpmm");
    }

    #[test]
    fn client_has_no_devices() {
        let n = Node::new(0, NodeRole::Client, 48, vec![]);
        assert!(n.devices.is_empty());
    }

    #[test]
    fn cpu_pool_limits_concurrency() {
        let sim = Sim::new();
        let n = Node::new(0, NodeRole::Storage, 2, vec![]);
        for _ in 0..4 {
            let s = sim.clone();
            let node = n.clone();
            sim.spawn(async move {
                node.cpu_serve(&s, SimTime::micros(10)).await;
            });
        }
        // 4 jobs on 2 cores, 10us each → 20us makespan
        assert_eq!(sim.run(), SimTime::micros(20));
    }
}
