//! Storage-device models: SCM (Optane DCPMM), NVMe SSD, HDD.
//!
//! A device is modeled in two stages:
//!  * an **op stage** — a k-server queue whose service time is the device
//!    access latency (k = internal parallelism / queue depth), which caps
//!    small-op IOPS at `k / latency`;
//!  * a **bandwidth pipe** — a 1-server queue at the full sequential
//!    bandwidth, which caps aggregate throughput for bulk transfers.
//!
//! A single streaming client thus sees `latency + bytes/bw` per op and can
//! saturate the device; many small-op clients saturate the op stage first.

use std::rc::Rc;

use crate::sim::exec::Sim;
use crate::sim::resource::Resource;
use crate::sim::time::{transfer_time, SimTime};

/// Static description of a device's performance envelope.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// sequential write bandwidth, bytes/sec
    pub write_bw: f64,
    /// sequential read bandwidth, bytes/sec
    pub read_bw: f64,
    /// per-op write access latency
    pub write_lat: SimTime,
    /// per-op read access latency
    pub read_lat: SimTime,
    /// internal op parallelism (queue depth the device services at once)
    pub parallelism: usize,
}

impl DeviceSpec {
    /// Intel Optane DCPMM (SCM) aggregate per NEXTGenIO node (6 DIMMs/socket
    /// ×2 used as one pool): very low latency, strong read, weaker write.
    pub fn scm_node() -> DeviceSpec {
        DeviceSpec {
            name: "optane-dcpmm",
            write_bw: 8.0 * (1u64 << 30) as f64,
            read_bw: 30.0 * (1u64 << 30) as f64,
            write_lat: SimTime::nanos(350),
            read_lat: SimTime::nanos(180),
            parallelism: 16,
        }
    }

    /// GCP local NVMe SSD aggregate per n2-custom-36 VM (16×375 GB = 6 TiB).
    pub fn nvme_gcp_node() -> DeviceSpec {
        DeviceSpec {
            name: "nvme-local-gcp",
            write_bw: 3.0 * (1u64 << 30) as f64,
            read_bw: 6.6 * (1u64 << 30) as f64,
            write_lat: SimTime::micros(25),
            read_lat: SimTime::micros(90),
            parallelism: 32,
        }
    }

    /// A small metadata-grade SSD (Lustre MDT on the extra node).
    pub fn mdt_ssd() -> DeviceSpec {
        DeviceSpec {
            name: "mdt-ssd",
            write_bw: 2.0 * (1u64 << 30) as f64,
            read_bw: 3.0 * (1u64 << 30) as f64,
            write_lat: SimTime::micros(15),
            read_lat: SimTime::micros(60),
            parallelism: 16,
        }
    }
}

/// A live simulated device bound to a `Sim`.
pub struct Device {
    pub spec: DeviceSpec,
    write_ops: Rc<Resource>,
    read_ops: Rc<Resource>,
    write_pipe: Rc<Resource>,
    read_pipe: Rc<Resource>,
}

impl Device {
    pub fn new(spec: DeviceSpec, tag: &str) -> Rc<Device> {
        Rc::new(Device {
            write_ops: Resource::new(format!("{tag}/wops"), spec.parallelism),
            read_ops: Resource::new(format!("{tag}/rops"), spec.parallelism),
            write_pipe: Resource::new(format!("{tag}/wbw"), 1),
            read_pipe: Resource::new(format!("{tag}/rbw"), 1),
            spec,
        })
    }

    /// Persist `bytes`; returns when durable (no volatile cache modeled —
    /// write-back caching is a *client*-side concern, see lustre::client).
    pub async fn write(&self, sim: &Sim, bytes: u64) {
        self.write_with_lat(sim, bytes, self.spec.write_lat).await;
    }

    /// Write with an overridden commit latency — used by log-structured
    /// consumers (DAOS VOS WAL) whose small commits don't pay the full
    /// block-write latency.
    pub async fn write_with_lat(&self, sim: &Sim, bytes: u64, lat: SimTime) {
        self.write_ops.serve(sim, lat).await;
        self.write_pipe
            .serve(sim, transfer_time(bytes, self.spec.write_bw))
            .await;
    }

    /// Read `bytes` from media.
    pub async fn read(&self, sim: &Sim, bytes: u64) {
        self.read_with_lat(sim, bytes, self.spec.read_lat).await;
    }

    /// Read with an overridden access latency — byte-addressable
    /// consumers (DAOS on SCM, indexed VOS extents) skip the block
    /// fetch path.
    pub async fn read_with_lat(&self, sim: &Sim, bytes: u64, lat: SimTime) {
        self.read_ops.serve(sim, lat).await;
        self.read_pipe
            .serve(sim, transfer_time(bytes, self.spec.read_bw))
            .await;
    }

    /// Observed busy time of the write pipe (utilization reporting).
    pub fn write_busy(&self) -> SimTime {
        self.write_pipe.busy_time()
    }

    pub fn read_busy(&self) -> SimTime {
        self.read_pipe.busy_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    fn run_writes(spec: DeviceSpec, nclients: usize, ops: usize, bytes: u64) -> f64 {
        let sim = Sim::new();
        let dev = Device::new(spec, "t");
        for _ in 0..nclients {
            let s = sim.clone();
            let d = dev.clone();
            sim.spawn(async move {
                for _ in 0..ops {
                    d.write(&s, bytes).await;
                }
            });
        }
        let end = sim.run();
        (nclients * ops) as u64 as f64 * bytes as f64 / end.as_secs_f64()
    }

    #[test]
    fn bulk_write_saturates_bandwidth() {
        // 8 clients × 100 × 1 MiB on an 8 GiB/s SCM node ≈ 8 GiB/s aggregate
        let bw = run_writes(DeviceSpec::scm_node(), 8, 100, 1 << 20);
        let ideal = 8.0 * (1u64 << 30) as f64;
        assert!(bw > 0.85 * ideal, "bw {bw} vs ideal {ideal}");
        assert!(bw <= ideal * 1.01);
    }

    #[test]
    fn single_client_also_near_full_bw() {
        let bw = run_writes(DeviceSpec::scm_node(), 1, 200, 1 << 20);
        let ideal = 8.0 * (1u64 << 30) as f64;
        assert!(bw > 0.7 * ideal, "bw {bw}");
    }

    #[test]
    fn small_ops_are_iops_capped() {
        // 64-byte writes: throughput must be far below the bandwidth cap.
        let bw = run_writes(DeviceSpec::nvme_gcp_node(), 16, 200, 64);
        let ideal = 3.0 * (1u64 << 30) as f64;
        assert!(bw < 0.05 * ideal, "bw {bw}");
    }

    #[test]
    fn read_faster_than_write_on_scm() {
        let sim = Sim::new();
        let dev = Device::new(DeviceSpec::scm_node(), "t");
        let wr_end = Cell::new(SimTime::ZERO);
        {
            let s = sim.clone();
            let d = dev.clone();
            sim.spawn(async move {
                for _ in 0..100 {
                    d.write(&s, 1 << 20).await;
                }
            });
        }
        let w = sim.run();
        wr_end.set(w);
        let sim2 = Sim::new();
        let dev2 = Device::new(DeviceSpec::scm_node(), "t2");
        {
            let s = sim2.clone();
            sim2.spawn(async move {
                for _ in 0..100 {
                    dev2.read(&s, 1 << 20).await;
                }
            });
        }
        let r = sim2.run();
        assert!(r < w, "read {r} should beat write {w}");
    }
}
