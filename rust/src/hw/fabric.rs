//! Network fabric models: Omni-Path PSM2, TCP (over OPA or GCP VPC), RDMA.
//!
//! Each node owns a full-duplex NIC (tx pipe + rx pipe at link bandwidth).
//! A bulk transfer holds the sender's tx pipe and the receiver's rx pipe
//! concurrently (acquired in global order to avoid cycles) for
//! `bytes / link_bw`, plus one message latency. Small control messages
//! (RPCs) cost latency only plus a per-message CPU overhead constant —
//! this is where TCP's kernel involvement hurts vs user-space PSM2,
//! reproducing Table 4.1's ratio.

use std::rc::Rc;

use crate::sim::exec::Sim;
use crate::sim::resource::Resource;
use crate::sim::time::{transfer_time, SimTime};

/// Fabric technology profile.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FabricKind {
    /// Omni-Path with PSM2: user-space, ~1.5 µs latency, ~11.2 GiB/s.
    Psm2,
    /// TCP over Omni-Path: kernel path, ~25 µs, ~2.8 GiB/s effective.
    TcpOpa,
    /// GCP VPC TCP: ~30 µs, ~3.1 GiB/s per VM (32 Gbit/s egress).
    TcpGcp,
}

#[derive(Clone, Copy, Debug)]
pub struct FabricSpec {
    pub kind: FabricKind,
    /// one-way small-message latency
    pub msg_lat: SimTime,
    /// per-NIC link bandwidth, bytes/sec
    pub link_bw: f64,
    /// per-message CPU/kernel overhead charged to the initiating side
    pub per_msg_cpu: SimTime,
}

impl FabricSpec {
    pub fn of(kind: FabricKind) -> FabricSpec {
        match kind {
            FabricKind::Psm2 => FabricSpec {
                kind,
                msg_lat: SimTime::nanos(1_500),
                link_bw: 11.2 * (1u64 << 30) as f64,
                per_msg_cpu: SimTime::nanos(400),
            },
            FabricKind::TcpOpa => FabricSpec {
                kind,
                msg_lat: SimTime::micros(25),
                link_bw: 2.8 * (1u64 << 30) as f64,
                per_msg_cpu: SimTime::micros(4),
            },
            FabricKind::TcpGcp => FabricSpec {
                kind,
                msg_lat: SimTime::micros(30),
                link_bw: 3.1 * (1u64 << 30) as f64,
                per_msg_cpu: SimTime::micros(4),
            },
        }
    }
}

/// A node's network interface: independent tx and rx bandwidth pipes.
pub struct Nic {
    pub id: usize,
    tx: Rc<Resource>,
    rx: Rc<Resource>,
}

impl Nic {
    pub fn new(id: usize) -> Rc<Nic> {
        Rc::new(Nic {
            id,
            tx: Resource::new(format!("nic{id}/tx"), 1),
            rx: Resource::new(format!("nic{id}/rx"), 1),
        })
    }

    pub fn tx_busy(&self) -> SimTime {
        self.tx.busy_time()
    }
    pub fn rx_busy(&self) -> SimTime {
        self.rx.busy_time()
    }
}

/// The fabric connecting all nodes of a cluster.
pub struct Fabric {
    pub spec: FabricSpec,
}

impl Fabric {
    pub fn new(kind: FabricKind) -> Rc<Fabric> {
        Rc::new(Fabric {
            spec: FabricSpec::of(kind),
        })
    }

    /// Bulk transfer of `bytes` from `src` to `dst`.
    ///
    /// Holds src.tx and dst.rx concurrently for the wire time. Resources
    /// are acquired in (nic id, direction) order so concurrent opposing
    /// transfers cannot deadlock.
    pub async fn xfer(&self, sim: &Sim, src: &Rc<Nic>, dst: &Rc<Nic>, bytes: u64) {
        sim.sleep(self.spec.msg_lat + self.spec.per_msg_cpu).await;
        if src.id == dst.id {
            // intra-node: charge a memcpy at 4x link speed, no NIC usage
            sim.sleep(transfer_time(bytes, self.spec.link_bw * 4.0)).await;
            return;
        }
        let dur = transfer_time(bytes, self.spec.link_bw);
        // global acquisition order: lower nic id first; tx before rx on tie
        let (first, second) = if src.id <= dst.id {
            (&src.tx, &dst.rx)
        } else {
            (&dst.rx, &src.tx)
        };
        first.acquire().await;
        second.acquire().await;
        sim.sleep(dur).await;
        second.release();
        first.release();
    }

    /// Small control message one-way (e.g. an RPC request or reply).
    pub async fn msg(&self, sim: &Sim) {
        sim.sleep(self.spec.msg_lat + self.spec.per_msg_cpu).await;
    }

    /// A full request/reply round trip with no payload.
    pub async fn rpc_rtt(&self, sim: &Sim) {
        self.msg(sim).await;
        self.msg(sim).await;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;
    use std::rc::Rc;

    #[test]
    fn single_stream_hits_link_bw() {
        let sim = Sim::new();
        let fabric = Fabric::new(FabricKind::Psm2);
        let a = Nic::new(0);
        let b = Nic::new(1);
        let s = sim.clone();
        let f = fabric.clone();
        let (a2, b2) = (a.clone(), b.clone());
        sim.spawn(async move {
            for _ in 0..100 {
                f.xfer(&s, &a2, &b2, 8 << 20).await;
            }
        });
        let end = sim.run();
        let bw = 100.0 * (8u64 << 20) as f64 / end.as_secs_f64();
        let ideal = 11.2 * (1u64 << 30) as f64;
        assert!(bw > 0.9 * ideal, "bw {bw}");
    }

    #[test]
    fn many_to_one_shares_receiver() {
        let sim = Sim::new();
        let fabric = Fabric::new(FabricKind::TcpGcp);
        let server = Nic::new(0);
        for i in 1..=4 {
            let cli = Nic::new(i);
            let s = sim.clone();
            let f = fabric.clone();
            let srv = server.clone();
            sim.spawn(async move {
                for _ in 0..50 {
                    f.xfer(&s, &cli, &srv, 1 << 20).await;
                }
            });
        }
        let end = sim.run();
        let bw = 200.0 * (1u64 << 20) as f64 / end.as_secs_f64();
        let ideal = 3.1 * (1u64 << 30) as f64;
        assert!(bw < ideal * 1.01, "bw {bw} cannot exceed receiver link");
        assert!(bw > 0.8 * ideal, "bw {bw} should approach receiver link");
    }

    #[test]
    fn psm2_latency_beats_tcp() {
        let lat = |kind| {
            let sim = Sim::new();
            let f = Fabric::new(kind);
            let done = Rc::new(Cell::new(SimTime::ZERO));
            let d = done.clone();
            let s = sim.clone();
            sim.spawn(async move {
                for _ in 0..100 {
                    f.rpc_rtt(&s).await;
                }
                d.set(s.now());
            });
            sim.run();
            done.get()
        };
        let psm2 = lat(FabricKind::Psm2);
        let tcp = lat(FabricKind::TcpOpa);
        assert!(
            tcp.as_nanos() > 10 * psm2.as_nanos(),
            "tcp {tcp} vs psm2 {psm2}"
        );
    }

    #[test]
    fn opposing_transfers_no_deadlock() {
        let sim = Sim::new();
        let fabric = Fabric::new(FabricKind::Psm2);
        let a = Nic::new(0);
        let b = Nic::new(1);
        for _ in 0..10 {
            let (s, f, x, y) = (sim.clone(), fabric.clone(), a.clone(), b.clone());
            sim.spawn(async move {
                f.xfer(&s, &x, &y, 4 << 20).await;
            });
            let (s, f, x, y) = (sim.clone(), fabric.clone(), b.clone(), a.clone());
            sim.spawn(async move {
                f.xfer(&s, &x, &y, 4 << 20).await;
            });
        }
        sim.run(); // must terminate
    }
}
