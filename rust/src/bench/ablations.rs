//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * `abl_hash_oid`   — the DAOS hash-OID retrieve optimisation the
//!   thesis leaves as future work (§3.1.2): index-free retrieval vs the
//!   KV-network path.
//! * `abl_lustre_dne` — Lustre DNE metadata scaling (§2.2.1): MDS count
//!   sweep under a metadata-heavy (file-per-field) workload.
//! * `abl_pg_count`   — RADOS placement-group sensitivity (§2.4/§3.2).
//! * `abl_s3_multipart` — S3 Store PutObject-per-field vs multipart
//!   accumulation (§3.3's expected write win).
//! * `abl_wrappers`   — the composable backend wrappers (tiered cache,
//!   replicated store, sharded catalogue) over the same fdb-hammer
//!   workload, against the bare backend baseline.

use std::rc::Rc;

use super::figures::{FigRow, Figure};
use super::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest};
use crate::bench::aggregate_bw;
use crate::fdb::{BackendConfig, Fdb, FdbBuilder};
use crate::hw::profiles::Testbed;
use crate::lustre::{Lustre, LustreConfig, StripeSpec};
use crate::sim::exec::{Sim, WaitGroup};
use crate::util::content::Bytes;

pub fn ablation_ids() -> Vec<&'static str> {
    vec![
        "abl_hash_oid",
        "abl_lustre_dne",
        "abl_pg_count",
        "abl_s3_multipart",
        "abl_wrappers",
        "abl_iodepth",
        "abl_coalesce",
        "abl_recovery",
        "abl_engine",
        "abl_observe",
        "abl_resilience",
        "abl_scrub",
    ]
}

pub fn run_ablation(id: &str, scale: f64) -> Option<Figure> {
    Some(match id {
        "abl_hash_oid" => abl_hash_oid(scale),
        "abl_lustre_dne" => abl_lustre_dne(scale),
        "abl_pg_count" => abl_pg_count(scale),
        "abl_s3_multipart" => abl_s3_multipart(scale),
        "abl_wrappers" => abl_wrappers(scale),
        "abl_iodepth" => abl_iodepth(scale),
        "abl_coalesce" => abl_coalesce(scale),
        "abl_recovery" => abl_recovery(scale),
        "abl_engine" => abl_engine(scale),
        "abl_observe" => abl_observe(scale),
        "abl_resilience" => abl_resilience(scale),
        "abl_scrub" => abl_scrub(scale),
        _ => return None,
    })
}

fn nops(scale: f64, paper: usize) -> usize {
    ((paper as f64 * scale).round() as usize).max(20)
}

/// Measure mean retrieve()+read latency for small fields with and
/// without hash-OIDs.
fn abl_hash_oid(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for hash_oids in [false, true] {
        let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
        let SystemUnderTest::Daos(d) = &dep.system else {
            unreachable!()
        };
        let n = nops(scale, 2000);
        let mk = |node| {
            FdbBuilder::new(&dep.sim)
                .node(node)
                .backend(BackendConfig::Daos {
                    daos: d.clone(),
                    pool: "fdb".to_string(),
                    hash_oids,
                })
                .build()
                .unwrap()
        };
        let nodes = dep.client_nodes();
        let mut w = mk(&nodes[0]);
        dep.sim.spawn(async move {
            for i in 0..n {
                let id = super::hammer::field_id(0, 1 + (i / 100) as u32, (i % 10) as u32, (i % 7) as u32);
                w.archive(&id, Bytes::virt(64 << 10, i as u64)).await.unwrap();
            }
        });
        dep.sim.run();
        let mut r = mk(&nodes[1]);
        let t0 = dep.sim.now();
        dep.sim.spawn(async move {
            for i in 0..n {
                let id = super::hammer::field_id(0, 1 + (i / 100) as u32, (i % 10) as u32, (i % 7) as u32);
                let h = r.retrieve(&id).await.unwrap().expect("present");
                r.read(&h).await.unwrap();
            }
        });
        let end = dep.sim.run();
        let per_op_us = (end - t0).as_secs_f64() * 1e6 / n as f64;
        rows.push(FigRow {
            x: if hash_oids { "hash-OIDs" } else { "KV index" }.to_string(),
            series: "retrieve+read latency".into(),
            value: per_op_us,
            unit: "us/field",
        });
    }
    Figure {
        id: "abl_hash_oid",
        title: "DAOS hash-OID retrieval ablation (thesis §3.1.2 future work)",
        expectation: "hash-OIDs cut the per-retrieve index round trips",
        rows,
        profiles: vec![],
    }
}

/// Metadata-heavy workload (file per field) vs MDS count.
fn abl_lustre_dne(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for mds_count in [1usize, 2, 4] {
        let sim = Sim::new();
        let cluster = Rc::new(crate::hw::profiles::build_cluster(
            Testbed::NextGenIo,
            4,
            8,
            true,
            true,
        ));
        let fs = Lustre::deploy(
            &sim,
            &cluster,
            LustreConfig {
                mds_count,
                ..Default::default()
            },
        );
        let n = nops(scale, 500);
        let spans = super::scenario::new_spans();
        let total = 8 * 8;
        let wg = WaitGroup::new(total);
        for (ni, node) in cluster.client_nodes().enumerate() {
            for p in 0..8 {
                let mut cli = fs.client(node);
                let s = sim.clone();
                let spans = spans.clone();
                let wg = wg.clone();
                let pid = ni * 8 + p;
                sim.spawn(async move {
                    let _ = cli.mkdir("/meta").await;
                    let t0 = s.now();
                    // file per field: create+write+fsync (metadata heavy)
                    for i in 0..n {
                        let path = format!("/meta/f{pid}-{i}");
                        let fd = cli
                            .create(&path, StripeSpec::default_layout())
                            .await
                            .unwrap();
                        cli.write_data(&fd, Bytes::virt(4 << 10, i as u64))
                            .await
                            .unwrap();
                        cli.fdatasync(&fd).await.unwrap();
                    }
                    spans
                        .borrow_mut()
                        .push((t0, s.now(), n as u64 * (4 << 10)));
                    wg.done();
                });
            }
        }
        sim.run();
        // report op rate, the metric DNE moves
        let bw = aggregate_bw(&spans.borrow());
        let ops_per_sec = bw / (4 << 10) as f64;
        rows.push(FigRow {
            x: format!("{mds_count} MDS"),
            series: "file-per-field create rate".into(),
            value: ops_per_sec / 1000.0,
            unit: "kops/s",
        });
    }
    Figure {
        id: "abl_lustre_dne",
        title: "Lustre DNE ablation: MDS count vs metadata throughput",
        expectation: "create rate scales with MDS instances until OST/journal bound",
        rows,
        profiles: vec![],
    }
}

/// RADOS PG-count sensitivity sweep.
fn abl_pg_count(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for pgs in [32usize, 400, 4096] {
        let sim = Sim::new();
        let cluster = Rc::new(crate::hw::profiles::build_cluster(
            Testbed::Gcp,
            4,
            8,
            true,
            true,
        ));
        let ceph = crate::ceph::Ceph::deploy(&sim, &cluster, crate::ceph::CephConfig::default());
        let pool = ceph.create_pool("p", pgs, crate::ceph::Redundancy::None);
        let n = nops(scale, 1000);
        let spans = super::scenario::new_spans();
        let wg = WaitGroup::new(8 * 8);
        for (ni, node) in cluster.client_nodes().enumerate() {
            for p in 0..8 {
                let cli = ceph.client(node);
                let s = sim.clone();
                let pool = pool.clone();
                let spans = spans.clone();
                let wg = wg.clone();
                let pid = ni * 8 + p;
                sim.spawn(async move {
                    let t0 = s.now();
                    for i in 0..n {
                        cli.write_full_data(
                            &pool,
                            "ns",
                            &format!("o{pid}-{i}"),
                            Bytes::virt(1 << 20, i as u64),
                        )
                        .await
                        .unwrap();
                    }
                    spans.borrow_mut().push((t0, s.now(), (n as u64) << 20));
                    wg.done();
                });
            }
        }
        sim.run();
        rows.push(FigRow {
            x: format!("{pgs} PGs"),
            series: "write".into(),
            value: aggregate_bw(&spans.borrow()) / (1u64 << 30) as f64,
            unit: "GiB/s",
        });
    }
    Figure {
        id: "abl_pg_count",
        title: "RADOS PG-count sensitivity (4 OSDs; sweet spot ~400)",
        expectation: "bandwidth peaks near ~100 PGs/OSD and degrades away from it",
        rows,
        profiles: vec![],
    }
}

/// S3 Store: object-per-field vs multipart accumulation.
fn abl_s3_multipart(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for multipart in [false, true] {
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 1, 2, RedundancyOpt::None);
        let server = dep.cluster.storage_nodes().next().unwrap().clone();
        let cnode = dep.client_nodes()[0].clone();
        let s3 = Rc::new(crate::s3::MemS3::new(&dep.sim, &server, &cnode));
        let n = nops(scale, 1000);
        let mut fdb: Fdb = FdbBuilder::new(&dep.sim)
            .backend(BackendConfig::S3 {
                s3: s3.clone(),
                client_tag: "p0".to_string(),
                multipart,
            })
            .build()
            .unwrap();
        let spans = super::scenario::new_spans();
        let spans2 = spans.clone();
        let sim = dep.sim.clone();
        dep.sim.spawn(async move {
            let t0 = sim.now();
            for i in 0..n {
                let id = super::hammer::field_id(0, 1 + (i / 100) as u32, (i % 10) as u32, 0);
                fdb.archive(&id, Bytes::virt(1 << 20, i as u64)).await.unwrap();
            }
            fdb.flush().await.expect("flush");
            spans2.borrow_mut().push((t0, sim.now(), (n as u64) << 20));
        });
        dep.sim.run();
        rows.push(FigRow {
            x: if multipart {
                "multipart-per-collocation"
            } else {
                "PutObject-per-field"
            }
            .to_string(),
            series: "archive+flush".into(),
            value: aggregate_bw(&spans.borrow()) / (1u64 << 30) as f64,
            unit: "GiB/s",
        });
    }
    Figure {
        id: "abl_s3_multipart",
        title: "S3 Store ablation: per-field PUTs vs multipart accumulation",
        expectation: "multipart reduces object count and lifts write throughput",
        rows,
        profiles: vec![],
    }
}

/// Composable wrapper ablation: the same fdb-hammer workload through
/// the bare Lustre backend, a tiered store (POSIX /scm front tier),
/// a 2-way replicated store, and a 4-shard catalogue.
fn abl_wrappers(scale: f64) -> Figure {
    use crate::bench::hammer::{self, HammerConfig};
    use crate::bench::scenario::WrapperOpt;
    let mut rows = Vec::new();
    for wrapper in [
        WrapperOpt::Bare,
        WrapperOpt::Tiered,
        WrapperOpt::Replicated(2),
        WrapperOpt::Sharded(4),
    ] {
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_wrapper(wrapper);
        let cfg = HammerConfig {
            procs_per_node: 2,
            nsteps: nops(scale, 100).min(20) as u32,
            nparams: 2,
            nlevels: 2,
            field_size: 256 << 10,
            check: true,
            contention: false,
            faults_ok: false,
        };
        let (r, _) = hammer::run(&dep, cfg);
        for (series, gibs) in [("write", r.gibs_w()), ("read", r.gibs_r())] {
            rows.push(FigRow {
                x: wrapper.label(),
                series: series.into(),
                value: gibs,
                unit: "GiB/s",
            });
        }
    }
    Figure {
        id: "abl_wrappers",
        title: "Composable backend wrappers vs bare Lustre (fdb-hammer)",
        expectation: "replication pays ~2x on writes; the sharded catalogue \
                      and tiered front change index/write paths, not bytes",
        rows,
        profiles: vec![],
    }
}

/// Queue-depth sweep (`BENCH_iodepth.json`): the fdb-hammer workload's
/// retrieve phase at I/O depth 1→16 on each backend. The Lustre rows
/// run with the POSIX index cache on, so the serial catalogue client
/// does not mask store-side parallelism — the IOR-style queue-depth
/// scaling shape of the DAOS interface papers. Small fields keep the
/// reads latency-bound (where queue depth pays); the write phase rides
/// along as a secondary series.
fn abl_iodepth(scale: f64) -> Figure {
    use crate::bench::hammer::{self, HammerConfig};
    use crate::fdb::IoProfile;
    let mut rows = Vec::new();
    let depths = [1usize, 2, 4, 8, 16];
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Null] {
        for &depth in &depths {
            let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None)
                .with_io(IoProfile::depth(depth).with_preload_indexes(true));
            let cfg = HammerConfig {
                procs_per_node: 1,
                // paper scale = 160 steps; clamp so small scales still
                // exercise the pipeline and large ones stay bounded
                nsteps: ((160.0 * scale).round() as u32).clamp(2, 16),
                nparams: 4,
                nlevels: 4,
                field_size: 64 << 10,
                // byte verification on every depth: results must be
                // identical, only virtual time may change
                check: kind != SystemKind::Null,
                contention: false,
                faults_ok: false,
            };
            let (r, _) = hammer::run(&dep, cfg);
            rows.push(FigRow {
                x: format!("depth {depth}"),
                series: format!("{} read time", kind.label()),
                value: r.read_time.as_secs_f64() * 1e3,
                unit: "ms",
            });
            rows.push(FigRow {
                x: format!("depth {depth}"),
                series: format!("{} read", kind.label()),
                value: r.gibs_r(),
                unit: "GiB/s",
            });
            rows.push(FigRow {
                x: format!("depth {depth}"),
                series: format!("{} write", kind.label()),
                value: r.gibs_w(),
                unit: "GiB/s",
            });
        }
    }
    Figure {
        id: "abl_iodepth",
        title: "I/O-depth engine: fdb-hammer retrieve phase vs queue depth",
        expectation: "depth 8 at least halves the POSIX/Lustre retrieve time; \
                      scaling saturates once the client NIC / OST pipes bind",
        rows,
        profiles: vec![],
    }
}

/// Read-plan coalescing sweep (`BENCH_coalesce.json`): a dense
/// retrieval — fields archived back-to-back by one process — re-read
/// through `retrieve_many` while `coalesce_gap` sweeps 0 → 1 MiB.
/// POSIX/Lustre (per-process data files) and spanned RADOS (fields
/// share spanned objects) genuinely merge; DAOS rides along as the
/// no-merge control (an array per field). Bytes are verified at every
/// gap: only the op count (and virtual time) may change.
fn abl_coalesce(scale: f64) -> Figure {
    use crate::fdb::rados::store::{RadosLayout, RadosStoreConfig};
    use crate::fdb::{IoProfile, Key};
    use crate::util::content::Bytes;
    use std::cell::Cell;

    let gaps: [(u64, &str); 4] = [
        (0, "gap 0"),
        (4 << 10, "gap 4KiB"),
        (64 << 10, "gap 64KiB"),
        (1 << 20, "gap 1MiB"),
    ];
    let field: u64 = 64 << 10;
    let mut rows = Vec::new();
    for kind in [SystemKind::Lustre, SystemKind::Ceph, SystemKind::Daos] {
        for &(gap, label) in &gaps {
            let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
            let io = IoProfile::depth(1)
                .with_preload_indexes(true)
                .with_coalesce_gap(gap);
            let mk = |node: &Rc<crate::hw::node::Node>| -> Fdb {
                let cfg = match &dep.system {
                    // spanned layout: fields share spanned objects, the
                    // RADOS shape ranged reads can merge within
                    SystemUnderTest::Ceph(c, pool) => BackendConfig::Rados {
                        ceph: c.clone(),
                        pool: pool.clone(),
                        store: RadosStoreConfig {
                            layout: RadosLayout::SpannedPerProcess,
                            ..Default::default()
                        },
                    },
                    _ => dep.backend_config(),
                };
                FdbBuilder::new(&dep.sim)
                    .node(node)
                    .backend(cfg)
                    .io(io)
                    .build()
                    .unwrap()
            };
            // one collocation under BOTH stock schemas: only step/param
            // vary, so every field appends to one data file / span chain
            let n = nops(scale, 2000);
            let ids: Vec<Key> = (0..n)
                .map(|i| super::hammer::field_id(0, 1 + (i / 16) as u32, (i % 16) as u32, 0))
                .collect();
            let nodes = dep.client_nodes();
            let mut w = mk(&nodes[0]);
            let batch: Vec<(Key, Bytes)> = ids
                .iter()
                .map(|id| (id.clone(), Bytes::virt(field, super::hammer::field_seed(id))))
                .collect();
            dep.sim.spawn(async move {
                w.archive_many(batch).await.unwrap();
                w.flush().await.unwrap();
                w.close().await.expect("close");
            });
            dep.sim.run();
            let mut r = mk(&nodes[1]);
            let ids2 = ids.clone();
            let merged = Rc::new(Cell::new(0u64));
            let merged2 = merged.clone();
            let t0 = dep.sim.now();
            dep.sim.spawn(async move {
                let fetched = r.retrieve_many(&ids2).await.unwrap();
                assert_eq!(fetched.len(), ids2.len(), "every field found");
                for (id, data) in &fetched {
                    let expect = Bytes::virt(field, super::hammer::field_seed(id));
                    assert!(data.content_eq(&expect), "bytes must match at any gap");
                }
                merged2.set(r.plan_stats().ops_merged);
            });
            let end = dep.sim.run();
            rows.push(FigRow {
                x: label.to_string(),
                series: format!("{} retrieve time", kind.label()),
                value: (end - t0).as_secs_f64() * 1e3,
                unit: "ms",
            });
            rows.push(FigRow {
                x: label.to_string(),
                series: format!("{} ops merged", kind.label()),
                value: merged.get() as f64,
                unit: "ops",
            });
        }
    }
    Figure {
        id: "abl_coalesce",
        title: "Vectored read planner: dense retrieve_many vs coalesce_gap",
        expectation: "gap 64KiB collapses adjacent Lustre/spanned-RADOS fields into \
                      few large ranged reads (<= 2/3 the uncoalesced retrieve time); \
                      DAOS (array per field) cannot merge and stays flat",
        rows,
        profiles: vec![],
    }
}

/// Crash-recovery sweep (`BENCH_recovery.json`): a durable (WAL'd)
/// writer is fail-stopped at a sweep of kill points mid-archive; a
/// fresh instance replays the WAL and a reader byte-verifies. Reported
/// per kill point: WAL intents replayed, recovery virtual time, and
/// fields verified — on bare POSIX and on replicated Lustre (the
/// replica fail-stop path).
fn abl_recovery(scale: f64) -> Figure {
    use super::crash::crash_archive;
    use super::scenario::WrapperOpt;

    let nfields = nops(scale, 480);
    // kill points spread over the archive, endpoints included
    let kills: Vec<u64> = [0.0, 0.25, 0.5, 0.75, 1.0]
        .iter()
        .map(|f| (nfields as f64 * f) as u64)
        .collect();
    let mut rows = Vec::new();
    for (wrapper, series) in [
        (WrapperOpt::Bare, "POSIX"),
        (WrapperOpt::Replicated(2), "replicated-2"),
    ] {
        for &kill in &kills {
            let r = crash_archive(SystemKind::Lustre, wrapper, 42, kill, nfields, 64 << 10);
            assert_eq!(
                r.verified, r.archived,
                "{series} kill@{kill}: recovery must restore every archived field"
            );
            assert_eq!(r.ghosts, 0, "{series} kill@{kill}: torn index entry");
            let x = format!("kill@{kill}");
            rows.push(FigRow {
                x: x.clone(),
                series: format!("{series} replayed"),
                value: r.stats.replayed as f64,
                unit: "fields",
            });
            rows.push(FigRow {
                x: x.clone(),
                series: format!("{series} recovery time"),
                value: r.recovery_ms,
                unit: "ms",
            });
            rows.push(FigRow {
                x,
                series: format!("{series} verified"),
                value: r.verified as f64,
                unit: "fields",
            });
        }
    }
    Figure {
        id: "abl_recovery",
        title: "WAL crash recovery: kill-point sweep over a durable archive",
        expectation: "every kill point recovers exactly the archived prefix \
                      (verified == replayed == kill point), zero ghost entries; \
                      recovery time grows with the replayed WAL length",
        rows,
        profiles: vec![],
    }
}

/// Cross-scenario I/O-engine sweep (`BENCH_engine.json`): the same
/// `--io-depth` knob driven through THREE scenarios — the fdb-hammer
/// batched archive/retrieve, the dense coalesced retrieve (streaming
/// plan execution at depth > 1), and the durable crash-recovery
/// scenario (group-commit WAL, engine-batched verify reads) — all on
/// Lustre. One engine, one semaphore, three workloads: the figure shows
/// queue depth paying (or not) on each, with byte verification and the
/// `inflight <= depth` bound asserted inside every leg.
fn abl_engine(scale: f64) -> Figure {
    use super::crash::crash_archive_with_io;
    use super::hammer::{self, HammerConfig};
    use super::scenario::WrapperOpt;
    use crate::fdb::{IoProfile, Key};
    use crate::util::content::Bytes;
    use std::cell::Cell;

    let field: u64 = 64 << 10;
    let mut rows = Vec::new();
    for depth in [1usize, 4, 8] {
        let x = format!("depth {depth}");

        // leg 1: fdb-hammer — the uncoalesced engine paths (archive
        // fan-out + catalogue-session lookups + per-field reads)
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_io(IoProfile::depth(depth).with_preload_indexes(true));
        let cfg = HammerConfig {
            procs_per_node: 1,
            nsteps: ((160.0 * scale).round() as u32).clamp(2, 16),
            nparams: 4,
            nlevels: 4,
            field_size: field,
            check: true,
            contention: false,
            faults_ok: false,
        };
        let (r, _) = hammer::run(&dep, cfg);
        rows.push(FigRow {
            x: x.clone(),
            series: "hammer read time".into(),
            value: r.read_time.as_secs_f64() * 1e3,
            unit: "ms",
        });
        rows.push(FigRow {
            x: x.clone(),
            series: "hammer write".into(),
            value: r.gibs_w(),
            unit: "GiB/s",
        });

        // leg 2: dense coalesced retrieve — streaming plan execution
        // (resolve overlaps execute) at depth > 1
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
        let io = IoProfile::depth(depth)
            .with_preload_indexes(true)
            .with_coalesce_gap(64 << 10);
        let mk = |node: &Rc<crate::hw::node::Node>| -> Fdb {
            FdbBuilder::new(&dep.sim)
                .node(node)
                .backend(dep.backend_config())
                .io(io)
                .build()
                .unwrap()
        };
        let n = nops(scale, 2000);
        let ids: Vec<Key> = (0..n)
            .map(|i| super::hammer::field_id(0, 1 + (i / 16) as u32, (i % 16) as u32, 0))
            .collect();
        let nodes = dep.client_nodes();
        let mut w = mk(&nodes[0]);
        let batch: Vec<(Key, Bytes)> = ids
            .iter()
            .map(|id| (id.clone(), Bytes::virt(field, super::hammer::field_seed(id))))
            .collect();
        dep.sim.spawn(async move {
            w.archive_many(batch).await.unwrap();
            w.flush().await.unwrap();
            w.close().await.expect("close");
        });
        dep.sim.run();
        let mut rd = mk(&nodes[1]);
        let ids2 = ids.clone();
        let merged = Rc::new(Cell::new(0u64));
        let peak = Rc::new(Cell::new(0usize));
        let (merged2, peak2) = (merged.clone(), peak.clone());
        let t0 = dep.sim.now();
        dep.sim.spawn(async move {
            let fetched = rd.retrieve_many(&ids2).await.unwrap();
            assert_eq!(fetched.len(), ids2.len(), "every field found");
            for (id, data) in &fetched {
                let expect = Bytes::virt(field, super::hammer::field_seed(id));
                assert!(data.content_eq(&expect), "bytes must match at any depth");
            }
            merged2.set(rd.plan_stats().ops_merged);
            peak2.set(rd.io_inflight_peak());
        });
        let end = dep.sim.run();
        assert!(peak.get() <= depth, "in-flight bound: {} > {depth}", peak.get());
        rows.push(FigRow {
            x: x.clone(),
            series: "coalesced retrieve time".into(),
            value: (end - t0).as_secs_f64() * 1e3,
            unit: "ms",
        });
        rows.push(FigRow {
            x: x.clone(),
            series: "coalesced ops merged".into(),
            value: merged.get() as f64,
            unit: "ops",
        });

        // leg 3: crash recovery — durable group-commit WAL under the
        // same depth, verify reads through the engine's batched path
        let nfields = nops(scale, 480).min(64);
        let kill = (nfields / 2) as u64;
        let cr = crash_archive_with_io(
            SystemKind::Lustre,
            WrapperOpt::Bare,
            42,
            kill,
            nfields,
            field,
            IoProfile::depth(depth),
        );
        assert_eq!(
            cr.verified, cr.archived,
            "depth {depth}: recovery must restore every archived field"
        );
        assert_eq!(cr.ghosts, 0, "depth {depth}: torn index entry surfaced");
        rows.push(FigRow {
            x: x.clone(),
            series: "crash verified".into(),
            value: cr.verified as f64,
            unit: "fields",
        });
        rows.push(FigRow {
            x,
            series: "crash recovery time".into(),
            value: cr.recovery_ms,
            unit: "ms",
        });
    }
    Figure {
        id: "abl_engine",
        title: "Unified I/O engine: one depth knob across hammer, coalesced \
                retrieve, and crash recovery",
        expectation: "depth 8 beats depth 1 on the hammer and coalesced legs \
                      (streaming plan execution overlaps resolve with reads); \
                      crash recovery stays byte-exact at every depth",
        rows,
        profiles: vec![],
    }
}

/// One observed run for `abl_observe`: archive + batched retrieve of a
/// dense collocation on Lustre with the telemetry registry attached.
/// `replicated` layers the 2-way replicated store with
/// [`crate::fdb::wrappers::ReadPolicy::Fastest`] (the policy the
/// per-replica read histograms feed); `fault` is an optional `--fault`
/// spec wrapped around the base backend. Returns the run's registry.
fn observe_run(
    scale: f64,
    depth: usize,
    replicated: bool,
    fault: Option<&str>,
) -> crate::fdb::MetricsRegistry {
    use super::scenario::WrapperOpt;
    use crate::fdb::wrappers::ReadPolicy;
    use crate::fdb::{FaultPlan, IoProfile, Key, MetricsRegistry};

    let field: u64 = 64 << 10;
    let reg = MetricsRegistry::new();
    let mut dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_io(IoProfile::depth(depth).with_preload_indexes(true))
        .with_metrics(&reg);
    if replicated {
        dep = dep
            .with_wrapper(WrapperOpt::Replicated(2))
            .with_read_policy(ReadPolicy::Fastest);
    }
    if let Some(spec) = fault {
        dep = dep.with_fault(FaultPlan::parse(spec).expect("fault spec"));
    }
    let n = nops(scale, 2000);
    let ids: Vec<Key> = (0..n)
        .map(|i| super::hammer::field_id(0, 1 + (i / 16) as u32, (i % 16) as u32, 0))
        .collect();
    let nodes = dep.client_nodes();
    let mut w = dep.fdb(&nodes[0]);
    let batch: Vec<(Key, Bytes)> = ids
        .iter()
        .map(|id| (id.clone(), Bytes::virt(field, super::hammer::field_seed(id))))
        .collect();
    dep.sim.spawn(async move {
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
    });
    dep.sim.run();
    let mut r = dep.fdb(&nodes[1]);
    let ids2 = ids.clone();
    dep.sim.spawn(async move {
        let fetched = r.retrieve_many(&ids2).await.unwrap();
        assert_eq!(fetched.len(), ids2.len(), "every field found");
        for (id, data) in &fetched {
            let expect = Bytes::virt(field, super::hammer::field_seed(id));
            assert!(data.content_eq(&expect), "bytes must match when observed");
        }
    });
    dep.sim.run();
    reg
}

/// Telemetry ablation (`BENCH_observe.json`): per-layer attribution vs
/// blended aggregates, and the admission-wait/service split.
///
/// Leg 1 injects a `slow:read` fault into ONE replica of a 2-way
/// replicated Lustre store read under `ReadPolicy::Fastest`. The fault
/// plan's `only=4` clause targets the reader's replica-1 store: fault
/// wrapper instances are numbered in build order and the run builds two
/// FDB instances (writer: store r0 = 0, store r1 = 1, catalogue = 2;
/// reader: 3, 4, 5). Per-replica histograms (`store.r1.posix.read` vs
/// `store.r0.posix.read`) isolate the degraded replica while the
/// top-level blended mean barely moves — EWMA routing sends reads to
/// the healthy replica after the seed probes, which is exactly what
/// aggregate stats hide.
///
/// Leg 2 sweeps `--io-depth` on the bare backend: the admission-wait
/// histogram (`engine.wait.data-read`) shows semaphore queueing — p99
/// wait is largest when the batch saturates the smallest depth — while
/// the service histogram's tail grows with depth as concurrent reads
/// contend for the NIC/OST pipes.
fn abl_observe(scale: f64) -> Figure {
    let p99_us = |reg: &crate::fdb::MetricsRegistry, name: &str| -> f64 {
        reg.hist(name)
            .map(|s| s.percentile(99.0) as f64 / 1e3)
            .unwrap_or(0.0)
    };
    let mean_us = |reg: &crate::fdb::MetricsRegistry, name: &str| -> f64 {
        reg.hist(name).map(|s| s.mean() / 1e3).unwrap_or(0.0)
    };
    let mut rows = Vec::new();

    // leg 1: per-layer isolation of a degraded replica
    for (x, fault) in [
        ("healthy", None),
        ("degraded-r1", Some("seed=42,slow:read:3000,only=4")),
    ] {
        let reg = observe_run(scale, 2, true, fault);
        for (series, name) in [
            ("r0 read p99", "store.r0.posix.read"),
            ("r1 read p99", "store.r1.posix.read"),
        ] {
            rows.push(FigRow {
                x: x.to_string(),
                series: series.into(),
                value: p99_us(&reg, name),
                unit: "us",
            });
        }
        rows.push(FigRow {
            x: x.to_string(),
            series: "blended read mean".into(),
            value: mean_us(&reg, "engine.service.data-read"),
            unit: "us",
        });
    }

    // leg 2: admission wait vs service across queue depths
    for depth in [2usize, 4, 16] {
        let reg = observe_run(scale, depth, false, None);
        let x = format!("depth {depth}");
        rows.push(FigRow {
            x: x.clone(),
            series: "wait p99".into(),
            value: p99_us(&reg, "engine.wait.data-read"),
            unit: "us",
        });
        rows.push(FigRow {
            x: x.clone(),
            series: "service p99".into(),
            value: p99_us(&reg, "engine.service.data-read"),
            unit: "us",
        });
        rows.push(FigRow {
            x,
            series: "inflight peak".into(),
            value: reg.gauge_value("engine.inflight_peak") as f64,
            unit: "ops",
        });
    }
    Figure {
        id: "abl_observe",
        title: "Telemetry: per-layer histograms vs blended aggregates; \
                admission wait vs service",
        expectation: "the slow replica's per-layer read p99 is >= 4x the healthy \
                      replica's while the blended top-level mean moves < 2x; wait \
                      p99 is largest where the batch saturates the smallest depth, \
                      and the service tail grows with depth",
        rows,
        profiles: vec![],
    }
}

/// One run for `abl_resilience`: archive + batched retrieve of `n`
/// fields on replicated:3 Lustre. When `faulted`, the degraded config
/// is hand-built because the deployment's `--fault` plumbing takes ONE
/// plan but this leg needs two independent fault layers: an inner
/// transient read-error storm drawn by EVERY store instance (what the
/// retry policy absorbs) plus an outer fail-stop scoped to one reader
/// replica (what hedging + quarantine route around). Returns the run's
/// registry and the retrieve outcome — `Ok(byte-verified count)` or the
/// caller-visible error.
fn resilience_run(
    n: usize,
    field: u64,
    faulted: bool,
    res: Option<crate::fdb::ResilienceProfile>,
) -> (
    crate::fdb::MetricsRegistry,
    Result<usize, crate::fdb::FdbError>,
) {
    use std::cell::RefCell;

    use crate::fdb::fault::{FaultAction, FaultClass, FaultPlan};
    use crate::fdb::{IoProfile, Key, MetricsRegistry};

    const COPIES: usize = 3;
    let reg = MetricsRegistry::new();
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let mut cfg = dep.backend_config();
    if faulted {
        // inner layer: every store instance draws the storm (each layer
        // keeps its own build counter, so the two plans scope
        // independently). Read-class only — the writer stays clean.
        cfg = BackendConfig::Fault {
            inner: Box::new(cfg),
            plan: FaultPlan::new(97).with_rule(
                FaultClass::Read,
                FaultAction::Err {
                    prob: 0.3,
                    transient: true,
                },
            ),
        };
        // outer layer: fail-stop the reader's replica-1 store. Outer
        // instances number in build order — writer stores 0..=2, writer
        // catalogue 3, reader stores 4..=6 — so `only=5` is reader r1.
        cfg = BackendConfig::Fault {
            inner: Box::new(cfg),
            plan: FaultPlan::new(41)
                .with_rule(FaultClass::Read, FaultAction::FailStop { after: 4 })
                .with_only_instance((COPIES + 1 + 1) as u64),
        };
    }
    let cfg = BackendConfig::Replicated {
        inner: Box::new(cfg),
        copies: COPIES,
    };
    let io = IoProfile::depth(4).with_preload_indexes(true);
    let build = |node: &Rc<crate::hw::node::Node>| {
        let mut b = FdbBuilder::new(&dep.sim)
            .node(node)
            .backend(cfg.clone())
            .io(io)
            .metrics(&reg);
        if let Some(r) = res {
            b = b.resilience(r);
        }
        b.build().expect("hand-built config is valid")
    };
    let ids: Vec<Key> = (0..n)
        .map(|i| super::hammer::field_id(0, 1 + (i / 16) as u32, (i % 16) as u32, 0))
        .collect();
    let nodes = dep.client_nodes();

    let mut w = build(&nodes[0]);
    let batch: Vec<(Key, Bytes)> = ids
        .iter()
        .map(|id| (id.clone(), Bytes::virt(field, super::hammer::field_seed(id))))
        .collect();
    dep.sim.spawn(async move {
        w.archive_many(batch).await.expect("storm is read-class");
        w.flush().await.expect("publish");
        w.close().await.expect("close");
    });
    dep.sim.run();

    let mut r = build(&nodes[1]);
    let out = Rc::new(RefCell::new(None));
    {
        let out = out.clone();
        let ids = ids.clone();
        dep.sim.spawn(async move {
            let got = match r.retrieve_many(&ids).await {
                Ok(fetched) => {
                    let mut verified = 0usize;
                    for (id, data) in &fetched {
                        let expect = Bytes::virt(field, super::hammer::field_seed(id));
                        if data.content_eq(&expect) {
                            verified += 1;
                        }
                    }
                    Ok(verified)
                }
                Err(e) => Err(e),
            };
            *out.borrow_mut() = Some(got);
        });
        dep.sim.run();
    }
    let outcome = out.borrow_mut().take().expect("reader ran");
    (reg, outcome)
}

/// Resilience ablation (`BENCH_resilience.json`): a replicated:3
/// retrieve under a fail-stopped reader replica PLUS a transient
/// read-error storm, with the retry/hedge/quarantine stack on vs off.
///
/// With resilience on the storm is absorbed — zero caller-visible
/// errors, every field byte-identical, and the degraded read p99 stays
/// within 3x the healthy baseline (failed probes are instant; the tail
/// only pays the retry backoff). With resilience off the replicated
/// fall-through alone cannot save a read whose every replica drew a
/// storm error, so the injected fault surfaces to the caller. (A
/// fail-stop ALONE is masked by bare fall-through — see
/// `bench::degrade`'s tests — which is exactly why the off-leg needs
/// the storm to make the contrast visible.)
fn abl_resilience(scale: f64) -> Figure {
    use crate::fdb::{MetricsRegistry, ResilienceProfile};

    let p99_us = |reg: &MetricsRegistry| -> f64 {
        reg.hist("engine.service.data-read")
            .map(|s| s.percentile(99.0) as f64 / 1e3)
            .unwrap_or(0.0)
    };
    let res = ResilienceProfile::retries(6)
        .with_backoff_us(50)
        .with_seed(7)
        .with_hedge_us(300)
        .with_quarantine(2, 2_000);
    let n = nops(scale, 2000);
    let field: u64 = 256 << 10;

    // leg 1: healthy baseline, resilience on
    let (hreg, healthy) = resilience_run(n, field, false, Some(res));
    let healthy_p99 = p99_us(&hreg);
    assert_eq!(
        healthy.expect("healthy leg"),
        n,
        "healthy: every field byte-verified"
    );
    assert!(healthy_p99 > 0.0, "baseline leg must record read latencies");

    // leg 2: replica loss + storm, resilience ON — the acceptance bar
    let (dreg, degraded) = resilience_run(n, field, true, Some(res));
    let degraded_p99 = p99_us(&dreg);
    let verified = degraded.expect("resilient leg: zero caller-visible errors");
    assert_eq!(verified, n, "resilient leg: every field byte-verified");
    assert!(
        degraded_p99 <= 3.0 * healthy_p99,
        "degraded read p99 {degraded_p99:.0}us exceeds 3x healthy p99 {healthy_p99:.0}us"
    );

    // leg 3: same faults, resilience OFF — the errors reach the caller
    let (offreg, off) = resilience_run(n, field, true, None);
    let err = off.expect_err("without resilience the injected errors must surface");
    assert!(
        crate::fdb::telemetry::is_injected_fault(&err),
        "surfaced error must be the injected fault, got: {err}"
    );

    let mut rows = Vec::new();
    for (x, p99, reg, errors) in [
        ("healthy", healthy_p99, &hreg, 0.0),
        ("replica-loss", degraded_p99, &dreg, 0.0),
        ("replica-loss/no-resilience", p99_us(&offreg), &offreg, 1.0),
    ] {
        rows.push(FigRow {
            x: x.to_string(),
            series: "read p99".into(),
            value: p99,
            unit: "us",
        });
        rows.push(FigRow {
            x: x.to_string(),
            series: "caller errors".into(),
            value: errors,
            unit: "errors",
        });
        rows.push(FigRow {
            x: x.to_string(),
            series: "retry attempts".into(),
            value: reg.counter_value("engine.retry.attempts") as f64,
            unit: "ops",
        });
        rows.push(FigRow {
            x: x.to_string(),
            series: "hedges launched".into(),
            value: reg.counter_value("engine.hedge.launched") as f64,
            unit: "ops",
        });
        rows.push(FigRow {
            x: x.to_string(),
            series: "replicas quarantined".into(),
            value: reg.counter_value("replica.quarantine.ejected") as f64,
            unit: "replicas",
        });
    }
    Figure {
        id: "abl_resilience",
        title: "Resilience: retry/hedge/quarantine vs a replica loss plus a \
                transient read-error storm",
        expectation: "with resilience on the degraded retrieve completes with \
                      zero caller-visible errors and read p99 <= 3x the healthy \
                      baseline; with resilience off the same faults surface \
                      injected errors to the caller",
        rows,
        profiles: vec![],
    }
}

/// Integrity scrub ablation: four `scrub_storm` legs covering every
/// seeded damage class (PR acceptance bar). `detect` seeds ghosts,
/// orphans, and p = 1.0 disk rot on the bare backend and asserts fsck
/// finds 100% of each; `repair-bare` asserts the ghost/orphan repairs
/// converge and a second pass is clean; `repair-replicated` rots every
/// primary copy on disk plus transient wire rot and asserts repair
/// heals every copy with ZERO caller-visible corruption; `rot/no-repair`
/// is the contrast leg where the same rot reaches every caller.
fn abl_scrub(scale: f64) -> Figure {
    use super::scrub::{scrub_storm, ScrubConfig, GROUP};
    use crate::fdb::MetricsRegistry;

    // whole collocation groups: one ghost group, one orphan group, and
    // at least one healthy-residue group (seeded counts stay exact)
    let nfields = (nops(scale, 16 * GROUP) / GROUP).max(3) * GROUP;
    let residue = nfields - 2 * GROUP;

    // leg 1: bare backend, all three damage classes, detect only —
    // fsck must find 100% of the seeded damage
    let detect = scrub_storm(
        &ScrubConfig {
            copies: 1,
            nfields,
            write_rot: 1.0,
            ghosts: true,
            orphans: true,
            ..Default::default()
        },
        None,
    );
    assert_eq!(detect.first.ghosts, GROUP as u64, "every ghost entry found");
    assert_eq!(detect.first.orphans, 1, "the orphaned container found");
    assert_eq!(
        detect.first.corrupt,
        residue as u64,
        "every rotten field found"
    );
    assert_eq!(detect.first.repaired, 0, "detect-only must not touch data");
    assert!(detect.passed(false));

    // leg 2: bare backend, ghost + orphan repair — the pass converges
    // and a follow-up detect-only pass is clean
    let bare = scrub_storm(
        &ScrubConfig {
            copies: 1,
            nfields,
            ghosts: true,
            orphans: true,
            repair: true,
            ..Default::default()
        },
        None,
    );
    assert!(bare.first.converged(), "bare repair must converge");
    assert!(
        bare.second.as_ref().is_some_and(|s| s.clean()),
        "second pass must be clean"
    );
    assert_eq!(bare.reads_ok, residue, "the residue reads back verified");
    assert!(bare.passed(true));

    // leg 3: replication 2, every primary copy rotten on disk plus
    // transient wire rot on the reader — repair heals every copy and
    // callers observe zero corruption
    let reg = MetricsRegistry::new();
    let healed = scrub_storm(
        &ScrubConfig {
            copies: 2,
            nfields,
            write_rot: 1.0,
            read_rot: 0.25,
            repair: true,
            ..Default::default()
        },
        Some(&reg),
    );
    assert_eq!(healed.first.corrupt, nfields as u64, "every rotten copy found");
    assert_eq!(
        healed.first.repaired,
        nfields as u64,
        "every rotten copy rewritten from its healthy replica"
    );
    assert!(healed.second.as_ref().is_some_and(|s| s.clean()));
    assert_eq!(
        healed.read_errors, 0,
        "zero caller-visible corruption; first error: {:?}",
        healed.first_error
    );
    assert_eq!(healed.reads_ok, nfields, "every field byte-verified");
    assert!(healed.passed(true));
    assert_eq!(reg.counter_value("integrity.fsck_repaired"), nfields as u64);

    // leg 4: same disk rot, no repair — the contrast: rot reaches the
    // caller as the typed Corrupt error on every read
    let unrepaired = scrub_storm(
        &ScrubConfig {
            copies: 2,
            nfields,
            write_rot: 1.0,
            ..Default::default()
        },
        None,
    );
    assert_eq!(unrepaired.first.repaired, 0);
    assert_eq!(unrepaired.read_errors, nfields, "rot must not read clean");

    let mut rows = Vec::new();
    for (x, r) in [
        ("detect", &detect),
        ("repair-bare", &bare),
        ("repair-replicated", &healed),
        ("rot/no-repair", &unrepaired),
    ] {
        for (series, value) in [
            ("ghosts found", r.first.ghosts as f64),
            ("orphans found", r.first.orphans as f64),
            ("corrupt found", r.first.corrupt as f64),
            ("copies repaired", r.first.repaired as f64),
            ("ghosts dropped", r.first.ghosts_dropped as f64),
            ("orphans quarantined", r.first.orphans_quarantined as f64),
            ("caller errors", (r.read_errors + r.verify_failures) as f64),
            ("reads verified", r.reads_ok as f64),
        ] {
            rows.push(FigRow {
                x: x.to_string(),
                series: series.into(),
                value,
                unit: "fields",
            });
        }
    }
    Figure {
        id: "abl_scrub",
        title: "Online scrub: fsck detection and repair across ghost, orphan, \
                and bit-rot damage",
        expectation: "fsck detects 100% of seeded ghosts/orphans/corruptions; \
                      with --repair the pass converges and a second pass is \
                      clean; with replication >= 2 the repaired dataset reads \
                      back with zero caller-visible corruption",
        rows,
        profiles: vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resilience_absorbs_replica_loss_and_bare_reads_do_not() {
        // the PR's acceptance bar: the three-leg contrast is asserted
        // inside abl_resilience itself (zero caller errors + p99 <= 3x
        // healthy with the stack on; injected errors surface with it
        // off) — the figure must additionally show the machinery
        // actually engaging on the degraded leg
        let f = run_ablation("abl_resilience", 0.05).unwrap();
        assert_eq!(f.value("healthy", "caller errors").unwrap(), 0.0);
        assert_eq!(f.value("replica-loss", "caller errors").unwrap(), 0.0);
        assert!(f.value("replica-loss/no-resilience", "caller errors").unwrap() >= 1.0);
        assert!(
            f.value("replica-loss", "retry attempts").unwrap() >= 1.0,
            "the storm must trigger engine retries"
        );
        assert!(
            f.value("replica-loss", "hedges launched").unwrap() >= 1.0,
            "instant primary failures must launch hedges"
        );
        assert!(
            f.value("replica-loss", "replicas quarantined").unwrap() >= 1.0,
            "the fail-stopped replica must be ejected from the rotation"
        );
        assert_eq!(
            f.value("replica-loss/no-resilience", "retry attempts").unwrap(),
            0.0,
            "the off leg must not retry"
        );
    }

    #[test]
    fn scrub_detects_everything_and_repair_reads_back_clean() {
        // the PR's acceptance bar: exact-count detection, repair
        // convergence, and zero caller-visible corruption are asserted
        // inside abl_scrub itself — the figure must additionally show
        // the contrast between the repaired and unrepaired legs
        let f = run_ablation("abl_scrub", 0.05).unwrap();
        // 0.05 scale → 3 groups of 16: 16 ghosts, 1 orphan container,
        // 16 rotten residue fields on the detect leg
        assert_eq!(f.value("detect", "ghosts found").unwrap(), 16.0);
        assert_eq!(f.value("detect", "orphans found").unwrap(), 1.0);
        assert_eq!(f.value("detect", "corrupt found").unwrap(), 16.0);
        assert_eq!(f.value("detect", "copies repaired").unwrap(), 0.0);
        assert_eq!(f.value("repair-bare", "ghosts dropped").unwrap(), 16.0);
        assert_eq!(f.value("repair-bare", "orphans quarantined").unwrap(), 1.0);
        assert_eq!(f.value("repair-bare", "caller errors").unwrap(), 0.0);
        // all 48 primary copies rotten: repaired leg heals every one and
        // readers see nothing; the no-repair leg surfaces every one
        assert_eq!(f.value("repair-replicated", "corrupt found").unwrap(), 48.0);
        assert_eq!(f.value("repair-replicated", "copies repaired").unwrap(), 48.0);
        assert_eq!(f.value("repair-replicated", "caller errors").unwrap(), 0.0);
        assert_eq!(f.value("repair-replicated", "reads verified").unwrap(), 48.0);
        assert_eq!(f.value("rot/no-repair", "caller errors").unwrap(), 48.0);
        assert_eq!(f.value("rot/no-repair", "reads verified").unwrap(), 0.0);
    }

    #[test]
    fn observe_isolates_the_slow_replica_and_splits_wait_from_service() {
        // the PR's acceptance bar: per-layer histograms find what the
        // blended aggregate hides, and admission wait is measured apart
        // from service time
        let f = run_ablation("abl_observe", 0.05).unwrap();
        let r0 = f.value("degraded-r1", "r0 read p99").unwrap();
        let r1 = f.value("degraded-r1", "r1 read p99").unwrap();
        assert!(
            r1 >= 4.0 * r0,
            "slow replica p99 ({r1:.0} us) must be >= 4x the healthy replica's ({r0:.0} us)"
        );
        let healthy = f.value("healthy", "blended read mean").unwrap();
        let degraded = f.value("degraded-r1", "blended read mean").unwrap();
        assert!(
            degraded < 2.0 * healthy,
            "blended mean must hide the slow replica: {degraded:.0} us vs healthy {healthy:.0} us"
        );
        // semaphore queueing is visible in the wait histogram: largest
        // where the batch saturates the smallest depth
        let w2 = f.value("depth 2", "wait p99").unwrap();
        let w16 = f.value("depth 16", "wait p99").unwrap();
        assert!(
            w2 > w16,
            "wait p99 at depth 2 ({w2:.0} us) must exceed depth 16 ({w16:.0} us)"
        );
        // while the service tail grows with depth (backend contention)
        let s2 = f.value("depth 2", "service p99").unwrap();
        let s16 = f.value("depth 16", "service p99").unwrap();
        assert!(
            s16 >= s2,
            "service p99 must grow with depth: {s16:.0} us at 16 vs {s2:.0} us at 2"
        );
        assert!(f.value("depth 16", "inflight peak").unwrap() > f.value("depth 2", "inflight peak").unwrap());
    }

    #[test]
    fn hash_oid_ablation_improves_latency() {
        let f = run_ablation("abl_hash_oid", 0.05).unwrap();
        let kv = f.value("KV index", "retrieve+read latency").unwrap();
        let hashed = f.value("hash-OIDs", "retrieve+read latency").unwrap();
        assert!(
            hashed < kv,
            "hash-OID retrieve {hashed}us should beat KV-index {kv}us"
        );
    }

    #[test]
    fn dne_scales_metadata_rate() {
        let f = run_ablation("abl_lustre_dne", 0.1).unwrap();
        let m1 = f.value("1 MDS", "file-per-field create rate").unwrap();
        let m4 = f.value("4 MDS", "file-per-field create rate").unwrap();
        assert!(m4 > m1, "DNE: 4 MDS rate {m4} should beat 1 MDS {m1}");
    }

    #[test]
    fn pg_count_sweet_spot() {
        let f = run_ablation("abl_pg_count", 0.05).unwrap();
        let low = f.value("32 PGs", "write").unwrap();
        let mid = f.value("400 PGs", "write").unwrap();
        let high = f.value("4096 PGs", "write").unwrap();
        assert!(mid >= low && mid >= high, "sweet spot: {low} {mid} {high}");
    }

    #[test]
    fn s3_multipart_roundtrip_and_speedup() {
        let f = run_ablation("abl_s3_multipart", 0.05).unwrap();
        let put = f.value("PutObject-per-field", "archive+flush").unwrap();
        let mp = f
            .value("multipart-per-collocation", "archive+flush")
            .unwrap();
        assert!(mp > 0.0 && put > 0.0);
    }

    #[test]
    fn unknown_ablation_is_none() {
        assert!(run_ablation("abl_nope", 1.0).is_none());
    }

    #[test]
    fn recovery_sweep_replays_exactly_the_kill_prefix() {
        // the PR's acceptance bar, figure-level: at every kill point the
        // WAL replay restores exactly the archived prefix on both the
        // bare and the replicated deployment (byte checks + zero-ghost
        // assertions run inside the ablation itself)
        let f = run_ablation("abl_recovery", 0.05).unwrap();
        // 0.05 scale → 24 fields, kill points at 0/6/12/18/24
        for kill in [0u64, 6, 12, 18, 24] {
            let x = format!("kill@{kill}");
            for series in ["POSIX", "replicated-2"] {
                let replayed = f.value(&x, &format!("{series} replayed")).unwrap();
                let verified = f.value(&x, &format!("{series} verified")).unwrap();
                assert_eq!(replayed, kill as f64, "{series} {x} replayed");
                assert_eq!(verified, kill as f64, "{series} {x} verified");
            }
        }
        // a longer WAL takes at least as long to recover as an empty one
        let t0 = f.value("kill@0", "POSIX recovery time").unwrap();
        let t24 = f.value("kill@24", "POSIX recovery time").unwrap();
        assert!(t24 >= t0, "recovery time must grow with WAL length");
    }

    #[test]
    fn iodepth_depth8_halves_posix_retrieve_time() {
        // the PR's acceptance bar: depth 8 completes the POSIX/Lustre-sim
        // retrieve phase in <= 1/2 the virtual time of depth 1, with the
        // hammer byte-verification on at every depth (identical results)
        let f = run_ablation("abl_iodepth", 0.05).unwrap();
        let t1 = f.value("depth 1", "Lustre read time").unwrap();
        let t8 = f.value("depth 8", "Lustre read time").unwrap();
        assert!(
            t8 <= 0.5 * t1,
            "depth-8 retrieve ({t8:.2} ms) should be <= half of depth-1 ({t1:.2} ms)"
        );
        // monotone-ish scaling: depth 16 must not regress past depth 1
        let t16 = f.value("depth 16", "Lustre read time").unwrap();
        assert!(t16 <= t1, "depth-16 ({t16:.2} ms) regressed past depth-1 ({t1:.2} ms)");
        // every backend produced non-degenerate sweeps
        for series in ["Lustre read", "DAOS read", "Null read"] {
            for depth in [1, 2, 4, 8, 16] {
                let v = f.value(&format!("depth {depth}"), series).unwrap();
                assert!(v >= 0.0, "{series} at depth {depth}: {v}");
            }
        }
    }

    #[test]
    fn coalesce_gap64k_meets_the_two_thirds_bar() {
        // the PR's acceptance bar: on the dense-retrieval scenario,
        // coalesce_gap = 64KiB completes the Lustre retrieve_many in at
        // most 2/3 of the uncoalesced virtual time (bytes verified at
        // every gap inside the ablation itself)
        let f = run_ablation("abl_coalesce", 0.05).unwrap();
        let t0 = f.value("gap 0", "Lustre retrieve time").unwrap();
        let t64 = f.value("gap 64KiB", "Lustre retrieve time").unwrap();
        assert!(
            t64 <= (2.0 / 3.0) * t0,
            "coalesced retrieve ({t64:.2} ms) should be <= 2/3 of uncoalesced ({t0:.2} ms)"
        );
        // the planner genuinely merged on the mergeable backends...
        assert!(f.value("gap 64KiB", "Lustre ops merged").unwrap() > 0.0);
        assert!(f.value("gap 64KiB", "Ceph ops merged").unwrap() > 0.0);
        // ...and could not on the array-per-field control
        assert_eq!(f.value("gap 64KiB", "DAOS ops merged").unwrap(), 0.0);
        // gap 0 is the planner-off baseline everywhere
        for s in ["Lustre ops merged", "Ceph ops merged", "DAOS ops merged"] {
            assert_eq!(f.value("gap 0", s).unwrap(), 0.0, "{s}");
        }
    }

    #[test]
    fn engine_sweep_pays_at_depth_and_recovers_exactly() {
        // one engine, three scenarios: depth 8 must not lose to depth 1
        // on either read leg, and the crash leg's internal assertions
        // (byte-exact recovery, zero ghosts, inflight <= depth) ran at
        // every depth just by the figure completing
        let f = run_ablation("abl_engine", 0.05).unwrap();
        let h1 = f.value("depth 1", "hammer read time").unwrap();
        let h8 = f.value("depth 8", "hammer read time").unwrap();
        assert!(
            h8 <= h1,
            "depth-8 hammer read ({h8:.2} ms) regressed past depth-1 ({h1:.2} ms)"
        );
        let c1 = f.value("depth 1", "coalesced retrieve time").unwrap();
        let c8 = f.value("depth 8", "coalesced retrieve time").unwrap();
        assert!(
            c8 <= c1,
            "depth-8 coalesced retrieve ({c8:.2} ms) regressed past depth-1 ({c1:.2} ms)"
        );
        // the streaming planner merged at every depth on the dense layout
        for depth in [1, 4, 8] {
            let x = format!("depth {depth}");
            assert!(f.value(&x, "coalesced ops merged").unwrap() > 0.0, "{x}");
            // 0.05 scale → 24 crash fields, kill at 12
            assert_eq!(f.value(&x, "crash verified").unwrap(), 12.0, "{x}");
        }
    }

    #[test]
    fn wrapper_ablation_runs_all_variants() {
        let f = run_ablation("abl_wrappers", 0.05).unwrap();
        for x in ["bare", "tiered", "replicated-2", "sharded-4"] {
            let w = f.value(x, "write").unwrap();
            let r = f.value(x, "read").unwrap();
            assert!(w > 0.0 && r > 0.0, "{x}: write {w} read {r}");
        }
        // replication writes every byte twice — it cannot beat bare
        let bare = f.value("bare", "write").unwrap();
        let rep = f.value("replicated-2", "write").unwrap();
        assert!(
            rep <= bare * 1.05,
            "2-way replication write {rep} should not beat bare {bare}"
        );
    }
}
