//! `scrub_storm`: the end-to-end integrity scenario behind `fdbctl
//! fsck` and `abl_scrub`. One deployment archives a dataset while
//! seeded damage lands in all three classes fsck exists to find:
//!
//! * **corruption** — a `corrupt:write` fault plan scoped to the
//!   writer's replica-0 store rots primary copies *on disk* (the
//!   catalogue checksum is computed before the store sees the payload,
//!   so the rot is detectable); an optional `corrupt:read` plan scoped
//!   to one reader replica adds transient wire rot on top.
//! * **ghosts** — one collocation's container is quarantined behind the
//!   catalogue's back, leaving every entry of that collocation pointing
//!   at nothing.
//! * **orphans** — another collocation's entries are forgotten while
//!   its container stays on disk.
//!
//! The scenario then runs `Fdb::fsck` (optionally `--repair` plus a
//! detect-only convergence pass) on the *writer* instance — the one
//! whose replicated store learned the secondary-copy locations at
//! archive time — and finally a fresh reader retrieves every surviving
//! field through the verified read path, byte-checking each one.

use std::cell::RefCell;
use std::rc::Rc;

use super::scenario::{deploy, RedundancyOpt, SystemKind};
use crate::fdb::fault::{FaultAction, FaultClass, FaultPlan};
use crate::fdb::scrub::FsckReport;
use crate::fdb::{BackendConfig, Fdb, FdbBuilder, Key, MetricsRegistry, Request};
use crate::hw::profiles::Testbed;
use crate::util::content::Bytes;

/// Fields per collocation (the ghost/orphan seeding granularity: one
/// collocation = one per-process data file on the POSIX backend).
pub const GROUP: usize = 16;

/// One integrity-storm configuration. Ghost/orphan seeding needs the
/// bare (copies = 1) POSIX-family backend — container granularity and
/// the store inventory only exist there; repair-from-replica needs
/// `copies >= 2`.
#[derive(Clone, Copy, Debug)]
pub struct ScrubConfig {
    pub kind: SystemKind,
    /// replica count; 1 = bare backend (no replication wrapper)
    pub copies: usize,
    pub seed: u64,
    /// total fields archived, spread over `nfields / GROUP` collocations
    pub nfields: usize,
    pub field_size: u64,
    /// `corrupt:write` probability on the writer's replica-0 store
    /// (persistent disk rot on primary copies)
    pub write_rot: f64,
    /// `corrupt:read` probability on the reader's replica-0 store
    /// (transient wire rot, absorbed by verified-read failover)
    pub read_rot: f64,
    /// quarantine collocation 0's container behind the catalogue's back
    pub ghosts: bool,
    /// forget collocation 1's entries, leaving its container on disk
    pub orphans: bool,
    /// run fsck in repair mode, then a detect-only convergence pass
    pub repair: bool,
}

impl Default for ScrubConfig {
    fn default() -> ScrubConfig {
        ScrubConfig {
            kind: SystemKind::Lustre,
            copies: 2,
            seed: 42,
            nfields: 3 * GROUP,
            field_size: 64 << 10,
            write_rot: 0.0,
            read_rot: 0.0,
            ghosts: false,
            orphans: false,
            repair: false,
        }
    }
}

/// What one storm observed.
#[derive(Clone, Debug, Default)]
pub struct ScrubReport {
    /// fields archived
    pub fields: usize,
    /// ghost entries seeded (`GROUP` when the ghost leg ran)
    pub seeded_ghosts: u64,
    /// orphan containers seeded (1 when the orphan leg ran)
    pub seeded_orphans: u64,
    /// the first fsck pass (repair mode when `cfg.repair`)
    pub first: FsckReport,
    /// the detect-only convergence pass (repair runs only)
    pub second: Option<FsckReport>,
    /// reader-leg fields returned AND byte-verified
    pub reads_ok: usize,
    /// reader-leg fields that surfaced a caller-visible error
    pub read_errors: usize,
    /// reader-leg fields returned with wrong bytes, or absent
    pub verify_failures: usize,
    /// first caller-visible reader error, when any surfaced
    pub first_error: Option<String>,
}

impl ScrubReport {
    /// The storm's acceptance bar: every seeded problem detected, and —
    /// on repair runs — the pass converged, the follow-up pass is
    /// clean, and the reader saw zero caller-visible damage.
    pub fn passed(&self, repaired: bool) -> bool {
        let detected = self.first.ghosts >= self.seeded_ghosts
            && self.first.orphans >= self.seeded_orphans;
        if !repaired {
            return detected;
        }
        detected
            && self.first.converged()
            && self.second.as_ref().is_some_and(|s| s.clean())
            && self.read_errors == 0
            && self.verify_failures == 0
    }
}

/// The identifier of field `i` in collocation group `g`: the stock
/// POSIX schema collocates on `type,levtype`, so a per-group `levtype`
/// value gives each group its own collocation (its own data file).
fn scrub_id(g: usize, i: usize) -> Key {
    super::hammer::field_id(0, 1 + i as u32, 0, 0).with("levtype", format!("l{g}"))
}

/// Run the storm. `metrics` (when given) receives the deployment's
/// registry, so `integrity.*` counters are inspectable afterwards.
pub fn scrub_storm(cfg: &ScrubConfig, metrics: Option<&MetricsRegistry>) -> ScrubReport {
    assert!(cfg.copies >= 1, "scrub_storm needs at least one copy");
    assert!(
        !(cfg.ghosts || cfg.orphans) || cfg.copies == 1,
        "ghost/orphan seeding is container-granular: bare backend only"
    );
    assert!(
        cfg.nfields >= 3 * GROUP,
        "the storm needs a ghost group, an orphan group, and a healthy residue"
    );
    let dep = deploy(Testbed::Gcp, cfg.kind, 2, 2, RedundancyOpt::None);
    let mut bcfg = dep.backend_config();
    if cfg.write_rot > 0.0 {
        // inner fault layer: persistent disk rot on the writer's
        // replica-0 store (instance 0 of this layer — writer stores
        // build before the writer catalogue and the reader)
        bcfg = BackendConfig::Fault {
            inner: Box::new(bcfg),
            plan: FaultPlan::new(cfg.seed)
                .with_rule(FaultClass::Write, FaultAction::Corrupt { prob: cfg.write_rot })
                .with_only_instance(0),
        };
    }
    if cfg.read_rot > 0.0 {
        // outer fault layer (its own instance counter): transient wire
        // rot on the reader's replica-0 store. Build order — writer
        // stores 0..copies-1, writer catalogue `copies`, reader
        // replica 0 = `copies + 1`.
        bcfg = BackendConfig::Fault {
            inner: Box::new(bcfg),
            plan: FaultPlan::new(cfg.seed.wrapping_add(0x5c12_ab5c))
                .with_rule(FaultClass::Read, FaultAction::Corrupt { prob: cfg.read_rot })
                .with_only_instance((cfg.copies + 1) as u64),
        };
    }
    if cfg.copies >= 2 {
        bcfg = BackendConfig::Replicated {
            inner: Box::new(bcfg),
            copies: cfg.copies,
        };
    }
    let own;
    let reg = match metrics {
        Some(r) => r,
        None => {
            own = MetricsRegistry::new();
            &own
        }
    };
    let build = |node: &Rc<crate::hw::node::Node>| -> Fdb {
        FdbBuilder::new(&dep.sim)
            .node(node)
            .backend(bcfg.clone())
            .metrics(reg)
            .build()
            .expect("hand-built config is valid")
    };
    let ids: Vec<Key> = (0..cfg.nfields)
        .map(|i| scrub_id(i / GROUP, i % GROUP))
        .collect();
    let nodes = dep.client_nodes();

    // phase 1 — the writer: archive everything (write rot lands here),
    // seed ghost/orphan damage, then scrub. fsck MUST run on this
    // instance: its replicated store learned the secondary-copy
    // locations at archive time, which is what repair rewrites from.
    let mut writer = build(&nodes[0]);
    let out = Rc::new(RefCell::new(ScrubReport {
        fields: cfg.nfields,
        seeded_ghosts: if cfg.ghosts { GROUP as u64 } else { 0 },
        seeded_orphans: if cfg.orphans { 1 } else { 0 },
        ..Default::default()
    }));
    {
        let out = out.clone();
        let ids = ids.clone();
        let cfg = *cfg;
        dep.sim.spawn(async move {
            for id in &ids {
                let data = Bytes::virt(cfg.field_size, super::hammer::field_seed(id));
                writer.archive(id, data).await.expect("archive");
            }
            writer.flush().await.expect("publish");
            writer.close().await.expect("close");
            let ds = ids[0]
                .project(&writer.schema.dataset.clone())
                .expect("dataset key");
            if cfg.ghosts {
                // group 0's container disappears; its entries stay
                let entries = writer.list(&ds, &Request::default()).await;
                let container = entries
                    .iter()
                    .find(|(id, _)| id == &ids[0])
                    .map(|(_, loc)| loc.container_uri())
                    .expect("victim entry listed");
                let (store, _) = writer.backend_mut();
                let gone = store
                    .quarantine_object(&ds, &container)
                    .await
                    .expect("quarantine the ghost container");
                assert!(gone, "ghost seeding needs a quarantine-capable store");
            }
            if cfg.orphans {
                // group 1's entries disappear; its container stays
                for id in &ids[GROUP..2 * GROUP] {
                    let (_, colloc, elem) = writer.schema.split(id).expect("schema");
                    let (_, cat) = writer.backend_mut();
                    cat.forget(&ds, &colloc, &elem, id)
                        .await
                        .expect("forget the orphan group's entries");
                }
                let (_, cat) = writer.backend_mut();
                cat.flush().await.expect("persist tombstones");
            }
            writer.invalidate_preload(&ds);
            let first = writer.fsck(&ds, cfg.repair).await.expect("fsck");
            let second = if cfg.repair {
                Some(writer.fsck(&ds, false).await.expect("fsck convergence pass"))
            } else {
                None
            };
            let mut o = out.borrow_mut();
            o.first = first;
            o.second = second;
        });
        dep.sim.run();
    }

    // phase 2 — a fresh reader retrieves every field expected to
    // survive, through the verified read path (reader-side wire rot is
    // live here; with copies >= 2 failover must absorb it).
    let mut reader = build(&nodes[1]);
    {
        let out = out.clone();
        let expected: Vec<Key> = ids
            .iter()
            .enumerate()
            .filter(|(i, _)| {
                !(cfg.ghosts && i / GROUP == 0) && !(cfg.orphans && i / GROUP == 1)
            })
            .map(|(_, id)| id.clone())
            .collect();
        let field_size = cfg.field_size;
        dep.sim.spawn(async move {
            for id in &expected {
                let one = std::slice::from_ref(id);
                let fetched = reader.retrieve_many(one).await;
                let mut o = out.borrow_mut();
                match fetched {
                    Ok(found) => match found.into_iter().next() {
                        Some((_, data)) => {
                            let expect =
                                Bytes::virt(field_size, super::hammer::field_seed(id));
                            if data.content_eq(&expect) {
                                o.reads_ok += 1;
                            } else {
                                o.verify_failures += 1;
                            }
                        }
                        None => o.verify_failures += 1,
                    },
                    Err(e) => {
                        o.read_errors += 1;
                        if o.first_error.is_none() {
                            o.first_error = Some(e.to_string());
                        }
                    }
                }
            }
        });
        dep.sim.run();
    }
    out.borrow().clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_only_finds_every_seeded_problem_class() {
        // bare POSIX, all three damage classes at once, no repair:
        // p = 1.0 write rot makes the corruption count exact (every
        // entry of the healthy-residue group), and the ghost/orphan
        // groups are seeded with known sizes
        let cfg = ScrubConfig {
            copies: 1,
            write_rot: 1.0,
            ghosts: true,
            orphans: true,
            ..Default::default()
        };
        let r = scrub_storm(&cfg, None);
        assert_eq!(r.first.entries, 2 * GROUP as u64, "orphan group delisted");
        assert_eq!(r.first.ghosts, GROUP as u64, "every ghost entry found");
        assert_eq!(r.first.orphans, 1, "the orphaned container found");
        assert_eq!(
            r.first.corrupt,
            GROUP as u64,
            "every rotten residue field found"
        );
        assert_eq!(r.first.repaired, 0, "detect-only must not touch data");
        assert!(r.passed(false));
        // and the rot is caller-visible on the bare backend: every
        // residue read fails its checksum with no replica to fall to
        assert_eq!(r.read_errors, GROUP, "disk rot must not read clean");
    }

    #[test]
    fn repair_drops_ghosts_and_quarantines_orphans_to_convergence() {
        let cfg = ScrubConfig {
            copies: 1,
            ghosts: true,
            orphans: true,
            repair: true,
            ..Default::default()
        };
        let r = scrub_storm(&cfg, None);
        assert_eq!(r.first.ghosts_dropped, GROUP as u64);
        assert_eq!(r.first.orphans_quarantined, 1);
        assert!(r.first.converged(), "repair must converge: {}", r.first);
        let second = r.second.as_ref().expect("convergence pass ran");
        assert!(second.clean(), "second pass must be clean: {second}");
        assert_eq!(second.entries, GROUP as u64, "only the residue remains");
        assert_eq!(r.reads_ok, GROUP, "the residue reads back verified");
        assert!(r.passed(true));
    }

    #[test]
    fn replicated_repair_heals_disk_rot_and_masks_wire_rot() {
        // the PR's acceptance bar: every primary copy rotten on disk
        // (p = 1.0), transient wire rot on the reader's replica 0 —
        // with replication >= 2 and --repair, fsck heals every copy,
        // the convergence pass is clean, and the reader observes ZERO
        // caller-visible corruption
        let reg = MetricsRegistry::new();
        let cfg = ScrubConfig {
            copies: 2,
            write_rot: 1.0,
            read_rot: 0.25,
            repair: true,
            ..Default::default()
        };
        let r = scrub_storm(&cfg, Some(&reg));
        assert_eq!(
            r.first.corrupt, r.fields as u64,
            "every rotten primary copy found"
        );
        assert_eq!(
            r.first.repaired, r.fields as u64,
            "every rotten copy rewritten from its healthy replica"
        );
        assert!(r.first.converged());
        assert!(r.second.as_ref().expect("convergence pass").clean());
        assert_eq!(r.read_errors, 0, "first error: {:?}", r.first_error);
        assert_eq!(r.verify_failures, 0);
        assert_eq!(r.reads_ok, r.fields, "every field byte-verified");
        assert!(r.passed(true));
        assert_eq!(
            reg.counter_value("integrity.fsck_repaired"),
            r.fields as u64
        );
    }

    #[test]
    fn unrepaired_disk_rot_surfaces_to_readers() {
        // contrast leg: same rot, no repair — the primary copy is the
        // one every replica reads, so the corruption reaches callers as
        // the typed error (this is what a non-zero fsck exit guards)
        let cfg = ScrubConfig {
            copies: 2,
            write_rot: 1.0,
            ..Default::default()
        };
        let r = scrub_storm(&cfg, None);
        assert_eq!(r.first.corrupt, r.fields as u64);
        assert_eq!(r.first.repaired, 0);
        assert_eq!(r.read_errors, r.fields, "rot must not read clean");
        assert_eq!(r.reads_ok, 0);
    }
}
