//! fdb-hammer (thesis §2.7.2 / §3.1.4): the NWP I/O benchmark over the
//! full FDB API. Writers archive `nsteps × nparams × nlevels` fields with
//! a flush per step and a close at the end; readers issue the equivalent
//! retrieve() + data-read sequences. Contention mode runs writers and
//! readers concurrently against pre-populated data (the operational
//! write+read pattern). The consistency check verifies every field is
//! found and its bytes match what was archived.

use std::rc::Rc;

use super::scenario::{new_spans, Deployment};
use super::{aggregate_bw, BwResult};
use crate::fdb::{Fdb, Key};
use crate::sim::exec::{Sim, WaitGroup};
use crate::sim::trace::Trace;
use crate::util::content::Bytes;

#[derive(Clone, Copy, Debug)]
pub struct HammerConfig {
    pub procs_per_node: usize,
    pub nsteps: u32,
    pub nparams: u32,
    pub nlevels: u32,
    pub field_size: u64,
    /// verify read bytes match archived bytes (seed check)
    pub check: bool,
    /// run writers and readers concurrently (write+read contention);
    /// readers hit the dataset pre-populated by a prior write phase
    pub contention: bool,
    /// tolerate injected backend faults: ops that fail with a typed
    /// error are skipped (and excluded from the bandwidth accounting)
    /// instead of aborting the run — set when a fault plan is active
    pub faults_ok: bool,
}

impl Default for HammerConfig {
    fn default() -> Self {
        HammerConfig {
            procs_per_node: 16,
            nsteps: 10,
            nparams: 4,
            nlevels: 4,
            field_size: 1 << 20,
            check: true,
            contention: false,
            faults_ok: false,
        }
    }
}

impl HammerConfig {
    pub fn fields_per_proc(&self) -> u64 {
        self.nsteps as u64 * self.nparams as u64 * self.nlevels as u64
    }
}

/// The identifier a (member, step, param, level) tuple maps to. A writer
/// node archives fields for a single ensemble member (thesis §2.7.2).
pub fn field_id(member: usize, step: u32, param: u32, level: u32) -> Key {
    Key::of(&[
        ("class", "od"),
        ("expver", "0001"),
        ("stream", "oper"),
        ("date", "20231201"),
        ("time", "1200"),
        ("type", "ef"),
        ("levtype", "pl"),
    ])
    .with("number", member.to_string())
    .with("step", step.to_string())
    .with("param", format!("p{param}"))
    .with("levelist", level.to_string())
}

/// Deterministic per-field payload seed (verification anchor).
pub fn field_seed(id: &Key) -> u64 {
    crate::ceph::hash_name(&id.canonical())
}

fn make_fdb(dep: &Deployment, node: &Rc<crate::hw::node::Node>, trace: &Trace) -> Fdb {
    dep.fdb_traced(node, trace)
}

/// The step's identifiers for one (member, proc) writer/reader.
fn step_ids(member: usize, proc: usize, step: u32, cfg: &HammerConfig) -> Vec<Key> {
    let mut ids = Vec::with_capacity((cfg.nparams * cfg.nlevels) as usize);
    // levels are partitioned over a node's processes so identifiers are
    // process-unique, like the real fdb-hammer
    for param in 0..cfg.nparams {
        for level in 0..cfg.nlevels {
            ids.push(field_id(member, step, param, level * 1000 + proc as u32));
        }
    }
    ids
}

async fn writer(
    mut fdb: Fdb,
    sim: Sim,
    member: usize,
    proc: usize,
    cfg: HammerConfig,
    spans: super::scenario::Spans,
    wg: Rc<WaitGroup>,
) {
    let t0 = sim.now();
    let mut wrote = 0u64;
    // one archive_many batch per step — the batched small-object path
    for step in 1..=cfg.nsteps {
        let batch: Vec<(Key, Bytes)> = step_ids(member, proc, step, &cfg)
            .into_iter()
            .map(|id| {
                let data = Bytes::virt(cfg.field_size, field_seed(&id));
                (id, data)
            })
            .collect();
        let n = batch.len() as u64;
        match fdb.archive_many(batch).await {
            Ok(()) => wrote += n,
            Err(e) => assert!(cfg.faults_ok, "archive_many: {e}"),
        }
        if let Err(e) = fdb.flush().await {
            assert!(cfg.faults_ok, "flush: {e}");
        }
    }
    if let Err(e) = fdb.close().await {
        assert!(cfg.faults_ok, "close: {e}");
    }
    let bytes = wrote * cfg.field_size;
    spans.borrow_mut().push((t0, sim.now(), bytes));
    wg.done();
}

async fn reader(
    mut fdb: Fdb,
    sim: Sim,
    member: usize,
    proc: usize,
    cfg: HammerConfig,
    spans: super::scenario::Spans,
    wg: Rc<WaitGroup>,
) {
    let t0 = sim.now();
    let mut missing = 0u64;
    let mut read = 0u64;
    // batched retrieve per step: catalogue lookups pipeline with reads
    for step in 1..=cfg.nsteps {
        let ids = step_ids(member, proc, step, &cfg);
        match fdb.retrieve_many(&ids).await {
            Ok(fetched) => {
                missing += (ids.len() - fetched.len()) as u64;
                read += fetched.len() as u64;
                if cfg.check {
                    for (id, data) in &fetched {
                        let expect = Bytes::virt(cfg.field_size, field_seed(id));
                        assert!(
                            data.content_eq(&expect),
                            "consistency check failed for {id}"
                        );
                    }
                }
            }
            Err(e) => {
                assert!(cfg.faults_ok, "retrieve_many: {e}");
                missing += ids.len() as u64;
            }
        }
    }
    assert!(
        missing == 0 || cfg.faults_ok,
        "reader found {missing} missing fields"
    );
    let bytes = read * cfg.field_size;
    spans.borrow_mut().push((t0, sim.now(), bytes));
    wg.done();
}

/// Separate write phase then read phase (no write+read contention), or —
/// with `cfg.contention` — a pre-populate phase followed by concurrent
/// writers (fresh dataset date) + readers (pre-populated dataset).
pub fn run(dep: &Deployment, cfg: HammerConfig) -> (BwResult, Trace) {
    let clients = dep.client_nodes();
    assert!(
        clients.len() >= 2 || !cfg.contention,
        "contention mode needs >= 2 client nodes (half write, half read)"
    );
    let trace = Trace::new();
    let mut result = BwResult::default();

    if !cfg.contention {
        // ---- write phase
        let spans = new_spans();
        let wg = WaitGroup::new(clients.len() * cfg.procs_per_node);
        for (ni, node) in clients.iter().enumerate() {
            for p in 0..cfg.procs_per_node {
                let fdb = make_fdb(dep, node, &trace);
                dep.sim.spawn(writer(
                    fdb,
                    dep.sim.clone(),
                    ni,
                    p,
                    cfg,
                    spans.clone(),
                    wg.clone(),
                ));
            }
        }
        let t = dep.sim.run();
        result.write_bw = aggregate_bw(&spans.borrow());
        result.write_time = t;
        // ---- read phase
        let spans = new_spans();
        let wg = WaitGroup::new(clients.len() * cfg.procs_per_node);
        let t0 = dep.sim.now();
        for (ni, node) in clients.iter().enumerate() {
            for p in 0..cfg.procs_per_node {
                let fdb = make_fdb(dep, node, &trace);
                dep.sim.spawn(reader(
                    fdb,
                    dep.sim.clone(),
                    ni,
                    p,
                    cfg,
                    spans.clone(),
                    wg.clone(),
                ));
            }
        }
        let t = dep.sim.run();
        result.read_bw = aggregate_bw(&spans.borrow());
        result.read_time = t - t0;
        let _ = wg;
    } else {
        // ---- pre-populate for the readers (unmeasured)
        let spans = new_spans();
        let _wg = {
            let wg = WaitGroup::new((clients.len() / 2) * cfg.procs_per_node);
            for (ni, node) in clients.iter().take(clients.len() / 2).enumerate() {
                for p in 0..cfg.procs_per_node {
                    let fdb = make_fdb(dep, node, &trace);
                    dep.sim.spawn(writer(
                        fdb,
                        dep.sim.clone(),
                        ni,
                        p,
                        cfg,
                        spans.clone(),
                        wg.clone(),
                    ));
                }
            }
            wg
        };
        dep.sim.run();
        // ---- concurrent writers (other member range) + readers
        let wspans = new_spans();
        let rspans = new_spans();
        let half = clients.len() / 2;
        let wg = WaitGroup::new(clients.len() * cfg.procs_per_node);
        let t0 = dep.sim.now();
        for (ni, node) in clients.iter().enumerate() {
            for p in 0..cfg.procs_per_node {
                let fdb = make_fdb(dep, node, &trace);
                if ni < half {
                    // writers: a disjoint member range (fresh fields)
                    dep.sim.spawn(writer(
                        fdb,
                        dep.sim.clone(),
                        1000 + ni,
                        p,
                        cfg,
                        wspans.clone(),
                        wg.clone(),
                    ));
                } else {
                    // readers: the pre-populated members
                    dep.sim.spawn(reader(
                        fdb,
                        dep.sim.clone(),
                        ni - half,
                        p,
                        cfg,
                        rspans.clone(),
                        wg.clone(),
                    ));
                }
            }
        }
        let t = dep.sim.run();
        result.write_bw = aggregate_bw(&wspans.borrow());
        result.read_bw = aggregate_bw(&rspans.borrow());
        result.write_time = t - t0;
        result.read_time = t - t0;
    }
    (result, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::scenario::{deploy, RedundancyOpt, SystemKind, WrapperOpt};
    use crate::hw::profiles::Testbed;

    fn small_cfg() -> HammerConfig {
        HammerConfig {
            procs_per_node: 2,
            nsteps: 3,
            nparams: 2,
            nlevels: 2,
            field_size: 256 << 10,
            check: true,
            contention: false,
            faults_ok: false,
        }
    }

    #[test]
    fn hammer_consistency_on_all_systems() {
        for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
            let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
            let (r, _) = run(&dep, small_cfg());
            assert!(r.write_bw > 0.0, "{kind:?}");
            assert!(r.read_bw > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn hammer_consistency_through_wrappers() {
        // the full fdb-hammer workload (byte verification on) through
        // every composable wrapper over a Lustre deployment
        for wrapper in [
            WrapperOpt::Tiered,
            WrapperOpt::Replicated(2),
            WrapperOpt::Sharded(4),
        ] {
            let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
                .with_wrapper(wrapper);
            let (r, _) = run(&dep, small_cfg());
            assert!(r.write_bw > 0.0, "{wrapper:?}");
            assert!(r.read_bw > 0.0, "{wrapper:?}");
        }
    }

    #[test]
    fn hammer_null_backend_with_shared_catalogue() {
        // readers are separate FDB instances: they only find the
        // writers' fields because the Null deployment shares one index
        let dep = deploy(Testbed::Gcp, SystemKind::Null, 1, 2, RedundancyOpt::None);
        let mut cfg = small_cfg();
        cfg.check = false; // the zero-cost store returns virtual zeros
        let (_, trace) = run(&dep, cfg);
        use crate::sim::trace::OpClass;
        // the reader asserted zero missing fields inside run(); the
        // trace proves the batched paths executed
        assert!(trace.count(OpClass::IndexRead) > 0);
    }

    #[test]
    fn hammer_contention_mode() {
        for kind in [SystemKind::Lustre, SystemKind::Daos] {
            let dep = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
            let mut cfg = small_cfg();
            cfg.contention = true;
            let (r, _) = run(&dep, cfg);
            assert!(r.write_bw > 0.0 && r.read_bw > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn daos_suffers_less_contention_penalty_than_lustre() {
        // The thesis' headline shape (Figs 4.13/4.22): write+read
        // contention costs Lustre a larger fraction of its read bandwidth
        // than DAOS. At tiny volumes Lustre's client cache hides writes
        // (also per thesis §2.5) — so this runs at writeback scale:
        // per-proc volume exceeds the dirty budget.
        let run_kind = |kind, contention| {
            let dep = deploy(Testbed::NextGenIo, kind, 2, 4, RedundancyOpt::None);
            let cfg = HammerConfig {
                procs_per_node: 4,
                nsteps: 5,
                nparams: 6,
                nlevels: 10,
                field_size: 1 << 20, // 300 MiB per proc > 256 MiB budget
                check: false,
                contention,
                faults_ok: false,
            };
            run(&dep, cfg).0
        };
        let lustre = run_kind(SystemKind::Lustre, true);
        let daos = run_kind(SystemKind::Daos, true);
        // Fig 4.13 shape: DAOS reads stay well ahead of Lustre when
        // writers run concurrently (PSM2 + MVCC + byte-addressable reads
        // vs kernel path + page-cache writeback bursts).
        assert!(
            daos.read_bw > 1.15 * lustre.read_bw,
            "contended DAOS read {:.2} GiB/s should beat Lustre {:.2} GiB/s",
            daos.gibs_r(),
            lustre.gibs_r()
        );
        // hammer-on-POSIX does NOT reproduce the operational data-file
        // lock ping-pong (thesis §2.7.2); the workflow driver tests that.
    }

    #[test]
    fn trace_collects_op_classes() {
        let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
        let (_, trace) = run(&dep, small_cfg());
        use crate::sim::trace::OpClass;
        assert!(trace.total(OpClass::DataWrite) > crate::sim::time::SimTime::ZERO);
        assert!(trace.total(OpClass::IndexWrite) > crate::sim::time::SimTime::ZERO);
        assert!(trace.total(OpClass::DataRead) > crate::sim::time::SimTime::ZERO);
    }
}
