//! Benchmark suite: IOR-like generic I/O, the Field I/O proof-of-concept,
//! and fdb-hammer (thesis §4.1.1), plus the scenario registry that
//! regenerates every evaluation table and figure.

pub mod ablations;
pub mod crash;
pub mod degrade;
pub mod fieldio;
pub mod figures;
pub mod hammer;
pub mod ior;
pub mod scenario;
pub mod scrub;

use crate::sim::time::SimTime;

/// A measured bandwidth pair (aggregate, bytes/sec).
#[derive(Clone, Copy, Debug, Default)]
pub struct BwResult {
    pub write_bw: f64,
    pub read_bw: f64,
    pub write_time: SimTime,
    pub read_time: SimTime,
}

impl BwResult {
    pub fn gibs_w(&self) -> f64 {
        self.write_bw / (1u64 << 30) as f64
    }
    pub fn gibs_r(&self) -> f64 {
        self.read_bw / (1u64 << 30) as f64
    }
}

/// Aggregate bandwidth from per-process (start, end, bytes) spans:
/// total bytes / (max end − min start) — the thesis' preferred metric
/// (§4.1.5, Fig 4.1: includes straggler effects).
pub fn aggregate_bw(spans: &[(SimTime, SimTime, u64)]) -> f64 {
    if spans.is_empty() {
        return 0.0;
    }
    let start = spans.iter().map(|s| s.0).min().unwrap();
    let end = spans.iter().map(|s| s.1).max().unwrap();
    let bytes: u64 = spans.iter().map(|s| s.2).sum();
    let dur = (end - start).as_secs_f64();
    if dur <= 0.0 {
        0.0
    } else {
        bytes as f64 / dur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_includes_stragglers() {
        let spans = vec![
            (SimTime::ZERO, SimTime::secs(1), 1 << 30),
            (SimTime::ZERO, SimTime::secs(2), 1 << 30), // straggler
        ];
        let bw = aggregate_bw(&spans);
        assert!((bw - (1u64 << 30) as f64).abs() < 1.0);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(aggregate_bw(&[]), 0.0);
    }
}
