//! `degraded_read`: the replica-loss scenario behind `fdbctl degrade`
//! and `abl_resilience`. A replicated deployment archives a batch of
//! fields, then a reader runs a retrieve storm while one of *its*
//! replica stores is fail-stopped mid-storm (a seeded `only=` fault
//! scoped to that single built instance). The scenario reports the
//! degraded-read tail latency against a healthy baseline of the same
//! deployment, plus the resilience counters (hedges launched, retries,
//! quarantine ejections) that show *how* the loss was absorbed.

use std::cell::RefCell;
use std::rc::Rc;

use super::scenario::{deploy, RedundancyOpt, SystemKind, WrapperOpt};
use crate::fdb::fault::{FaultAction, FaultClass, FaultPlan};
use crate::fdb::wrappers::ReadPolicy;
use crate::fdb::{IoProfile, MetricsRegistry, ResilienceProfile};
use crate::hw::profiles::Testbed;
use crate::util::content::Bytes;

/// Retrieve-storm passes over the full field set. Fixed so the victim
/// replica keeps taking read traffic well past its kill point — the
/// quarantine/backoff lifecycle needs repeat visits to exercise.
const ROUNDS: usize = 4;

/// What one replica-loss run observed. The latency and counter fields
/// come from the *degraded* leg; `healthy_p99_us` is the same workload
/// on the same deployment with no fault injected.
#[derive(Clone, Debug, Default)]
pub struct DegradeReport {
    /// fields archived and retrieved each round
    pub fields: usize,
    /// retrieve-storm passes completed
    pub rounds: usize,
    /// fields returned AND byte-verified across all rounds
    pub reads_ok: usize,
    /// retrieve rounds that surfaced a caller-visible error
    pub read_errors: usize,
    /// fields returned with wrong bytes, or published fields missing
    pub verify_failures: usize,
    /// healthy-baseline data-read p99 (`engine.service.data-read`), µs
    pub healthy_p99_us: f64,
    /// degraded-leg data-read p99, µs
    pub degraded_p99_us: f64,
    /// degraded leg: `engine.retry.attempts`
    pub retries: u64,
    /// degraded leg: `engine.hedge.launched`
    pub hedges: u64,
    /// degraded leg: `replica.quarantine.ejected`
    pub quarantined: u64,
    /// first caller-visible error, when any surfaced
    pub first_error: Option<String>,
}

#[derive(Clone, Default)]
struct LegStats {
    rounds: usize,
    reads_ok: usize,
    read_errors: usize,
    verify_failures: usize,
    first_error: Option<String>,
}

fn p99_us(reg: &MetricsRegistry) -> f64 {
    reg.hist("engine.service.data-read")
        .map(|h| h.percentile(99.0) as f64 / 1e3)
        .unwrap_or(0.0)
}

/// One leg: archive `nfields`, publish, then `ROUNDS` retrieve-storm
/// passes on a second node. `fault` (if any) is scoped by its `only=`
/// clause to a single reader-side replica instance, so the writer is
/// always healthy and every field is durably published before the
/// storm begins.
#[allow(clippy::too_many_arguments)]
fn run_leg(
    kind: SystemKind,
    copies: usize,
    fault: Option<FaultPlan>,
    nfields: usize,
    field_size: u64,
    io: IoProfile,
    res: ResilienceProfile,
    reg: &MetricsRegistry,
) -> LegStats {
    let mut dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None)
        .with_wrapper(WrapperOpt::Replicated(copies))
        .with_io(io)
        .with_read_policy(ReadPolicy::RoundRobin)
        .with_resilience(res)
        .with_metrics(reg);
    if let Some(plan) = fault {
        dep = dep.with_fault(plan);
    }
    let nodes = dep.client_nodes();
    let ids: Vec<_> = (0..nfields)
        .map(|i| super::hammer::field_id(0, 1 + (i / 16) as u32, (i % 16) as u32, 0))
        .collect();

    // phase 1: a healthy writer archives and publishes every field.
    // NOTE: built BEFORE the reader — fault `only=` instance numbering
    // (used by [`degraded_read`]) counts on this build order.
    let mut writer = dep.fdb(&nodes[0]);
    {
        let ids = ids.clone();
        dep.sim.spawn(async move {
            for id in &ids {
                let data = Bytes::virt(field_size, super::hammer::field_seed(id));
                writer.archive(id, data).await.expect("writer is fault-free");
            }
            writer.flush().await.expect("publish");
            writer.close().await.expect("close");
        });
        dep.sim.run();
    }

    // phase 2: the retrieve storm. The victim replica dies partway in;
    // each round byte-verifies everything that comes back.
    let mut reader = dep.fdb(&nodes[1]);
    let out = Rc::new(RefCell::new(LegStats::default()));
    {
        let out = out.clone();
        let ids = ids.clone();
        dep.sim.spawn(async move {
            for _ in 0..ROUNDS {
                match reader.retrieve_many(&ids).await {
                    Ok(found) => {
                        let mut o = out.borrow_mut();
                        let mut returned = 0usize;
                        for (id, data) in found {
                            let expect =
                                Bytes::virt(field_size, super::hammer::field_seed(&id));
                            if data.content_eq(&expect) {
                                o.reads_ok += 1;
                            } else {
                                o.verify_failures += 1;
                            }
                            returned += 1;
                        }
                        // every field was published before the storm:
                        // an absent field is a caller-visible failure
                        o.verify_failures += ids.len() - returned;
                    }
                    Err(e) => {
                        let mut o = out.borrow_mut();
                        o.read_errors += 1;
                        if o.first_error.is_none() {
                            o.first_error = Some(e.to_string());
                        }
                    }
                }
                out.borrow_mut().rounds += 1;
            }
        });
        dep.sim.run();
    }
    let stats = out.borrow().clone();
    stats
}

/// Run the replica-loss scenario: a healthy baseline leg, then the same
/// workload with reader replica 1 (replica 0 when `copies == 1`)
/// fail-stopped after `kill_after` reads. Both legs run under the same
/// [`ResilienceProfile`]; `metrics` (when given) receives the degraded
/// leg's registry so `--metrics-json` exports the interesting run.
///
/// Fault instance numbering: the fault wrapper sits INSIDE the
/// replication wrapper, so each built replica advances the plan's
/// shared build counter. The writer instance builds `copies` stores
/// plus one catalogue (instances `0..=copies`); the reader's replica
/// `v` is therefore instance `(copies + 1) + v`.
#[allow(clippy::too_many_arguments)]
pub fn degraded_read(
    kind: SystemKind,
    copies: usize,
    seed: u64,
    kill_after: u64,
    nfields: usize,
    field_size: u64,
    io: IoProfile,
    res: ResilienceProfile,
    metrics: Option<&MetricsRegistry>,
) -> DegradeReport {
    assert!(copies >= 1, "degrade needs a replicated deployment");
    let healthy_reg = MetricsRegistry::new();
    run_leg(kind, copies, None, nfields, field_size, io, res, &healthy_reg);

    let own;
    let reg = match metrics {
        Some(r) => r,
        None => {
            own = MetricsRegistry::new();
            &own
        }
    };
    let victim = 1usize.min(copies - 1);
    let plan = FaultPlan::new(seed)
        .with_rule(FaultClass::Read, FaultAction::FailStop { after: kill_after })
        .with_only_instance(((copies + 1) + victim) as u64);
    let degraded = run_leg(kind, copies, Some(plan), nfields, field_size, io, res, reg);

    DegradeReport {
        fields: nfields,
        rounds: degraded.rounds,
        reads_ok: degraded.reads_ok,
        read_errors: degraded.read_errors,
        verify_failures: degraded.verify_failures,
        healthy_p99_us: p99_us(&healthy_reg),
        degraded_p99_us: p99_us(reg),
        retries: reg.counter_value("engine.retry.attempts"),
        hedges: reg.counter_value("engine.hedge.launched"),
        quarantined: reg.counter_value("replica.quarantine.ejected"),
        first_error: degraded.first_error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_loss_is_absorbed_with_zero_caller_errors() {
        // the PR's acceptance bar: replicated:3 under a mid-storm
        // replica fail-stop completes every read byte-identical, and
        // the degraded tail stays within 3x of the healthy baseline
        let res = ResilienceProfile::retries(3)
            .with_hedge_us(400)
            .with_quarantine(2, 5_000);
        let r = degraded_read(
            SystemKind::Lustre,
            3,
            11,
            4,
            24,
            4096,
            IoProfile::default(),
            res,
            None,
        );
        assert_eq!(r.read_errors, 0, "resilient reads must mask the dead replica");
        assert_eq!(r.verify_failures, 0);
        assert_eq!(r.reads_ok, 24 * ROUNDS, "every field, every round");
        assert!(r.healthy_p99_us > 0.0, "baseline leg must record latencies");
        assert!(
            r.degraded_p99_us <= 3.0 * r.healthy_p99_us,
            "degraded p99 {}us exceeds 3x healthy p99 {}us",
            r.degraded_p99_us,
            r.healthy_p99_us
        );
        assert!(
            r.hedges >= 1,
            "a dead primary in the rotation must launch hedges"
        );
        assert!(
            r.quarantined >= 1,
            "repeat failures must eject the dead replica"
        );
    }

    #[test]
    fn bare_fallthrough_masks_the_loss_without_resilience() {
        // with every resilience knob off, replica fall-through alone
        // still hides a single fail-stopped replica — the layer buys
        // tail-latency control and observability, not bare availability
        // (which is why abl_resilience's off-leg adds a transient error
        // storm to make the contrast visible)
        let r = degraded_read(
            SystemKind::Lustre,
            3,
            11,
            4,
            16,
            2048,
            IoProfile::default(),
            ResilienceProfile::default(),
            None,
        );
        assert_eq!(r.read_errors, 0);
        assert_eq!(r.verify_failures, 0);
        assert_eq!(r.reads_ok, 16 * ROUNDS);
        assert_eq!(r.retries, 0);
        assert_eq!(r.hedges, 0);
        assert_eq!(r.quarantined, 0);
    }
}
