//! `crash_archive`: the crash-recovery scenario behind `abl_recovery`
//! and the CI durability smoke. A durable (WAL'd) writer archives fields
//! until a seeded fail-stop fault kills it mid-archive; the scenario
//! then reopens the dataset in a fresh FDB instance, replays the dead
//! writer's WAL, and byte-verifies that the recovered index agrees with
//! the data: every field archived before the kill is retrievable with
//! its exact payload, and nothing past the kill point ever surfaces
//! (no torn index).

use std::cell::RefCell;
use std::rc::Rc;

use super::scenario::{deploy, RedundancyOpt, SystemKind, WrapperOpt};
use crate::fdb::fault::{FaultAction, FaultClass, FaultPlan, RecoveryStats};
use crate::fdb::{IoProfile, MetricsRegistry, ResilienceProfile};
use crate::hw::profiles::Testbed;
use crate::util::content::Bytes;

/// What one crash-recovery run observed.
#[derive(Clone, Copy, Debug, Default)]
pub struct CrashReport {
    /// fields the writer archived successfully before the fault
    pub archived: usize,
    /// fields the writer attempted in total
    pub attempted: usize,
    /// WAL replay counters from [`crate::fdb::fdb::Fdb::recover`]
    pub stats: RecoveryStats,
    /// virtual time of recover + publish (flush/close), milliseconds
    pub recovery_ms: f64,
    /// fields found AND byte-verified after recovery
    pub verified: usize,
    /// fields past the kill point that wrongly surfaced post-recovery
    pub ghosts: usize,
}

/// Run one seeded crash: a durable writer on `kind` (optionally under a
/// wrapper — `WrapperOpt::Replicated(n)` exercises the replica failure
/// paths) is fail-stopped after `kill_after` store writes, then a fresh
/// instance recovers and a reader verifies. `nfields` fields of
/// `field_size` bytes are attempted.
pub fn crash_archive(
    kind: SystemKind,
    wrapper: WrapperOpt,
    seed: u64,
    kill_after: u64,
    nfields: usize,
    field_size: u64,
) -> CrashReport {
    crash_archive_with_io(
        kind,
        wrapper,
        seed,
        kill_after,
        nfields,
        field_size,
        IoProfile::default().with_durable(true),
    )
}

/// [`crash_archive`] under an explicit [`IoProfile`] (durability is
/// forced on — a non-durable crash scenario has nothing to recover).
/// The doomed writer uses single-field `archive` so the seeded
/// kill point stays op-exact at any depth, but the verify phase reads
/// through `retrieve_many` — the engine's batched path — so crash
/// recovery is exercised at depth (the `abl_engine` crash leg).
pub fn crash_archive_with_io(
    kind: SystemKind,
    wrapper: WrapperOpt,
    seed: u64,
    kill_after: u64,
    nfields: usize,
    field_size: u64,
    io: IoProfile,
) -> CrashReport {
    crash_archive_observed(kind, wrapper, seed, kill_after, nfields, field_size, io, None, None)
}

/// [`crash_archive_with_io`] with an optional telemetry registry
/// attached to both the doomed writer and the recovering instance, so
/// a run records the WAL-sync counters, the `recovery.*` replay
/// counters, and the injected-fault outcome counts alongside the
/// latency histograms (the `crash --metrics` path). `res` layers a
/// retry/deadline/hedge policy under the scenario (the fail-stop is a
/// permanent fault, so retries never mask the kill itself).
#[allow(clippy::too_many_arguments)]
pub fn crash_archive_observed(
    kind: SystemKind,
    wrapper: WrapperOpt,
    seed: u64,
    kill_after: u64,
    nfields: usize,
    field_size: u64,
    io: IoProfile,
    res: Option<ResilienceProfile>,
    metrics: Option<&MetricsRegistry>,
) -> CrashReport {
    let plan = FaultPlan::new(seed).with_rule(
        FaultClass::Write,
        FaultAction::FailStop { after: kill_after },
    );
    let io = io.with_durable(true);
    let mut dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None)
        .with_wrapper(wrapper)
        .with_io(io)
        .with_fault(plan);
    if let Some(r) = res {
        dep = dep.with_resilience(r);
    }
    if let Some(reg) = metrics {
        dep = dep.with_metrics(reg);
    }
    let nodes = dep.client_nodes();
    let ids: Vec<_> = (0..nfields)
        .map(|i| super::hammer::field_id(0, 1 + (i / 16) as u32, (i % 16) as u32, 0))
        .collect();

    // phase 1: the doomed writer. First archive error = the crash; the
    // instance is dropped on the spot — no flush, no close — exactly
    // like a killed producer process.
    let mut writer = dep.fdb(&nodes[0]);
    let archived = Rc::new(RefCell::new(0usize));
    {
        let ids = ids.clone();
        let archived = archived.clone();
        dep.sim.spawn(async move {
            for (i, id) in ids.iter().enumerate() {
                let data = Bytes::virt(field_size, super::hammer::field_seed(id));
                if writer.archive(id, data).await.is_err() {
                    break;
                }
                *archived.borrow_mut() = i + 1;
            }
            drop(writer); // crash: in-memory index state dies here
        });
        dep.sim.run();
    }
    let archived = *archived.borrow();

    // phase 2: recovery in a fresh, fault-free instance of the same
    // deployment (the crashed node stays dead; a healthy one recovers)
    dep.fault = None;
    let mut recoverer = dep.fdb(&nodes[1]);
    let ds = ids[0]
        .project(&recoverer.schema.dataset.clone())
        .expect("dataset key");
    let report = Rc::new(RefCell::new(CrashReport {
        archived,
        attempted: nfields,
        ..CrashReport::default()
    }));
    {
        let report = report.clone();
        let ds = ds.clone();
        let ids = ids.clone();
        let sim = dep.sim.clone();
        dep.sim.spawn(async move {
            let t0 = sim.now();
            let stats = recoverer.recover(&ds).await.expect("recover");
            recoverer.flush().await.expect("publish recovered index");
            recoverer.close().await.expect("close recovered index");
            let recovery_ms = (sim.now() - t0).as_secs_f64() * 1e3;
            // phase 3: verify — reuse the recoverer's client read-side
            // (its preload was invalidated by recover + flush). The
            // batched retrieve runs at the profile's configured depth, so
            // recovered indexes are read back through the engine paths.
            recoverer.invalidate_preload(&ds);
            let mut verified = 0usize;
            let mut ghosts = 0usize;
            let found = recoverer.retrieve_many(&ids).await.expect("retrieve_many");
            // found pairs come back in input order with absent fields
            // skipped: walk ids with a cursor to recover each pair's
            // input index
            let mut cursor = 0usize;
            for (id, data) in found {
                while ids[cursor] != id {
                    cursor += 1;
                }
                if cursor < archived {
                    let expect = Bytes::virt(field_size, super::hammer::field_seed(&id));
                    if data.content_eq(&expect) {
                        verified += 1;
                    }
                } else {
                    ghosts += 1;
                }
                cursor += 1;
            }
            let mut r = report.borrow_mut();
            r.stats = stats;
            r.recovery_ms = recovery_ms;
            r.verified = verified;
            r.ghosts = ghosts;
        });
        dep.sim.run();
    }
    let report = *report.borrow();
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovery_restores_exactly_the_archived_fields() {
        // the PR's acceptance bar: kill at every seeded fault point of a
        // small archive; after reopen + WAL replay the catalogue agrees
        // with the data — every pre-kill field byte-verified, zero torn
        // (ghost) entries past the kill point
        for kill_after in [0u64, 1, 5, 12, 23] {
            let r = crash_archive(SystemKind::Lustre, WrapperOpt::Bare, 42, kill_after, 24, 4096);
            assert_eq!(
                r.archived,
                kill_after.min(24) as usize,
                "fail-stop after {kill_after} writes"
            );
            assert_eq!(
                r.verified, r.archived,
                "kill@{kill_after}: every archived field must recover byte-identical"
            );
            assert_eq!(r.ghosts, 0, "kill@{kill_after}: torn index entry surfaced");
            assert_eq!(r.stats.replayed, r.archived, "kill@{kill_after}: WAL replay count");
        }
    }

    #[test]
    fn recovery_under_replication_survives_replica_failstop() {
        // replicated Lustre: each replica draws its own fault stream;
        // the count-based fail-stop still kills the archive at the same
        // op, and recovery must behave exactly like the bare case
        let r = crash_archive(
            SystemKind::Lustre,
            WrapperOpt::Replicated(2),
            7,
            9,
            16,
            4096,
        );
        assert_eq!(r.archived, 9);
        assert_eq!(r.verified, 9);
        assert_eq!(r.ghosts, 0);
    }

    #[test]
    fn corrupt_replay_targets_are_gated_out_of_recovery() {
        // the recover() integrity gate, driven by a torn-write + corrupt
        // schedule: every payload rots on disk as it lands (the WAL
        // intent checksums are computed before the store sees the
        // bytes), and the 4th data write is torn — which errors before
        // its intent is logged, killing the writer. Recovery must read
        // each replay target back, fail its checksum, count it
        // `data_corrupt`, and index nothing: corrupt data must never
        // become visible through a recovered catalogue.
        let plan = FaultPlan::new(5)
            .with_rule(FaultClass::Write, FaultAction::Corrupt { prob: 1.0 })
            .with_rule(FaultClass::Write, FaultAction::Torn { nth: 3 });
        let mut dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_io(IoProfile::default().with_durable(true))
            .with_fault(plan);
        let nodes = dep.client_nodes();
        let ids: Vec<_> = (0..6)
            .map(|i| super::super::hammer::field_id(0, 1 + i as u32, 0, 0))
            .collect();
        let mut w = dep.fdb(&nodes[0]);
        let archived = Rc::new(RefCell::new(0usize));
        {
            let ids = ids.clone();
            let archived = archived.clone();
            dep.sim.spawn(async move {
                for id in &ids {
                    let data = Bytes::virt(2048, super::super::hammer::field_seed(id));
                    if w.archive(id, data).await.is_err() {
                        break;
                    }
                    *archived.borrow_mut() += 1;
                }
                drop(w); // dies on the torn write, WAL unflushed
            });
            dep.sim.run();
        }
        assert_eq!(*archived.borrow(), 3, "the torn 4th write kills the writer");
        dep.fault = None;
        let mut rec = dep.fdb(&nodes[1]);
        let ds = ids[0].project(&rec.schema.dataset.clone()).unwrap();
        let out = Rc::new(RefCell::new((RecoveryStats::default(), 0usize)));
        {
            let out = out.clone();
            let ids = ids.clone();
            dep.sim.spawn(async move {
                let stats = rec.recover(&ds).await.expect("recover");
                rec.flush().await.expect("flush");
                rec.invalidate_preload(&ds);
                let mut found = 0;
                for id in &ids {
                    if rec.retrieve(id).await.expect("retrieve").is_some() {
                        found += 1;
                    }
                }
                *out.borrow_mut() = (stats, found);
            });
            dep.sim.run();
        }
        let (stats, found) = *out.borrow();
        assert_eq!(stats.wal_files, 1, "the dead writer's WAL was scanned");
        assert_eq!(stats.data_corrupt, 3, "every rotten replay target gated");
        assert_eq!(stats.replayed, 0, "corrupt data must never be indexed");
        assert_eq!(stats.data_missing, 0, "torn write logged no intent");
        assert_eq!(found, 0, "no corrupt field surfaces post-recovery");
    }

    #[test]
    fn committed_intents_are_not_replayed() {
        // a writer that flushed before dying: the flush's commit
        // watermark means recovery replays nothing, yet all fields stay
        // visible through the published sub-TOC
        use crate::fdb::fault::{FaultAction, FaultClass, FaultPlan};
        use crate::fdb::IoProfile;
        use crate::util::content::Bytes;
        use std::cell::RefCell;
        use std::rc::Rc;

        let plan = FaultPlan::new(3)
            .with_rule(FaultClass::Write, FaultAction::FailStop { after: 8 });
        let mut dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_io(IoProfile::default().with_durable(true))
            .with_fault(plan);
        let nodes = dep.client_nodes();
        let ids: Vec<_> = (0..8)
            .map(|i| super::super::hammer::field_id(0, 1 + i as u32, 0, 0))
            .collect();
        let mut w = dep.fdb(&nodes[0]);
        {
            let ids = ids.clone();
            dep.sim.spawn(async move {
                for id in &ids {
                    let data = Bytes::virt(1024, super::super::hammer::field_seed(id));
                    w.archive(id, data).await.expect("within budget");
                }
                w.flush().await.expect("flush commits the WAL");
                drop(w); // dies after the flush, before close
            });
            dep.sim.run();
        }
        dep.fault = None;
        let mut rec = dep.fdb(&nodes[1]);
        let ds = ids[0].project(&rec.schema.dataset.clone()).unwrap();
        let replayed = Rc::new(RefCell::new((0usize, 0usize, 0usize)));
        {
            let out = replayed.clone();
            let ids = ids.clone();
            dep.sim.spawn(async move {
                let stats = rec.recover(&ds).await.expect("recover");
                rec.flush().await.expect("flush");
                rec.invalidate_preload(&ds);
                let mut found = 0;
                for id in &ids {
                    if rec.retrieve(id).await.expect("retrieve").is_some() {
                        found += 1;
                    }
                }
                *out.borrow_mut() = (stats.replayed, stats.committed, found);
            });
            dep.sim.run();
        }
        let (replayed, committed, found) = *replayed.borrow();
        assert_eq!(replayed, 0, "flushed intents must not replay");
        assert_eq!(committed, 8, "all intents sit below the commit watermark");
        assert_eq!(found, 8, "flushed fields stay visible without replay");
    }
}
