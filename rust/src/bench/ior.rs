//! IOR-like generic I/O benchmark (thesis §4.1.1): every process writes
//! then reads `nops × xfer_size`, file-per-process on Lustre (optionally
//! via DFS on DAOS for Fig 4.29), object-per-op on DAOS/Ceph.

use super::scenario::{new_spans, Deployment, SystemUnderTest};
use super::{aggregate_bw, BwResult};
use crate::daos::{dfs::Dfs, ObjClass};
use crate::lustre::StripeSpec;
use crate::sim::exec::WaitGroup;
use crate::util::content::Bytes;

#[derive(Clone, Copy, Debug)]
pub struct IorConfig {
    pub procs_per_node: usize,
    pub nops: usize,
    pub xfer: u64,
    /// route DAOS through the DFS POSIX layer (IOR/HDF5 mode, Fig 4.29)
    pub daos_via_dfs: bool,
}

impl Default for IorConfig {
    fn default() -> Self {
        IorConfig {
            procs_per_node: 16,
            nops: 100,
            xfer: 1 << 20,
            daos_via_dfs: false,
        }
    }
}

/// Run write phase then read phase; returns aggregate bandwidths.
pub fn run(dep: &Deployment, cfg: IorConfig) -> BwResult {
    let clients = dep.client_nodes();
    let mut result = BwResult::default();
    for write in [true, false] {
        let spans = new_spans();
        let total = clients.len() * cfg.procs_per_node;
        let wg = WaitGroup::new(total);
        for (ni, node) in clients.iter().enumerate() {
            for p in 0..cfg.procs_per_node {
                let sim = dep.sim.clone();
                let node = node.clone();
                let spans = spans.clone();
                let wg = wg.clone();
                let pid = ni * cfg.procs_per_node + p;
                match &dep.system {
                    SystemUnderTest::Lustre(fs) => {
                        let fs = fs.clone();
                        dep.sim.spawn(async move {
                            let mut cli = fs.client(&node);
                            let path = format!("/ior/f{pid}");
                            let t0 = sim.now();
                            if write {
                                let _ = cli.mkdir("/ior").await;
                                let fd = cli
                                    .create(&path, StripeSpec::default_layout())
                                    .await
                                    .unwrap();
                                for i in 0..cfg.nops {
                                    cli.write_data(
                                        &fd,
                                        Bytes::virt(cfg.xfer, (pid * 1_000_000 + i) as u64),
                                    )
                                    .await
                                    .unwrap();
                                }
                                cli.fdatasync(&fd).await.unwrap();
                            } else {
                                let fd = cli.open(&path).await.unwrap().unwrap();
                                for i in 0..cfg.nops {
                                    let got = cli
                                        .read(&fd, (i as u64) * cfg.xfer, cfg.xfer)
                                        .await
                                        .unwrap();
                                    assert_eq!(got.len(), cfg.xfer);
                                }
                            }
                            spans.borrow_mut().push((
                                t0,
                                sim.now(),
                                cfg.nops as u64 * cfg.xfer,
                            ));
                            wg.done();
                        });
                    }
                    SystemUnderTest::Daos(d) => {
                        let d = d.clone();
                        let via_dfs = cfg.daos_via_dfs;
                        dep.sim.spawn(async move {
                            let cli = d.client(&node);
                            let pool = cli.pool_connect("fdb").await.unwrap();
                            let cont =
                                cli.cont_create_with_label(&pool, "ior").await.unwrap();
                            let t0 = sim.now();
                            if via_dfs {
                                let dfs = Dfs::mount(&cli, &cont);
                                let path = format!("/ior/f{pid}");
                                if write {
                                    let f = dfs.create(&path, ObjClass::S1).await;
                                    for i in 0..cfg.nops {
                                        dfs.write_data(
                                            &f,
                                            (i as u64) * cfg.xfer,
                                            Bytes::virt(
                                                cfg.xfer,
                                                (pid * 1_000_000 + i) as u64,
                                            ),
                                        )
                                        .await;
                                    }
                                } else {
                                    let f = dfs.open(&path).await.unwrap().unwrap();
                                    for i in 0..cfg.nops {
                                        let got = dfs
                                            .read(&f, (i as u64) * cfg.xfer, cfg.xfer)
                                            .await
                                            .unwrap();
                                        assert_eq!(got.len(), cfg.xfer);
                                    }
                                }
                            } else {
                                // native: one array per op
                                for i in 0..cfg.nops {
                                    let oid = crate::daos::Oid::new(
                                        10 + pid as u64,
                                        (if write { 0 } else { 0 }) + i as u64,
                                    );
                                    let arr = cli.array_open_with_attr(
                                        &cont,
                                        oid,
                                        ObjClass::S1,
                                    );
                                    if write {
                                        cli.array_write_data(
                                            &arr,
                                            0,
                                            Bytes::virt(
                                                cfg.xfer,
                                                (pid * 1_000_000 + i) as u64,
                                            ),
                                        )
                                        .await;
                                    } else {
                                        let got =
                                            cli.array_read(&arr, 0, cfg.xfer).await.unwrap();
                                        assert_eq!(got.len(), cfg.xfer);
                                    }
                                }
                            }
                            spans.borrow_mut().push((
                                t0,
                                sim.now(),
                                cfg.nops as u64 * cfg.xfer,
                            ));
                            wg.done();
                        });
                    }
                    SystemUnderTest::Ceph(c, pool) => {
                        let c = c.clone();
                        let pool = pool.clone();
                        dep.sim.spawn(async move {
                            let cli = c.client(&node);
                            let t0 = sim.now();
                            for i in 0..cfg.nops {
                                let name = format!("ior-{pid}-{i}");
                                if write {
                                    cli.write_full_data(
                                        &pool,
                                        "ior",
                                        &name,
                                        Bytes::virt(cfg.xfer, (pid * 1_000_000 + i) as u64),
                                    )
                                    .await
                                    .unwrap();
                                } else {
                                    let got = cli
                                        .read(&pool, "ior", &name, 0, cfg.xfer)
                                        .await
                                        .unwrap()
                                        .unwrap();
                                    assert_eq!(got.len(), cfg.xfer);
                                }
                            }
                            spans.borrow_mut().push((
                                t0,
                                sim.now(),
                                cfg.nops as u64 * cfg.xfer,
                            ));
                            wg.done();
                        });
                    }
                    SystemUnderTest::Null(_) => {
                        panic!("IOR needs a deployed storage system (lustre|daos|ceph)")
                    }
                }
            }
        }
        // wait for the phase to complete
        let wg2 = wg.clone();
        dep.sim.spawn(async move {
            wg2.wait().await;
        });
        let t = dep.sim.run();
        let bw = aggregate_bw(&spans.borrow());
        if write {
            result.write_bw = bw;
            result.write_time = t;
        } else {
            result.read_bw = bw;
            result.read_time = t;
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::scenario::{deploy, RedundancyOpt, SystemKind};
    use crate::hw::profiles::Testbed;

    fn run_small(kind: SystemKind) -> BwResult {
        let dep = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
        run(
            &dep,
            IorConfig {
                procs_per_node: 4,
                nops: 20,
                xfer: 1 << 20,
                daos_via_dfs: false,
            },
        )
    }

    #[test]
    fn ior_runs_on_all_systems() {
        for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
            let r = run_small(kind);
            assert!(r.write_bw > 0.0, "{kind:?} write bw");
            assert!(r.read_bw > 0.0, "{kind:?} read bw");
            // sanity: below the 2-server aggregate device ceiling ×2
            assert!(r.gibs_w() < 20.0, "{kind:?} write {}", r.gibs_w());
        }
    }

    #[test]
    fn daos_dfs_mode_runs() {
        let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
        let r = run(
            &dep,
            IorConfig {
                procs_per_node: 2,
                nops: 10,
                xfer: 1 << 20,
                daos_via_dfs: true,
            },
        );
        assert!(r.write_bw > 0.0 && r.read_bw > 0.0);
    }
}
