//! Scenario registry: one entry per table/figure of the thesis'
//! evaluation. Each regenerates the paper's rows/series on the simulated
//! testbeds and states the expected *shape* (who wins, by what factor).
//!
//! `scale` multiplies per-process op counts (1.0 = paper scale; the
//! default used by `cargo bench` is 0.05 so full sweeps run in minutes —
//! aggregate bandwidths are steady-state and converge well below 1.0).

use crate::bench::fieldio::{self, FieldIoConfig};
use crate::bench::hammer::{self, HammerConfig};
use crate::bench::ior::{self, IorConfig};
use crate::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use crate::daos::ObjClass;
use crate::hw::fabric::{Fabric, FabricKind};
use crate::hw::profiles::Testbed;
use crate::sim::exec::Sim;
use crate::sim::trace::Trace;

/// One data point of a figure.
#[derive(Clone, Debug)]
pub struct FigRow {
    /// x-axis label (e.g. "4 servers", "16 procs", a config name)
    pub x: String,
    /// series label (e.g. "DAOS write")
    pub series: String,
    /// value in GiB/s unless the figure says otherwise
    pub value: f64,
    pub unit: &'static str,
}

#[derive(Clone, Debug)]
pub struct Figure {
    pub id: &'static str,
    pub title: &'static str,
    /// the paper's qualitative result this reproduction should match
    pub expectation: &'static str,
    pub rows: Vec<FigRow>,
    /// optional op-class profiling renders (Figs 4.14/4.15/4.23–4.25)
    pub profiles: Vec<(String, String)>,
}

impl Figure {
    pub fn render(&self) -> String {
        let mut out = format!("== {} — {}\n   paper: {}\n", self.id, self.title, self.expectation);
        let xw = self.rows.iter().map(|r| r.x.len()).max().unwrap_or(4).max(4);
        let sw = self
            .rows
            .iter()
            .map(|r| r.series.len())
            .max()
            .unwrap_or(6)
            .max(6);
        for r in &self.rows {
            out.push_str(&format!(
                "   {:xw$}  {:sw$}  {:>9.3} {}\n",
                r.x,
                r.series,
                r.value,
                r.unit,
                xw = xw,
                sw = sw
            ));
        }
        for (label, prof) in &self.profiles {
            out.push_str(&format!("   profile[{label}]: {prof}\n"));
        }
        out
    }

    /// Value lookup for shape assertions in tests.
    pub fn value(&self, x: &str, series: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.x == x && r.series == series)
            .map(|r| r.value)
    }

    /// Version of the benchmark-record JSON schema emitted by
    /// [`Figure::to_json`]. Bump when the shape of the emitted object
    /// changes, so checked-in `BENCH_*.json` baselines can be compared
    /// against fresh output without guessing their vintage.
    pub const JSON_SCHEMA_VERSION: u64 = 1;

    /// Machine-readable form (benchmark records like `BENCH_iodepth.json`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::obj()
                    .set("x", r.x.clone())
                    .set("series", r.series.clone())
                    .set("value", r.value)
                    .set("unit", r.unit)
            })
            .collect();
        Json::obj()
            .set("schema_version", Figure::JSON_SCHEMA_VERSION)
            .set("id", self.id)
            .set("title", self.title)
            .set("expectation", self.expectation)
            .set("rows", Json::Arr(rows))
    }

    /// Sum of a series across x (for coarse comparisons).
    pub fn series_mean(&self, series: &str) -> f64 {
        let vals: Vec<f64> = self
            .rows
            .iter()
            .filter(|r| r.series == series)
            .map(|r| r.value)
            .collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }
}

fn gib(v: f64) -> f64 {
    v / (1u64 << 30) as f64
}

fn ops(scale: f64, paper: usize) -> usize {
    ((paper as f64 * scale).round() as usize).max(10)
}

/// All figure ids, in paper order.
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "tab2_1", "fig3_5", "tab4_1", "fig4_4", "fig4_5", "fig4_6", "fig4_7", "fig4_8",
        "fig4_9", "fig4_10", "fig4_11", "fig4_12", "fig4_13", "fig4_14", "fig4_15",
        "fig4_18", "fig4_19", "fig4_20", "fig4_21", "fig4_22", "fig4_23", "fig4_24",
        "fig4_25", "fig4_26", "fig4_27", "fig4_28", "fig4_29", "fig4_30",
    ]
}

/// Run one figure by id. `scale` ∈ (0, 1] scales per-process op counts.
pub fn run_figure(id: &str, scale: f64) -> Option<Figure> {
    Some(match id {
        "tab2_1" => tab2_1(),
        "tab4_1" => tab4_1(),
        "fig3_5" => fig3_5(scale),
        "fig4_4" => node_roofline("fig4_4", Testbed::NextGenIo),
        "fig4_18" => node_roofline("fig4_18", Testbed::Gcp),
        "fig4_5" => fig4_5(scale),
        "fig4_6" => fig4_6(scale),
        "fig4_7" => ior_scaling("fig4_7", Testbed::NextGenIo, &[SystemKind::Lustre, SystemKind::Daos], &[2, 4, 8], 4, scale),
        "fig4_8" => fieldio_scaling("fig4_8", false, scale),
        "fig4_9" => fieldio_scaling("fig4_9", true, scale),
        "fig4_10" => fig4_10(scale),
        "fig4_11" => fig4_11(scale),
        "fig4_12" => hammer_scaling("fig4_12", Testbed::NextGenIo, &[SystemKind::Lustre, SystemKind::Daos], &[2, 4, 8], false, scale),
        "fig4_13" => hammer_scaling("fig4_13", Testbed::NextGenIo, &[SystemKind::Lustre, SystemKind::Daos], &[2, 4, 8], true, scale),
        "fig4_14" => profile_fig("fig4_14", Testbed::NextGenIo, SystemKind::Daos, scale),
        "fig4_15" => profile_fig("fig4_15", Testbed::NextGenIo, SystemKind::Lustre, scale),
        "fig4_19" => fig4_19(scale),
        "fig4_20" => ior_scaling("fig4_20", Testbed::Gcp, &[SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph], &[2, 4, 8], 2, scale),
        "fig4_21" => hammer_scaling("fig4_21", Testbed::Gcp, &[SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph], &[2, 4, 8], false, scale),
        "fig4_22" => hammer_scaling("fig4_22", Testbed::Gcp, &[SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph], &[2, 4, 8], true, scale),
        "fig4_23" => profile_fig("fig4_23", Testbed::Gcp, SystemKind::Daos, scale),
        "fig4_24" => profile_fig("fig4_24", Testbed::Gcp, SystemKind::Ceph, scale),
        "fig4_25" => profile_fig("fig4_25", Testbed::Gcp, SystemKind::Lustre, scale),
        "fig4_26" => fig4_26(scale),
        "fig4_27" => redundancy_fig("fig4_27", RedundancyOpt::Replica2, ObjClass::Rp2, scale),
        "fig4_28" => redundancy_fig("fig4_28", RedundancyOpt::Ec2p1, ObjClass::Ec2p1, scale),
        "fig4_29" => fig4_29(scale),
        "fig4_30" => fig4_30(scale),
        _ => return None,
    })
}

// ------------------------------------------------------------ tables

fn tab2_1() -> Figure {
    let rows = vec![
        ("members", 52.0, 24.0),
        ("steps", 144.0, 100.0),
        ("levels", 150.0, 10.0),
        ("parameters", 20.0, 10.0),
    ];
    Figure {
        id: "tab2_1",
        title: "dimension of operational runs vs fdb-hammer runs",
        expectation: "hammer exercises fewer members/steps/levels/params than operations",
        rows: rows
            .into_iter()
            .flat_map(|(dim, op, hm)| {
                vec![
                    FigRow {
                        x: dim.to_string(),
                        series: "operational".into(),
                        value: op,
                        unit: "",
                    },
                    FigRow {
                        x: dim.to_string(),
                        series: "fdb-hammer(max)".into(),
                        value: hm,
                        unit: "",
                    },
                ]
            })
            .collect(),
        profiles: vec![],
    }
}

fn tab4_1() -> Figure {
    // process-to-process transfer rate: stream 64 MiB messages
    let rate = |kind: FabricKind| {
        let sim = Sim::new();
        let f = Fabric::new(kind);
        let a = crate::hw::fabric::Nic::new(0);
        let b = crate::hw::fabric::Nic::new(1);
        let s = sim.clone();
        sim.spawn(async move {
            for _ in 0..64 {
                f.xfer(&s, &a, &b, 64 << 20).await;
            }
        });
        let t = sim.run();
        64.0 * (64u64 << 20) as f64 / t.as_secs_f64()
    };
    Figure {
        id: "tab4_1",
        title: "process-to-process transfer rates with PSM2 and TCP",
        expectation: "PSM2 delivers several times the TCP rate on Omni-Path",
        rows: vec![
            FigRow {
                x: "PSM2".into(),
                series: "stream".into(),
                value: gib(rate(FabricKind::Psm2)),
                unit: "GiB/s",
            },
            FigRow {
                x: "TCP".into(),
                series: "stream".into(),
                value: gib(rate(FabricKind::TcpOpa)),
                unit: "GiB/s",
            },
        ],
        profiles: vec![],
    }
}

// ------------------------------------------------------------ helpers

fn node_roofline(id: &'static str, testbed: Testbed) -> Figure {
    // ideal node-as-networked-server bandwidth: min(device, NIC)
    let dev = testbed.storage_device();
    let fabric = crate::hw::fabric::FabricSpec::of(testbed.fabric_for(false));
    let w = dev.write_bw.min(fabric.link_bw);
    let r = dev.read_bw.min(fabric.link_bw);
    Figure {
        id,
        title: "ideal write/read bandwidth of one storage node",
        expectation: "write is device-bound; read is network-bound on NEXTGenIO, device/NIC-balanced on GCP",
        rows: vec![
            FigRow {
                x: "node".into(),
                series: "ideal write".into(),
                value: gib(w),
                unit: "GiB/s",
            },
            FigRow {
                x: "node".into(),
                series: "ideal read".into(),
                value: gib(r),
                unit: "GiB/s",
            },
        ],
        profiles: vec![],
    }
}

fn ior_point(
    testbed: Testbed,
    kind: SystemKind,
    servers: usize,
    clients: usize,
    procs: usize,
    nops: usize,
) -> (f64, f64) {
    let dep = deploy(testbed, kind, servers, clients, RedundancyOpt::None);
    let r = ior::run(
        &dep,
        IorConfig {
            procs_per_node: procs,
            nops,
            xfer: 1 << 20,
            daos_via_dfs: false,
        },
    );
    (gib(r.write_bw), gib(r.read_bw))
}

fn ior_scaling(
    id: &'static str,
    testbed: Testbed,
    systems: &[SystemKind],
    servers: &[usize],
    client_ratio: usize,
    scale: f64,
) -> Figure {
    let mut rows = Vec::new();
    for &kind in systems {
        for &srv in servers {
            let nops = ops(scale, if kind == SystemKind::Ceph { 100 } else { 10_000 });
            let (w, r) = ior_point(testbed, kind, srv, srv * client_ratio, 8, nops);
            rows.push(FigRow {
                x: format!("{srv} servers"),
                series: format!("{} write", kind.label()),
                value: w,
                unit: "GiB/s",
            });
            rows.push(FigRow {
                x: format!("{srv} servers"),
                series: format!("{} read", kind.label()),
                value: r,
                unit: "GiB/s",
            });
        }
    }
    Figure {
        id,
        title: "IOR bandwidth scalability",
        expectation: "DAOS scales ~linearly with servers; Lustre trails at scale; Ceph lowest (TCP + OSD path)",
        rows,
        profiles: vec![],
    }
}

fn fig4_5(scale: f64) -> Figure {
    // IOR vs a 2(+1)-node Lustre deployment, sweeping process counts
    let mut rows = Vec::new();
    for procs in [4usize, 8, 16, 32] {
        let (w, r) = ior_point(
            Testbed::NextGenIo,
            SystemKind::Lustre,
            2,
            4,
            procs,
            ops(scale, 100),
        );
        rows.push(FigRow {
            x: format!("{procs} procs/node"),
            series: "Lustre write".into(),
            value: w,
            unit: "GiB/s",
        });
        rows.push(FigRow {
            x: format!("{procs} procs/node"),
            series: "Lustre read".into(),
            value: r,
            unit: "GiB/s",
        });
    }
    Figure {
        id: "fig4_5",
        title: "IOR against 2+1-node Lustre (NEXTGenIO), process sweep",
        expectation: "bandwidth saturates as process count grows; read > write",
        rows,
        profiles: vec![],
    }
}

fn fig4_6(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for procs in [4usize, 8, 16, 32] {
        let (w, r) = ior_point(
            Testbed::NextGenIo,
            SystemKind::Daos,
            2,
            4,
            procs,
            ops(scale, 100),
        );
        rows.push(FigRow {
            x: format!("{procs} procs/node"),
            series: "DAOS write".into(),
            value: w,
            unit: "GiB/s",
        });
        rows.push(FigRow {
            x: format!("{procs} procs/node"),
            series: "DAOS read".into(),
            value: r,
            unit: "GiB/s",
        });
    }
    Figure {
        id: "fig4_6",
        title: "IOR against 2-node DAOS (NEXTGenIO), process sweep",
        expectation: "saturates near the 2-node hardware ceiling; read > write",
        rows,
        profiles: vec![],
    }
}

fn fieldio_scaling(id: &'static str, contention: bool, scale: f64) -> Figure {
    let mut rows = Vec::new();
    for srv in [2usize, 4, 8] {
        let dep = deploy(
            Testbed::NextGenIo,
            SystemKind::Daos,
            srv,
            srv * 2,
            RedundancyOpt::None,
        );
        let r = fieldio::run(
            &dep,
            FieldIoConfig {
                procs_per_node: 8,
                nfields: ops(scale, 2000),
                field_size: 1 << 20,
                contention,
                ..Default::default()
            },
        );
        rows.push(FigRow {
            x: format!("{srv} servers"),
            series: "DAOS write".into(),
            value: gib(r.write_bw),
            unit: "GiB/s",
        });
        rows.push(FigRow {
            x: format!("{srv} servers"),
            series: "DAOS read".into(),
            value: gib(r.read_bw),
            unit: "GiB/s",
        });
    }
    Figure {
        id,
        title: if contention {
            "Field I/O scaling on DAOS, write+read contention"
        } else {
            "Field I/O scaling on DAOS, no contention"
        },
        expectation: "near-linear scaling; contention costs DAOS little (MVCC)",
        rows,
        profiles: vec![],
    }
}

fn fig4_10(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for (size_label, size) in [("64KiB", 64u64 << 10), ("1MiB", 1 << 20), ("16MiB", 16 << 20)] {
        for (class_label, class) in [("OC_S1", ObjClass::S1), ("OC_S2", ObjClass::S2), ("OC_SX", ObjClass::Sx)] {
            let dep = deploy(
                Testbed::NextGenIo,
                SystemKind::Daos,
                4,
                8,
                RedundancyOpt::None,
            );
            let r = fieldio::run(
                &dep,
                FieldIoConfig {
                    procs_per_node: 8,
                    nfields: ops(scale, 100),
                    field_size: size,
                    array_class: class,
                    ..Default::default()
                },
            );
            rows.push(FigRow {
                x: format!("{size_label}/{class_label}"),
                series: "write".into(),
                value: gib(r.write_bw),
                unit: "GiB/s",
            });
            rows.push(FigRow {
                x: format!("{size_label}/{class_label}"),
                series: "read".into(),
                value: gib(r.read_bw),
                unit: "GiB/s",
            });
        }
    }
    Figure {
        id: "fig4_10",
        title: "Field I/O: field size × object sharding sweep (DAOS)",
        expectation: "OC_S1 best for parallel ~1MiB fields; sharding helps only large fields",
        rows,
        profiles: vec![],
    }
}

fn fig4_11(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for kind in [SystemKind::Lustre, SystemKind::Daos] {
        for srv in [2usize, 4, 8] {
            let dep = deploy(Testbed::NextGenIo, kind, srv, srv * 2, RedundancyOpt::None);
            let r = fieldio::run(
                &dep,
                FieldIoConfig {
                    procs_per_node: 8,
                    nfields: ops(scale, 2000),
                    field_size: 1 << 20,
                    ..Default::default()
                },
            );
            rows.push(FigRow {
                x: format!("{srv} servers"),
                series: format!("{} write", kind.label()),
                value: gib(r.write_bw),
                unit: "GiB/s",
            });
            rows.push(FigRow {
                x: format!("{srv} servers"),
                series: format!("{} read", kind.label()),
                value: gib(r.read_bw),
                unit: "GiB/s",
            });
        }
    }
    Figure {
        id: "fig4_11",
        title: "Field I/O scalability: Lustre vs DAOS (NEXTGenIO)",
        expectation: "DAOS ≥ Lustre and scales more cleanly",
        rows,
        profiles: vec![],
    }
}

fn hammer_scaling(
    id: &'static str,
    testbed: Testbed,
    systems: &[SystemKind],
    servers: &[usize],
    contention: bool,
    scale: f64,
) -> Figure {
    let mut rows = Vec::new();
    let paper_fields = 10_000f64;
    // nsteps × nparams × nlevels ≈ paper fields; 100 × 10 × 10 at 1.0
    let nsteps = ((paper_fields * scale / 100.0).cbrt() * 4.0).round().max(2.0) as u32;
    for &kind in systems {
        for &srv in servers {
            let dep = deploy(testbed, kind, srv, srv * 2, RedundancyOpt::None);
            let (r, _) = hammer::run(
                &dep,
                HammerConfig {
                    procs_per_node: 8,
                    nsteps,
                    nparams: 5,
                    nlevels: 4,
                    field_size: 1 << 20,
                    check: false,
                    contention,
                    faults_ok: false,
                },
            );
            rows.push(FigRow {
                x: format!("{srv} servers"),
                series: format!("{} write", kind.label()),
                value: gib(r.write_bw),
                unit: "GiB/s",
            });
            rows.push(FigRow {
                x: format!("{srv} servers"),
                series: format!("{} read", kind.label()),
                value: gib(r.read_bw),
                unit: "GiB/s",
            });
        }
    }
    Figure {
        id,
        title: if contention {
            "fdb-hammer scalability, write+read contention"
        } else {
            "fdb-hammer scalability, no contention"
        },
        expectation: if contention {
            "contention collapses Lustre (DLM ping-pong); DAOS barely affected; Ceph in between"
        } else {
            "DAOS highest and ~linear; Lustre next; Ceph lowest (TCP-only)"
        },
        rows,
        profiles: vec![],
    }
}

fn profile_fig(id: &'static str, testbed: Testbed, kind: SystemKind, scale: f64) -> Figure {
    let mut profiles = Vec::new();
    let mut rows = Vec::new();
    for contention in [false, true] {
        // the telemetry registry rides along so the time breakdown gains
        // tail-latency (p99/p999) columns next to the class totals
        let reg = crate::fdb::MetricsRegistry::new();
        let dep = deploy(testbed, kind, 2, 4, RedundancyOpt::None).with_metrics(&reg);
        let (_, trace): (_, Trace) = hammer::run(
            &dep,
            HammerConfig {
                procs_per_node: 8,
                nsteps: ops(scale, 100).max(3) as u32 / 3,
                nparams: 4,
                nlevels: 3,
                field_size: 1 << 20,
                check: false,
                contention,
                faults_ok: false,
            },
        );
        let label = if contention { "contention" } else { "no-contention" };
        profiles.push((label.to_string(), trace.render()));
        for (cls, hist) in [
            ("data-read", "engine.service.data-read"),
            ("data-write", "engine.service.data-write"),
        ] {
            if let Some(snap) = reg.hist(hist) {
                for (pname, p) in [("p99", 99.0), ("p999", 99.9)] {
                    rows.push(FigRow {
                        x: label.to_string(),
                        series: format!("{cls} {pname}"),
                        value: snap.percentile(p) as f64 / 1e3,
                        unit: "us",
                    });
                }
            }
        }
    }
    Figure {
        id,
        title: "fdb-hammer client-side time breakdown",
        expectation: match kind {
            SystemKind::Lustre => "lock time appears and grows under contention",
            SystemKind::Daos => "time is data-write/read dominated; no lock class",
            SystemKind::Ceph => "data ops dominate; higher per-op overhead than DAOS",
        },
        rows,
        profiles,
    }
}

fn fig4_19(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        let nops = ops(scale, if kind == SystemKind::Ceph { 100 } else { 10_000 });
        let (w, r) = ior_point(Testbed::Gcp, kind, 4, 8, 8, nops);
        rows.push(FigRow {
            x: "16-VM-equivalent".into(),
            series: format!("{} write", kind.label()),
            value: w,
            unit: "GiB/s",
        });
        rows.push(FigRow {
            x: "16-VM-equivalent".into(),
            series: format!("{} read", kind.label()),
            value: r,
            unit: "GiB/s",
        });
    }
    Figure {
        id: "fig4_19",
        title: "IOR on GCP: Lustre vs DAOS vs Ceph",
        expectation: "DAOS ≥ Lustre > Ceph for writes; reads closer",
        rows,
        profiles: vec![],
    }
}

fn fig3_5(scale: f64) -> Figure {
    use crate::fdb::rados::store::{RadosLayout, RadosStoreConfig};
    // seven configurations of the Ceph backends (thesis Fig 3.5)
    let configs: Vec<(&str, RadosStoreConfig, bool)> = vec![
        (
            "ns+span+sync",
            RadosStoreConfig {
                layout: RadosLayout::SpannedPerProcess,
                ..Default::default()
            },
            true,
        ),
        (
            "pool+span+sync",
            RadosStoreConfig {
                layout: RadosLayout::SpannedPerProcess,
                pool_per_dataset: true,
                ..Default::default()
            },
            true,
        ),
        (
            "ns+single-large",
            RadosStoreConfig {
                layout: RadosLayout::SingleLargePerProcess,
                ..Default::default()
            },
            true,
        ),
        (
            "ns+obj-per-field",
            RadosStoreConfig::default(),
            true,
        ),
        (
            "ns+obj-per-field+1GiB-max",
            RadosStoreConfig::default(),
            true,
        ),
        (
            "ns+obj-per-field+async",
            RadosStoreConfig {
                async_io: true,
                ..Default::default()
            },
            false, // fails the consistency requirement (patterned bars)
        ),
        (
            "ns+span+async",
            RadosStoreConfig {
                layout: RadosLayout::SpannedPerProcess,
                async_io: true,
                ..Default::default()
            },
            true,
        ),
    ];
    let mut rows = Vec::new();
    for (name, cfg, consistent) in configs {
        let dep = deploy(Testbed::Gcp, SystemKind::Ceph, 4, 8, RedundancyOpt::None);
        let raised_max = name.contains("1GiB-max") || name.contains("single-large");
        if raised_max {
            // emulate raising osd_max_object_size at deployment
            if let crate::bench::scenario::SystemUnderTest::Ceph(c, _) = &dep.system {
                // Safety: config is plain data behind Rc; runs are
                // single-threaded. We rebuild instead of mutating.
                let _ = c;
            }
        }
        let r = run_fig3_5_config(&dep, cfg, ops(scale, 10_000), raised_max);
        rows.push(FigRow {
            x: name.to_string(),
            series: if consistent {
                "write".to_string()
            } else {
                "write (INCONSISTENT)".to_string()
            },
            value: gib(r.write_bw),
            unit: "GiB/s",
        });
        rows.push(FigRow {
            x: name.to_string(),
            series: "read".into(),
            value: gib(r.read_bw),
            unit: "GiB/s",
        });
    }
    Figure {
        id: "fig3_5",
        title: "FDB Ceph backend configuration sweep",
        expectation: "obj-per-field best balance; single-large best read but ~half write; async fastest write but fails consistency",
        rows,
        profiles: vec![],
    }
}

fn run_fig3_5_config(
    dep: &crate::bench::scenario::Deployment,
    store_cfg: crate::fdb::rados::store::RadosStoreConfig,
    nfields: usize,
    raise_max: bool,
) -> crate::bench::BwResult {
    use crate::bench::{aggregate_bw, BwResult};
    use crate::fdb::{BackendConfig, FdbBuilder};
    use crate::sim::exec::WaitGroup;
    use crate::util::content::Bytes;

    let crate::bench::scenario::SystemUnderTest::Ceph(ceph, pool) = &dep.system else {
        unreachable!()
    };
    let ceph = if raise_max {
        // re-deploy with a raised object-size cap
        let mut cfg = crate::ceph::CephConfig::default();
        cfg.max_object_size = 1 << 40;
        crate::ceph::Ceph::deploy(&dep.sim, &dep.cluster, cfg)
    } else {
        ceph.clone()
    };
    let pool = if raise_max {
        ceph.create_pool("fdb", pool.pg_num, pool.redundancy)
    } else {
        pool.clone()
    };
    let clients = dep.client_nodes();
    let mk = |node: &std::rc::Rc<crate::hw::node::Node>| {
        FdbBuilder::new(&dep.sim)
            .node(node)
            .backend(BackendConfig::Rados {
                ceph: ceph.clone(),
                pool: pool.clone(),
                store: store_cfg.clone(),
            })
            .build()
            .unwrap()
    };
    let mut result = BwResult::default();
    // write phase
    let spans = crate::bench::scenario::new_spans();
    let wg = WaitGroup::new(clients.len() * 4);
    for (ni, node) in clients.iter().enumerate() {
        for p in 0..4usize {
            let mut fdb = mk(node);
            let sim = dep.sim.clone();
            let spans = spans.clone();
            let wg = wg.clone();
            dep.sim.spawn(async move {
                let t0 = sim.now();
                for i in 0..nfields {
                    let id = hammer::field_id(ni, 1 + (i / 50) as u32, (i % 10) as u32, (p * 1000 + i % 5) as u32);
                    fdb.archive(&id, Bytes::virt(1 << 20, hammer::field_seed(&id)))
                        .await
                        .unwrap();
                    if i % 50 == 49 {
                        fdb.flush().await.expect("flush");
                    }
                }
                fdb.flush().await.expect("flush");
                spans
                    .borrow_mut()
                    .push((t0, sim.now(), nfields as u64 * (1 << 20)));
                wg.done();
            });
        }
    }
    dep.sim.run();
    result.write_bw = aggregate_bw(&spans.borrow());
    // read phase
    let spans = crate::bench::scenario::new_spans();
    let wg = WaitGroup::new(clients.len() * 4);
    let t0 = dep.sim.now();
    for (ni, node) in clients.iter().enumerate() {
        for p in 0..4usize {
            let mut fdb = mk(node);
            let sim = dep.sim.clone();
            let spans = spans.clone();
            let wg = wg.clone();
            dep.sim.spawn(async move {
                let t0 = sim.now();
                for i in 0..nfields {
                    let id = hammer::field_id(ni, 1 + (i / 50) as u32, (i % 10) as u32, (p * 1000 + i % 5) as u32);
                    if let Some(h) = fdb.retrieve(&id).await.unwrap() {
                        fdb.read(&h).await.unwrap();
                    }
                }
                spans
                    .borrow_mut()
                    .push((t0, sim.now(), nfields as u64 * (1 << 20)));
                wg.done();
            });
        }
    }
    dep.sim.run();
    let _ = (wg, t0);
    result.read_bw = aggregate_bw(&spans.borrow());
    result
}

fn fig4_26(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        let dep = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
        let (r, _) = hammer::run(
            &dep,
            HammerConfig {
                procs_per_node: 8,
                nsteps: ops(scale, 10_000).max(40) as u32 / 20,
                nparams: 5,
                nlevels: 4,
                field_size: 1 << 10, // 1 KiB fields
                check: false,
                contention: false,
                faults_ok: false,
            },
        );
        rows.push(FigRow {
            x: "1KiB objects".into(),
            series: format!("{} write", kind.label()),
            value: r.write_bw / (1u64 << 20) as f64,
            unit: "MiB/s",
        });
        rows.push(FigRow {
            x: "1KiB objects".into(),
            series: format!("{} read", kind.label()),
            value: r.read_bw / (1u64 << 20) as f64,
            unit: "MiB/s",
        });
    }
    Figure {
        id: "fig4_26",
        title: "small-object (1 KiB) bandwidth",
        expectation: "DAOS leads durable KiB-object I/O (WAL commits); Ceph per-op bound; Lustre reads collapse (write rate is page-cache buffering)",
        rows,
        profiles: vec![],
    }
}

fn redundancy_fig(
    id: &'static str,
    red: RedundancyOpt,
    daos_class: ObjClass,
    scale: f64,
) -> Figure {
    let mut rows = Vec::new();
    for kind in [SystemKind::Daos, SystemKind::Ceph] {
        for srv in [2usize, 4] {
            let dep = deploy(Testbed::Gcp, kind, srv, srv * 2, RedundancyOpt::None);
            // DAOS: redundancy via object class; Ceph: via pool settings
            let dep = if kind == SystemKind::Ceph {
                deploy(Testbed::Gcp, kind, srv, srv * 2, red)
            } else {
                dep
            };
            let r = match (&dep.system, kind) {
                (_, SystemKind::Daos) => {
                    // hammer with a redundant array class via fieldio
                    fieldio::run(
                        &dep,
                        FieldIoConfig {
                            procs_per_node: 8,
                            nfields: ops(scale, 10_000),
                            field_size: 1 << 20,
                            array_class: daos_class,
                            ..Default::default()
                        },
                    )
                }
                _ => {
                    let (r, _) = hammer::run(
                        &dep,
                        HammerConfig {
                            procs_per_node: 8,
                            nsteps: ops(scale, 10_000).max(40) as u32 / 20,
                            nparams: 5,
                            nlevels: 4,
                            field_size: 1 << 20,
                            check: false,
                            contention: false,
                            faults_ok: false,
                        },
                    );
                    r
                }
            };
            rows.push(FigRow {
                x: format!("{srv} servers"),
                series: format!("{} write", kind.label()),
                value: gib(r.write_bw),
                unit: "GiB/s",
            });
            rows.push(FigRow {
                x: format!("{srv} servers"),
                series: format!("{} read", kind.label()),
                value: gib(r.read_bw),
                unit: "GiB/s",
            });
        }
    }
    Figure {
        id,
        title: if red == RedundancyOpt::Replica2 {
            "fdb-hammer with replication factor 2"
        } else {
            "fdb-hammer with 2+1 erasure coding"
        },
        expectation: "redundancy costs both systems write bandwidth; DAOS stays ahead",
        rows,
        profiles: vec![],
    }
}

fn fig4_29(scale: f64) -> Figure {
    let mut rows = Vec::new();
    // DAOS via DFS (the IOR/HDF5 route) vs Lustre
    for (label, kind, via_dfs) in [
        ("DAOS/DFS", SystemKind::Daos, true),
        ("Lustre", SystemKind::Lustre, false),
    ] {
        let dep = deploy(Testbed::Gcp, kind, 4, 8, RedundancyOpt::None);
        let r = ior::run(
            &dep,
            IorConfig {
                procs_per_node: 8,
                nops: ops(scale, 10_000),
                xfer: 1 << 20,
                daos_via_dfs: via_dfs,
            },
        );
        rows.push(FigRow {
            x: "16-VM-equivalent".into(),
            series: format!("{label} write"),
            value: gib(r.write_bw),
            unit: "GiB/s",
        });
        rows.push(FigRow {
            x: "16-VM-equivalent".into(),
            series: format!("{label} read"),
            value: gib(r.read_bw),
            unit: "GiB/s",
        });
    }
    Figure {
        id: "fig4_29",
        title: "IOR/HDF5 via DAOS DFS vs Lustre",
        expectation: "DAOS via its POSIX layer remains competitive with Lustre",
        rows,
        profiles: vec![],
    }
}

fn fig4_30(scale: f64) -> Figure {
    let mut rows = Vec::new();
    for (label, dummy) in [("DAOS", false), ("dummy libdaos", true)] {
        let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 4, RedundancyOpt::None);
        let r = fieldio::run(
            &dep,
            FieldIoConfig {
                procs_per_node: 8,
                nfields: ops(scale, 1000),
                field_size: 1 << 20,
                dummy,
                ..Default::default()
            },
        );
        rows.push(FigRow {
            x: "4-VM deployment".into(),
            series: format!("{label} write"),
            value: gib(r.write_bw),
            unit: "GiB/s",
        });
        rows.push(FigRow {
            x: "4-VM deployment".into(),
            series: format!("{label} read"),
            value: gib(r.read_bw),
            unit: "GiB/s",
        });
    }
    Figure {
        id: "fig4_30",
        title: "Field I/O with dummy libdaos (client-side overhead)",
        expectation: "dummy bandwidth is far above real — the client library is not the bottleneck",
        rows,
        profiles: vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_ids() {
        for id in all_ids() {
            // tiny scale: just verify every figure executes end-to-end
            if matches!(id, "tab2_1" | "tab4_1" | "fig4_4" | "fig4_18") {
                let fig = run_figure(id, 0.01).unwrap();
                assert!(!fig.rows.is_empty() || !fig.profiles.is_empty(), "{id}");
            }
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run_figure("fig9_9", 1.0).is_none());
    }

    #[test]
    fn roofline_matches_calibration() {
        let f = run_figure("fig4_4", 1.0).unwrap();
        let w = f.value("node", "ideal write").unwrap();
        let r = f.value("node", "ideal read").unwrap();
        assert!((w - 8.0).abs() < 0.2, "NEXTGenIO ideal write {w}");
        assert!((r - 11.2).abs() < 0.3, "NEXTGenIO ideal read {r}");
        let g = run_figure("fig4_18", 1.0).unwrap();
        assert!((g.value("node", "ideal write").unwrap() - 3.0).abs() < 0.2);
        assert!((g.value("node", "ideal read").unwrap() - 3.1).abs() < 0.2);
    }

    #[test]
    fn tab4_1_psm2_beats_tcp() {
        let f = run_figure("tab4_1", 1.0).unwrap();
        let psm2 = f.value("PSM2", "stream").unwrap();
        let tcp = f.value("TCP", "stream").unwrap();
        assert!(psm2 > 2.5 * tcp, "psm2 {psm2} vs tcp {tcp}");
    }
}
