//! Deployment scaffolding shared by all benchmarks: build a testbed
//! cluster, deploy one storage system on it, hand out client slots.

use std::cell::RefCell;
use std::rc::Rc;

use crate::ceph::{Ceph, CephConfig, CephPool, Redundancy};
use crate::daos::{Daos, DaosConfig};
use crate::fdb::{BackendConfig, Fdb, FdbBuilder};
use crate::hw::cluster::Cluster;
use crate::hw::node::Node;
use crate::hw::profiles::{build_cluster, Testbed};
use crate::lustre::{Lustre, LustreConfig};
use crate::sim::exec::Sim;
use crate::sim::time::SimTime;
use crate::sim::trace::Trace;

/// Which storage system a scenario runs against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    Lustre,
    Daos,
    Ceph,
}

impl SystemKind {
    pub fn label(self) -> &'static str {
        match self {
            SystemKind::Lustre => "Lustre",
            SystemKind::Daos => "DAOS",
            SystemKind::Ceph => "Ceph",
        }
    }

    /// Lustre and Ceph use an extra node for MDS/Mon (thesis Figs
    /// 4.3/4.17: "+1 for Lustre and Ceph").
    pub fn extra_md_node(self) -> bool {
        !matches!(self, SystemKind::Daos)
    }
}

/// A deployed system under test.
pub enum SystemUnderTest {
    Lustre(Rc<Lustre>),
    Daos(Rc<Daos>),
    Ceph(Rc<Ceph>, Rc<CephPool>),
}

pub struct Deployment {
    pub sim: Sim,
    pub cluster: Rc<Cluster>,
    pub system: SystemUnderTest,
    pub kind: SystemKind,
    pub testbed: Testbed,
}

/// Redundancy options for Figs 4.27/4.28 (mapped per system).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RedundancyOpt {
    #[default]
    None,
    Replica2,
    Ec2p1,
}

pub fn deploy(
    testbed: Testbed,
    kind: SystemKind,
    servers: usize,
    clients: usize,
    redundancy: RedundancyOpt,
) -> Deployment {
    let sim = Sim::new();
    // Ceph is TCP-only; Lustre on NEXTGenIO uses LNET over OPA (fast);
    // DAOS uses PSM2 natively.
    let tcp_only = matches!(kind, SystemKind::Ceph);
    let cluster = Rc::new(build_cluster(
        testbed,
        servers,
        clients,
        kind.extra_md_node(),
        tcp_only,
    ));
    let system = match kind {
        SystemKind::Lustre => {
            SystemUnderTest::Lustre(Lustre::deploy(&sim, &cluster, LustreConfig::default()))
        }
        SystemKind::Daos => {
            let d = Daos::deploy(&sim, &cluster, DaosConfig::default());
            d.create_pool("fdb");
            SystemUnderTest::Daos(d)
        }
        SystemKind::Ceph => {
            let c = Ceph::deploy(&sim, &cluster, CephConfig::default());
            let red = match redundancy {
                RedundancyOpt::None => Redundancy::None,
                RedundancyOpt::Replica2 => Redundancy::Replica(2),
                RedundancyOpt::Ec2p1 => Redundancy::Erasure(2, 1),
            };
            // ~100 PGs per OSD sweet spot
            let pgs = (servers * 100).next_power_of_two().max(64);
            let pool = c.create_pool("fdb", pgs, red);
            SystemUnderTest::Ceph(c, pool)
        }
    };
    Deployment {
        sim,
        cluster,
        system,
        kind,
        testbed,
    }
}

impl Deployment {
    pub fn client_nodes(&self) -> Vec<Rc<Node>> {
        self.cluster.client_nodes().cloned().collect()
    }

    /// The default [`BackendConfig`] for this deployment's system —
    /// the single place mapping a deployed system to FDB backends.
    pub fn backend_config(&self) -> BackendConfig {
        match &self.system {
            SystemUnderTest::Lustre(fs) => BackendConfig::Posix {
                fs: fs.clone(),
                root: "/fdb".to_string(),
            },
            SystemUnderTest::Daos(d) => BackendConfig::Daos {
                daos: d.clone(),
                pool: "fdb".to_string(),
                hash_oids: false,
            },
            SystemUnderTest::Ceph(c, pool) => BackendConfig::Rados {
                ceph: c.clone(),
                pool: pool.clone(),
                store: crate::fdb::rados::store::RadosStoreConfig::default(),
            },
        }
    }

    /// One FDB instance (per simulated process) on `node`.
    pub fn fdb(&self, node: &Rc<Node>) -> Fdb {
        FdbBuilder::new(&self.sim)
            .node(node)
            .backend(self.backend_config())
            .build()
            .expect("deployment backend config is valid")
    }

    /// Like [`Deployment::fdb`] with a shared trace collector attached.
    pub fn fdb_traced(&self, node: &Rc<Node>, trace: &Trace) -> Fdb {
        FdbBuilder::new(&self.sim)
            .node(node)
            .trace(trace)
            .backend(self.backend_config())
            .build()
            .expect("deployment backend config is valid")
    }
}

/// Shared span collector used by benchmark client processes.
pub type Spans = Rc<RefCell<Vec<(SimTime, SimTime, u64)>>>;

pub fn new_spans() -> Spans {
    Rc::new(RefCell::new(Vec::new()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deploy_each_kind() {
        for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
            let d = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
            assert_eq!(d.client_nodes().len(), 4);
            assert_eq!(d.kind, kind);
        }
    }

    #[test]
    fn ceph_gets_md_node_daos_does_not() {
        let c = deploy(Testbed::Gcp, SystemKind::Ceph, 2, 2, RedundancyOpt::None);
        assert_eq!(c.cluster.metadata_nodes().count(), 1);
        let d = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
        assert_eq!(d.cluster.metadata_nodes().count(), 0);
    }
}
