//! Deployment scaffolding shared by all benchmarks: build a testbed
//! cluster, deploy one storage system on it, hand out client slots.

use std::cell::RefCell;
use std::rc::Rc;

use crate::ceph::{Ceph, CephConfig, CephPool, Redundancy};
use crate::daos::{Daos, DaosConfig};
use crate::fdb::wrappers::ReadPolicy;
use crate::fdb::{
    BackendConfig, FaultPlan, Fdb, FdbBuilder, IoProfile, MetricsRegistry, ResilienceProfile,
    SharedNullCatalogue,
};
use crate::hw::cluster::Cluster;
use crate::hw::node::Node;
use crate::hw::profiles::{build_cluster, Testbed};
use crate::lustre::{Lustre, LustreConfig};
use crate::sim::exec::Sim;
use crate::sim::time::SimTime;
use crate::sim::trace::Trace;

/// Which storage system a scenario runs against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    Lustre,
    Daos,
    Ceph,
    /// No storage system: the zero-cost Null store with a deployment-
    /// shared Null catalogue — client-overhead runs (Fig 4.30) and CI
    /// smoke tests.
    Null,
}

impl SystemKind {
    pub fn label(self) -> &'static str {
        match self {
            SystemKind::Lustre => "Lustre",
            SystemKind::Daos => "DAOS",
            SystemKind::Ceph => "Ceph",
            SystemKind::Null => "Null",
        }
    }

    /// Lustre and Ceph use an extra node for MDS/Mon (thesis Figs
    /// 4.3/4.17: "+1 for Lustre and Ceph").
    pub fn extra_md_node(self) -> bool {
        matches!(self, SystemKind::Lustre | SystemKind::Ceph)
    }

    /// The queue depth `--io-depth auto` derives from the backend's
    /// device-parallelism profile: enough in-flight ops per client to
    /// cover the distinct server-side pipes one client can drive at
    /// once, without over-committing the session pool.
    pub fn auto_io_depth(self) -> usize {
        match self {
            // FDB data files stripe 8×8 MiB: one read per OST pipe
            SystemKind::Lustre => 8,
            // DAOS event queues are the deep end of the interface
            // papers' sweeps; network round trips, not devices, bind
            SystemKind::Daos => 16,
            // ~100 PGs/OSD sweet spot, but one client saturates its
            // TCP NIC well before that many outstanding ops
            SystemKind::Ceph => 8,
            // no device behind the sink: just overlap client overhead
            SystemKind::Null => 4,
        }
    }
}

/// A deployed system under test.
pub enum SystemUnderTest {
    Lustre(Rc<Lustre>),
    Daos(Rc<Daos>),
    Ceph(Rc<Ceph>, Rc<CephPool>),
    /// Nothing deployed; the shared catalogue gives every FDB instance
    /// of the deployment one index (the bare Null catalogue is
    /// process-local, so readers would see nothing).
    Null(SharedNullCatalogue),
}

/// A composable backend wrapper layered over a deployment's base
/// backend — sweeps the `fdb::wrappers` subsystem from benches and the
/// CLI without touching the workload code.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WrapperOpt {
    #[default]
    Bare,
    /// [`crate::fdb::wrappers::TieredStore`]: a fast front tier absorbs
    /// writes ahead of the system's own store. On Lustre the front is a
    /// POSIX store on a dedicated `/scm` burst-buffer root; elsewhere a
    /// second instance of the system's store doubles as the absorbing
    /// tier.
    Tiered,
    /// [`crate::fdb::wrappers::ReplicatedStore`] over n instances of
    /// the system's store.
    Replicated(usize),
    /// [`crate::fdb::wrappers::ShardedCatalogue`] over n instances of
    /// the system's catalogue.
    Sharded(usize),
}

impl WrapperOpt {
    pub fn label(self) -> String {
        match self {
            WrapperOpt::Bare => "bare".to_string(),
            WrapperOpt::Tiered => "tiered".to_string(),
            WrapperOpt::Replicated(n) => format!("replicated-{n}"),
            WrapperOpt::Sharded(n) => format!("sharded-{n}"),
        }
    }
}

pub struct Deployment {
    pub sim: Sim,
    pub cluster: Rc<Cluster>,
    pub system: SystemUnderTest,
    pub kind: SystemKind,
    pub testbed: Testbed,
    pub wrapper: WrapperOpt,
    /// I/O-depth profile applied to every FDB instance built from this
    /// deployment (queue depth + POSIX index caching)
    pub io: IoProfile,
    /// Seeded fault plan wrapped around the BASE backend of every FDB
    /// instance built from this deployment ([`crate::fdb::fault`]); None
    /// = no fault injection
    pub fault: Option<FaultPlan>,
    /// Shared telemetry registry attached to every FDB instance built
    /// from this deployment ([`crate::fdb::telemetry`]); None = metrics
    /// off (the zero-overhead default)
    pub metrics: Option<MetricsRegistry>,
    /// Replica read routing applied to every replicated store built
    /// from this deployment; None = the wrapper's default (round-robin)
    pub read_policy: Option<ReadPolicy>,
    /// Retry/backoff/deadline/hedging/quarantine policy applied to
    /// every FDB instance built from this deployment; None = all off
    pub resilience: Option<ResilienceProfile>,
}

/// Redundancy options for Figs 4.27/4.28 (mapped per system).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RedundancyOpt {
    #[default]
    None,
    Replica2,
    Ec2p1,
}

pub fn deploy(
    testbed: Testbed,
    kind: SystemKind,
    servers: usize,
    clients: usize,
    redundancy: RedundancyOpt,
) -> Deployment {
    let sim = Sim::new();
    // Ceph is TCP-only; Lustre on NEXTGenIO uses LNET over OPA (fast);
    // DAOS uses PSM2 natively.
    let tcp_only = matches!(kind, SystemKind::Ceph);
    let cluster = Rc::new(build_cluster(
        testbed,
        servers,
        clients,
        kind.extra_md_node(),
        tcp_only,
    ));
    let system = match kind {
        SystemKind::Lustre => {
            SystemUnderTest::Lustre(Lustre::deploy(&sim, &cluster, LustreConfig::default()))
        }
        SystemKind::Daos => {
            let d = Daos::deploy(&sim, &cluster, DaosConfig::default());
            d.create_pool("fdb");
            SystemUnderTest::Daos(d)
        }
        SystemKind::Ceph => {
            let c = Ceph::deploy(&sim, &cluster, CephConfig::default());
            let red = match redundancy {
                RedundancyOpt::None => Redundancy::None,
                RedundancyOpt::Replica2 => Redundancy::Replica(2),
                RedundancyOpt::Ec2p1 => Redundancy::Erasure(2, 1),
            };
            // ~100 PGs per OSD sweet spot
            let pgs = (servers * 100).next_power_of_two().max(64);
            let pool = c.create_pool("fdb", pgs, red);
            SystemUnderTest::Ceph(c, pool)
        }
        SystemKind::Null => SystemUnderTest::Null(SharedNullCatalogue::new()),
    };
    Deployment {
        sim,
        cluster,
        system,
        kind,
        testbed,
        wrapper: WrapperOpt::Bare,
        io: IoProfile::default(),
        fault: None,
        metrics: None,
        read_policy: None,
        resilience: None,
    }
}

impl Deployment {
    pub fn client_nodes(&self) -> Vec<Rc<Node>> {
        self.cluster.client_nodes().cloned().collect()
    }

    /// Layer a composable backend wrapper over the deployment's base
    /// backend for every FDB instance subsequently built from it.
    pub fn with_wrapper(mut self, wrapper: WrapperOpt) -> Deployment {
        self.wrapper = wrapper;
        self
    }

    /// Set the full I/O-depth profile for every FDB instance built from
    /// this deployment (coordinator, benches, I/O servers alike).
    pub fn with_io(mut self, io: IoProfile) -> Deployment {
        self.io = io;
        self
    }

    /// Convenience: just the queue depth.
    pub fn with_io_depth(mut self, depth: usize) -> Deployment {
        self.io.depth = depth;
        self
    }

    /// Inject seeded faults into every FDB instance built from this
    /// deployment. The plan wraps the BASE backend — *inside* any
    /// wrapper — so a replicated deployment's replicas each draw an
    /// independent fault stream (a dead replica, not a dead store).
    pub fn with_fault(mut self, plan: FaultPlan) -> Deployment {
        self.fault = Some(plan);
        self
    }

    /// Attach a shared [`MetricsRegistry`] to every FDB instance built
    /// from this deployment: every client process reports into one
    /// registry, so the dumped histograms aggregate the whole run.
    pub fn with_metrics(mut self, reg: &MetricsRegistry) -> Deployment {
        self.metrics = Some(reg.clone());
        self
    }

    /// Route replica reads for every replicated store built from this
    /// deployment (e.g. [`ReadPolicy::Fastest`] for EWMA-latency
    /// routing, the policy the per-replica histograms feed).
    pub fn with_read_policy(mut self, policy: ReadPolicy) -> Deployment {
        self.read_policy = Some(policy);
        self
    }

    /// Apply a [`ResilienceProfile`] to every FDB instance built from
    /// this deployment: engine retry/backoff and per-op deadlines, plus
    /// hedged reads and replica quarantine on replicated wrappers.
    pub fn with_resilience(mut self, res: ResilienceProfile) -> Deployment {
        self.resilience = Some(res);
        self
    }

    /// The unwrapped [`BackendConfig`] of the deployed system.
    fn base_config(&self) -> BackendConfig {
        match &self.system {
            SystemUnderTest::Lustre(fs) => BackendConfig::Posix {
                fs: fs.clone(),
                root: "/fdb".to_string(),
            },
            SystemUnderTest::Daos(d) => BackendConfig::Daos {
                daos: d.clone(),
                pool: "fdb".to_string(),
                hash_oids: false,
            },
            SystemUnderTest::Ceph(c, pool) => BackendConfig::Rados {
                ceph: c.clone(),
                pool: pool.clone(),
                store: crate::fdb::rados::store::RadosStoreConfig::default(),
            },
            SystemUnderTest::Null(cat) => BackendConfig::SharedNull(cat.clone()),
        }
    }

    /// The front-tier config for [`WrapperOpt::Tiered`]: on Lustre a
    /// POSIX store on a dedicated burst-buffer root; elsewhere a second
    /// instance of the system's own store stands in for the fast tier.
    fn front_tier_config(&self) -> BackendConfig {
        match &self.system {
            SystemUnderTest::Lustre(fs) => BackendConfig::Posix {
                fs: fs.clone(),
                root: "/scm".to_string(),
            },
            _ => self.base_config(),
        }
    }

    /// The default [`BackendConfig`] for this deployment's system with
    /// the selected wrapper applied — the single place mapping a
    /// deployed system to FDB backends.
    pub fn backend_config(&self) -> BackendConfig {
        let mut base = self.base_config();
        if let Some(plan) = &self.fault {
            base = BackendConfig::Fault {
                inner: Box::new(base),
                plan: plan.clone(),
            };
        }
        match self.wrapper {
            WrapperOpt::Bare => base,
            WrapperOpt::Tiered => BackendConfig::Tiered {
                front: Box::new(self.front_tier_config()),
                back: Box::new(base),
            },
            WrapperOpt::Replicated(copies) => BackendConfig::Replicated {
                inner: Box::new(base),
                copies,
            },
            WrapperOpt::Sharded(shards) => BackendConfig::Sharded {
                inner: Box::new(base),
                shards,
            },
        }
    }

    /// Shared builder plumbing: backend + io + optional telemetry
    /// registry and replica read policy.
    fn builder(&self, node: &Rc<Node>) -> FdbBuilder {
        let mut b = FdbBuilder::new(&self.sim)
            .node(node)
            .backend(self.backend_config())
            .io(self.io);
        if let Some(reg) = &self.metrics {
            b = b.metrics(reg);
        }
        if let Some(policy) = self.read_policy {
            b = b.read_policy(policy);
        }
        if let Some(res) = self.resilience {
            b = b.resilience(res);
        }
        b
    }

    /// One FDB instance (per simulated process) on `node`.
    pub fn fdb(&self, node: &Rc<Node>) -> Fdb {
        self.builder(node)
            .build()
            .expect("deployment backend config is valid")
    }

    /// Like [`Deployment::fdb`] with a shared trace collector attached.
    pub fn fdb_traced(&self, node: &Rc<Node>, trace: &Trace) -> Fdb {
        self.builder(node)
            .trace(trace)
            .build()
            .expect("deployment backend config is valid")
    }
}

/// Shared span collector used by benchmark client processes.
pub type Spans = Rc<RefCell<Vec<(SimTime, SimTime, u64)>>>;

pub fn new_spans() -> Spans {
    Rc::new(RefCell::new(Vec::new()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deploy_each_kind() {
        for kind in [
            SystemKind::Lustre,
            SystemKind::Daos,
            SystemKind::Ceph,
            SystemKind::Null,
        ] {
            let d = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
            assert_eq!(d.client_nodes().len(), 4);
            assert_eq!(d.kind, kind);
        }
    }

    #[test]
    fn null_deployment_shares_one_index_across_processes() {
        let d = deploy(Testbed::Gcp, SystemKind::Null, 1, 2, RedundancyOpt::None);
        let nodes = d.client_nodes();
        let mut w = d.fdb(&nodes[0]);
        let mut r = d.fdb(&nodes[1]);
        d.sim.spawn(async move {
            let id = crate::fdb::schema::example_identifier();
            w.archive(&id, vec![1u8; 64]).await.unwrap();
            // a *different* FDB instance of the same deployment sees it
            let h = r.retrieve(&id).await.unwrap().expect("shared index");
            assert_eq!(r.read(&h).await.unwrap().len(), 64);
        });
        d.sim.run();
    }

    #[test]
    fn wrapped_configs_build_and_describe() {
        let d = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
        for (wrapper, shape) in [
            (WrapperOpt::Bare, "posix"),
            (WrapperOpt::Tiered, "tiered(posix,posix)"),
            (WrapperOpt::Replicated(2), "replicated2(posix)"),
            (WrapperOpt::Sharded(4), "sharded4(posix)"),
        ] {
            let d2 = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
                .with_wrapper(wrapper);
            assert_eq!(d2.backend_config().describe(), shape);
            let node = d2.client_nodes()[0].clone();
            let _ = d2.fdb(&node); // constructible
        }
        assert_eq!(d.backend_config().describe(), "posix");
    }

    #[test]
    fn ceph_gets_md_node_daos_does_not() {
        let c = deploy(Testbed::Gcp, SystemKind::Ceph, 2, 2, RedundancyOpt::None);
        assert_eq!(c.cluster.metadata_nodes().count(), 1);
        let d = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
        assert_eq!(d.cluster.metadata_nodes().count(), 0);
    }
}
