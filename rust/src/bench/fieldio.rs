//! Field I/O (thesis Appendix B): the proof-of-concept pair of functions
//! — write-and-index / de-reference-and-read a weather field — used for
//! the early DAOS assessment (Figs 4.8–4.11) and the client-overhead
//! measurement with a dummy libdaos (Fig 4.30).

use super::scenario::{new_spans, Deployment, SystemUnderTest};
use super::{aggregate_bw, BwResult};
use crate::daos::{ObjClass, Oid};
use crate::lustre::StripeSpec;
use crate::sim::exec::WaitGroup;
use crate::util::content::Bytes;

#[derive(Clone, Copy, Debug)]
pub struct FieldIoConfig {
    pub procs_per_node: usize,
    pub nfields: usize,
    pub field_size: u64,
    /// DAOS object class for the field arrays (Fig 4.10 sharding sweep)
    pub array_class: ObjClass,
    /// zero-cost server interactions ("dummy libdaos", Fig 4.30)
    pub dummy: bool,
    /// run writers and readers concurrently (Fig 4.9)
    pub contention: bool,
}

impl Default for FieldIoConfig {
    fn default() -> Self {
        FieldIoConfig {
            procs_per_node: 8,
            nfields: 100,
            field_size: 1 << 20,
            array_class: ObjClass::S1,
            dummy: false,
            contention: false,
        }
    }
}

/// One Field I/O process: write fields + index entries, or de-reference
/// + read them back.
pub fn run(dep: &Deployment, cfg: FieldIoConfig) -> BwResult {
    let clients = dep.client_nodes();
    let mut result = BwResult::default();
    let phases: Vec<&str> = if cfg.contention {
        vec!["prepopulate", "concurrent"]
    } else {
        vec!["write", "read"]
    };
    for phase in phases {
        let wspans = new_spans();
        let rspans = new_spans();
        let half = clients.len() / 2;
        let participants = match phase {
            "prepopulate" => half.max(1) * cfg.procs_per_node,
            "concurrent" => clients.len() * cfg.procs_per_node,
            _ => clients.len() * cfg.procs_per_node,
        };
        let wg = WaitGroup::new(participants);
        for (ni, node) in clients.iter().enumerate() {
            for p in 0..cfg.procs_per_node {
                let write = match phase {
                    "write" => true,
                    "read" => false,
                    "prepopulate" => {
                        if ni >= half.max(1) {
                            continue;
                        }
                        true
                    }
                    _ => ni < half, // concurrent: first half writes
                };
                let pid = ni * cfg.procs_per_node + p;
                // member tag: in concurrent mode writers write fresh ids,
                // readers read the pre-populated ones
                let tag = if phase == "concurrent" && write {
                    pid + 100_000
                } else if phase == "concurrent" {
                    (ni - half) * cfg.procs_per_node + p
                } else {
                    pid
                };
                let sim = dep.sim.clone();
                let spans = if write { wspans.clone() } else { rspans.clone() };
                let wg = wg.clone();
                match &dep.system {
                    SystemUnderTest::Daos(d) => {
                        let d = d.clone();
                        let node = node.clone();
                        let dummy = cfg.dummy;
                        dep.sim.spawn(async move {
                            let cli = if dummy {
                                d.dummy_client(&node)
                            } else {
                                d.client(&node)
                            };
                            let pool = cli.pool_connect("fdb").await.unwrap();
                            let cont = cli
                                .cont_create_with_label(&pool, "fieldio")
                                .await
                                .unwrap();
                            let kv = cli.kv_open(
                                &cont,
                                Oid::new(4, tag as u64),
                                ObjClass::S1,
                            );
                            let t0 = sim.now();
                            for i in 0..cfg.nfields {
                                let name = format!("fld-{tag}-{i}");
                                if write {
                                    // write field array + insert index entry
                                    let oid = cli.alloc_oid(&cont).await;
                                    let arr = cli.array_open_with_attr(
                                        &cont,
                                        oid,
                                        cfg.array_class,
                                    );
                                    cli.array_write_data(
                                        &arr,
                                        0,
                                        Bytes::virt(cfg.field_size, tag as u64 * 77 + i as u64),
                                    )
                                    .await;
                                    let mut loc = Vec::with_capacity(16);
                                    loc.extend_from_slice(&oid.hi.to_le_bytes());
                                    loc.extend_from_slice(&oid.lo.to_le_bytes());
                                    cli.kv_put(&kv, &name, &loc).await;
                                } else {
                                    // de-reference then read
                                    let loc =
                                        cli.kv_get(&kv, &name).await.unwrap().unwrap();
                                    let oid = Oid::new(
                                        u64::from_le_bytes(loc[0..8].try_into().unwrap()),
                                        u64::from_le_bytes(loc[8..16].try_into().unwrap()),
                                    );
                                    let arr = cli.array_open_with_attr(
                                        &cont,
                                        oid,
                                        cfg.array_class,
                                    );
                                    let got = cli
                                        .array_read(&arr, 0, cfg.field_size)
                                        .await
                                        .unwrap();
                                    assert_eq!(got.len(), cfg.field_size);
                                }
                            }
                            spans.borrow_mut().push((
                                t0,
                                sim.now(),
                                cfg.nfields as u64 * cfg.field_size,
                            ));
                            wg.done();
                        });
                    }
                    SystemUnderTest::Lustre(fs) => {
                        // Lustre equivalent: per-process data file + a
                        // per-process index file of (name, offset) records
                        let fs = fs.clone();
                        let node = node.clone();
                        dep.sim.spawn(async move {
                            let mut cli = fs.client(&node);
                            let _ = cli.mkdir("/fieldio").await;
                            let data_path = format!("/fieldio/d{tag}");
                            let idx_path = format!("/fieldio/i{tag}");
                            let t0 = sim.now();
                            if write {
                                let dfd = cli
                                    .create(&data_path, StripeSpec::fdb_data())
                                    .await
                                    .unwrap();
                                let ifd = cli
                                    .create(&idx_path, StripeSpec::default_layout())
                                    .await
                                    .unwrap();
                                for i in 0..cfg.nfields {
                                    let off = cli
                                        .write_data(
                                            &dfd,
                                            Bytes::virt(
                                                cfg.field_size,
                                                tag as u64 * 77 + i as u64,
                                            ),
                                        )
                                        .await
                                        .unwrap();
                                    cli.write(&ifd, &off.to_le_bytes()).await.unwrap();
                                }
                                cli.fdatasync(&dfd).await.unwrap();
                                cli.fdatasync(&ifd).await.unwrap();
                            } else {
                                let ifd = cli.open(&idx_path).await.unwrap().unwrap();
                                let dfd = cli.open(&data_path).await.unwrap().unwrap();
                                for i in 0..cfg.nfields {
                                    let rec =
                                        cli.read(&ifd, i as u64 * 8, 8).await.unwrap();
                                    let off = u64::from_le_bytes(
                                        rec.to_vec().try_into().unwrap(),
                                    );
                                    let got = cli
                                        .read(&dfd, off, cfg.field_size)
                                        .await
                                        .unwrap();
                                    assert_eq!(got.len(), cfg.field_size);
                                }
                            }
                            spans.borrow_mut().push((
                                t0,
                                sim.now(),
                                cfg.nfields as u64 * cfg.field_size,
                            ));
                            wg.done();
                        });
                    }
                    SystemUnderTest::Ceph(..) | SystemUnderTest::Null(_) => {
                        panic!("Field I/O was a DAOS/Lustre PoC (thesis App. B)")
                    }
                }
            }
        }
        dep.sim.run();
        match phase {
            "write" | "prepopulate" => {
                result.write_bw = aggregate_bw(&wspans.borrow());
            }
            "read" => {
                result.read_bw = aggregate_bw(&rspans.borrow());
            }
            _ => {
                // concurrent: both measured in the same window
                result.write_bw = aggregate_bw(&wspans.borrow());
                result.read_bw = aggregate_bw(&rspans.borrow());
            }
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::scenario::{deploy, RedundancyOpt, SystemKind};
    use crate::hw::profiles::Testbed;

    fn cfg() -> FieldIoConfig {
        FieldIoConfig {
            procs_per_node: 2,
            nfields: 20,
            field_size: 512 << 10,
            ..Default::default()
        }
    }

    #[test]
    fn fieldio_daos_and_lustre() {
        for kind in [SystemKind::Daos, SystemKind::Lustre] {
            let dep = deploy(Testbed::NextGenIo, kind, 2, 2, RedundancyOpt::None);
            let r = run(&dep, cfg());
            assert!(r.write_bw > 0.0 && r.read_bw > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn dummy_daos_much_faster() {
        let real = {
            let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
            run(&dep, cfg())
        };
        let dummy = {
            let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
            let mut c = cfg();
            c.dummy = true;
            run(&dep, c)
        };
        assert!(
            dummy.write_bw > 5.0 * real.write_bw,
            "dummy {} vs real {}",
            dummy.gibs_w(),
            real.gibs_w()
        );
    }

    #[test]
    fn contention_mode_runs() {
        let dep = deploy(Testbed::NextGenIo, SystemKind::Daos, 2, 4, RedundancyOpt::None);
        let mut c = cfg();
        c.contention = true;
        let r = run(&dep, c);
        assert!(r.write_bw > 0.0 && r.read_bw > 0.0);
    }

    #[test]
    fn sharding_class_sweep_runs() {
        for class in [ObjClass::S1, ObjClass::S2, ObjClass::Sx] {
            let dep = deploy(Testbed::NextGenIo, SystemKind::Daos, 2, 2, RedundancyOpt::None);
            let mut c = cfg();
            c.array_class = class;
            let r = run(&dep, c);
            assert!(r.write_bw > 0.0, "{class:?}");
        }
    }
}
