//! `fdbctl` — the leader binary: runs benchmarks, figure regeneration,
//! and the end-to-end operational NWP workflow on the simulated testbeds.

use fdbr::coordinator;
use fdbr::util::cli::Args;

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        println!("{}", coordinator::usage());
        std::process::exit(2);
    }
    let cmd = raw.remove(0);
    let args = Args::parse(raw);
    let result = match cmd.as_str() {
        "figures" => coordinator::cmd_figures(&args),
        "hammer" => coordinator::cmd_hammer(&args),
        "trace" => coordinator::cmd_trace(&args),
        "metrics" => coordinator::cmd_metrics(&args),
        "crash" => coordinator::cmd_crash(&args),
        "degrade" => coordinator::cmd_degrade(&args),
        "fsck" => coordinator::cmd_fsck(&args),
        "ior" => coordinator::cmd_ior(&args),
        "fieldio" => coordinator::cmd_fieldio(&args),
        "opsrun" => coordinator::cmd_opsrun(&args),
        "admin" => coordinator::cmd_admin(&args),
        "help" | "--help" | "-h" => {
            println!("{}", coordinator::usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n\n{}", coordinator::usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
