//! Self-contained deterministic PRNG (no `rand` crate offline).
//!
//! SplitMix64 for seeding, Xoshiro256** as the main generator — the same
//! combination `rand`'s SmallRng family uses. All simulation randomness
//! (placement jitter, workload shuffles, synthetic field noise) flows
//! through this module so runs are reproducible from a single seed.

/// SplitMix64 step: used to expand a single u64 seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per simulated process).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u64 in `[0, n)` (Lemire's method, bias-free for our n ranges).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; negligible bias for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)` (integer).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value; the pair's twin dropped).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill a byte buffer with pseudorandom data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(5);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
