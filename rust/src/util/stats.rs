//! Small statistics helpers used by the bench harness and reports.

/// Running summary of a sample (count/mean/min/max and percentiles on demand).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { values: Vec::new() }
    }

    pub fn add(&mut self, x: f64) {
        self.values.push(x);
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Percentile by **nearest-rank** on the sorted sample, `p` in
    /// [0,100]: the value at 1-based rank `ceil(p/100 * n)`, clamped to
    /// the sample (p=0 → minimum, p=100 → maximum). No interpolation —
    /// every percentile is an observed sample, and the telemetry
    /// histograms ([`crate::fdb::telemetry`]) use the same rule, so a
    /// bench p99 and a registry p99 over the same sample agree exactly.
    /// Total order via `f64::total_cmp`, so NaN samples (e.g. a rate
    /// computed over a zero-length span) sort last instead of panicking
    /// the comparator.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(f64::total_cmp);
        sorted[nearest_rank_index(p, sorted.len())]
    }
}

/// The 0-based index of the nearest-rank percentile `p` (in [0,100]) in
/// a sorted sample of `n` elements: `ceil(p/100 * n) - 1`, clamped to
/// `[0, n-1]`. Shared rule between [`Summary::percentile`] and the
/// telemetry histograms so both report the same value on one sample.
pub fn nearest_rank_index(p: f64, n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    rank.clamp(1, n) - 1
}

/// Format a throughput in bytes/sec as a human-readable GiB/s string.
pub fn gibs(bytes_per_sec: f64) -> String {
    format!("{:7.2} GiB/s", bytes_per_sec / (1u64 << 30) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811388).abs() < 1e-5);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn empty_summary_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic_percentile() {
        // regression: `partial_cmp().unwrap()` used to panic on NaN
        let mut s = Summary::new();
        for x in [3.0, f64::NAN, 1.0, 2.0] {
            s.add(x);
        }
        // finite samples keep their order; NaN sorts last (total_cmp)
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 2.0); // rank ceil(0.5*4)=2 of [1,2,3,NaN]
        assert!(s.percentile(100.0).is_nan());
    }

    #[test]
    fn nearest_rank_n1() {
        // n=1: every percentile is the single sample
        let mut s = Summary::new();
        s.add(7.0);
        for p in [0.0, 1.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(s.percentile(p), 7.0, "p{p}");
        }
    }

    #[test]
    fn nearest_rank_n2() {
        // n=2: rank ceil(p/100*2) — p<=50 hits the lower sample, p>50
        // the upper; no interpolation ever
        let mut s = Summary::new();
        s.add(10.0);
        s.add(20.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(50.0), 10.0);
        assert_eq!(s.percentile(50.1), 20.0);
        assert_eq!(s.percentile(99.0), 20.0);
        assert_eq!(s.percentile(100.0), 20.0);
    }

    #[test]
    fn nearest_rank_n100() {
        // n=100 over 1..=100: pN is exactly the N-th sample (rank = N)
        let mut s = Summary::new();
        for x in 1..=100 {
            s.add(x as f64);
        }
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(95.0), 95.0);
        assert_eq!(s.percentile(99.0), 99.0);
        // p99.9: rank ceil(99.9) = 100 → the maximum
        assert_eq!(s.percentile(99.9), 100.0);
        assert_eq!(s.percentile(1.0), 1.0);
        assert_eq!(s.percentile(0.0), 1.0);
    }

    #[test]
    fn nearest_rank_index_clamps() {
        assert_eq!(nearest_rank_index(0.0, 5), 0);
        assert_eq!(nearest_rank_index(100.0, 5), 4);
        assert_eq!(nearest_rank_index(50.0, 0), 0);
    }
}
