//! Byte-size formatting/parsing helpers (KiB/MiB/GiB, powers of two).

pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;

/// Format a byte count compactly: `1.00 MiB`, `512 B`, `3.50 GiB`.
pub fn fmt_bytes(n: u64) -> String {
    if n >= TIB {
        format!("{:.2} TiB", n as f64 / TIB as f64)
    } else if n >= GIB {
        format!("{:.2} GiB", n as f64 / GIB as f64)
    } else if n >= MIB {
        format!("{:.2} MiB", n as f64 / MIB as f64)
    } else if n >= KIB {
        format!("{:.2} KiB", n as f64 / KIB as f64)
    } else {
        format!("{n} B")
    }
}

/// Parse `"1MiB"`, `"4K"`, `"512"`, `"2g"` into bytes.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !c.is_ascii_digit() && c != '.')?;
    let (num, unit) = if split == 0 {
        return None;
    } else {
        s.split_at(split)
    };
    let v: f64 = num.parse().ok()?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kib" | "kb" => KIB,
        "m" | "mib" | "mb" => MIB,
        "g" | "gib" | "gb" => GIB,
        "t" | "tib" | "tb" => TIB,
        _ => return None,
    };
    Some((v * mult as f64) as u64)
}

/// Parse with fallback for plain integers (no unit suffix).
pub fn parse_bytes_or_plain(s: &str) -> Option<u64> {
    s.trim().parse::<u64>().ok().or_else(|| parse_bytes(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_roundtrip() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(MIB), "1.00 MiB");
        assert_eq!(fmt_bytes(GIB * 3 + GIB / 2), "3.50 GiB");
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_bytes("1MiB"), Some(MIB));
        assert_eq!(parse_bytes("4K"), Some(4 * KIB));
        assert_eq!(parse_bytes("2g"), Some(2 * GIB));
        assert_eq!(parse_bytes("1.5M"), Some((1.5 * MIB as f64) as u64));
        assert_eq!(parse_bytes("junk"), None);
        assert_eq!(parse_bytes_or_plain("12345"), Some(12345));
    }
}
