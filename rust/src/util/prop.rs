//! Miniature property-testing harness (no `proptest` offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` against `cases` random
//! inputs produced by `gen`. On failure it performs a simple greedy
//! shrink (if a `Shrink` impl exists) and panics with the offending case.

use crate::util::rng::Rng;

/// Types that can propose smaller variants of themselves for shrinking.
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl<T: Clone + Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            // drop halves
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[self.len() / 2..].to_vec());
            // drop one element
            if self.len() <= 16 {
                for i in 0..self.len() {
                    let mut v = self.clone();
                    v.remove(i);
                    out.push(v);
                }
            }
            // shrink one element
            for (i, first) in self
                .iter()
                .enumerate()
                .take(8)
                .flat_map(|(i, x)| x.shrink().into_iter().next().map(|s| (i, s)))
                .collect::<Vec<_>>()
            {
                let mut v = self.clone();
                v[i] = first;
                out.push(v);
            }
        }
        out
    }
}

/// Run a property over random cases; shrink + panic on first failure.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Clone + std::fmt::Debug + Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case_no in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            // greedy shrink
            let mut best = input.clone();
            'outer: loop {
                for cand in best.shrink() {
                    if !prop(&cand) {
                        best = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case={case_no})\n  original: {input:?}\n  shrunk:   {best:?}"
            );
        }
    }
}

/// Variant without shrinking for non-`Shrink` inputs.
pub fn check_no_shrink<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case_no in 0..cases {
        let input = gen(&mut rng);
        assert!(
            prop(&input),
            "property failed (seed={seed}, case={case_no})\n  input: {input:?}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 200, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_shrinks() {
        check(2, 200, |r| r.below(1000), |&x| x < 500);
    }

    #[test]
    fn vec_shrink_produces_smaller() {
        let v: Vec<u64> = vec![5, 6, 7, 8];
        assert!(v.shrink().iter().all(|s| s.len() <= v.len()));
    }
}
