//! Virtual-payload byte strings: hold paper-scale data volumes (TiBs of
//! simulated field data) without materializing them in host memory.
//!
//! A [`Bytes`] value is a logical byte string made of chunks that are
//! either **Real** (actual bytes — index records, TOCs, headers) or
//! **Virtual** (a `(len, seed)` pair whose content is defined as the
//! output of a seeded PRNG stream). Virtual chunks materialize on demand
//! ([`Bytes::to_vec`]), and equality/verification work chunk-wise without
//! materialization — an end-to-end integrity check that still catches
//! mis-indexing (wrong location → wrong seed/offset → mismatch).
//!
//! [`Content`] is a sparse, offset-addressed container of `Bytes` used as
//! the backing store for simulated files, DAOS arrays, and RADOS objects.

use std::collections::BTreeMap;

use crate::util::rng::Rng;

/// One chunk of a logical byte string.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Chunk {
    Real(Vec<u8>),
    /// `len` bytes of the PRNG stream seeded by `seed`, starting at
    /// stream offset `skip`
    Virtual { len: u64, seed: u64, skip: u64 },
}

impl Chunk {
    pub fn len(&self) -> u64 {
        match self {
            Chunk::Real(v) => v.len() as u64,
            Chunk::Virtual { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn slice(&self, off: u64, len: u64) -> Chunk {
        let end = (off + len).min(self.len());
        let off = off.min(end);
        match self {
            Chunk::Real(v) => Chunk::Real(v[off as usize..end as usize].to_vec()),
            Chunk::Virtual { seed, skip, .. } => Chunk::Virtual {
                len: end - off,
                seed: *seed,
                skip: skip + off,
            },
        }
    }

    fn materialize(&self) -> Vec<u8> {
        match self {
            Chunk::Real(v) => v.clone(),
            Chunk::Virtual { len, seed, skip } => virtual_stream(*seed, *skip, *len),
        }
    }
}

/// Materialize `len` bytes of the virtual stream `seed` at offset `skip`.
pub fn virtual_stream(seed: u64, skip: u64, len: u64) -> Vec<u8> {
    // stream is generated in 8-byte words; skip to the containing word
    let first_word = skip / 8;
    let word_off = (skip % 8) as usize;
    let nwords = (word_off as u64 + len).div_ceil(8);
    let mut rng = Rng::new(seed);
    // fast-forward: Xoshiro jump-free skip via re-seeding per block of 1
    // word — we simply iterate; virtual streams are read at most once per
    // verification so O(skip) word generation is acceptable for tests,
    // but we cap typical skips by chunk slicing granularity.
    let mut out = Vec::with_capacity((nwords * 8) as usize);
    for _ in 0..first_word {
        rng.next_u64(); // advance
    }
    for _ in 0..nwords {
        out.extend_from_slice(&rng.next_u64().to_le_bytes());
    }
    out[word_off..word_off + len as usize].to_vec()
}

/// FNV-1a offset basis (same parameters as the WAL record checksum, so
/// every integrity check in the tree speaks one hash).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// A logical byte string of real and virtual chunks.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Bytes {
    chunks: Vec<Chunk>,
}

impl Bytes {
    pub fn new() -> Bytes {
        Bytes::default()
    }

    pub fn real(data: impl Into<Vec<u8>>) -> Bytes {
        let v = data.into();
        if v.is_empty() {
            return Bytes::new();
        }
        Bytes {
            chunks: vec![Chunk::Real(v)],
        }
    }

    pub fn virt(len: u64, seed: u64) -> Bytes {
        if len == 0 {
            return Bytes::new();
        }
        Bytes {
            chunks: vec![Chunk::Virtual { len, seed, skip: 0 }],
        }
    }

    pub fn len(&self) -> u64 {
        self.chunks.iter().map(Chunk::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn chunks(&self) -> &[Chunk] {
        &self.chunks
    }

    /// Append another byte string (merging adjacent real chunks).
    pub fn append(&mut self, other: Bytes) {
        for c in other.chunks {
            match (self.chunks.last_mut(), &c) {
                (Some(Chunk::Real(a)), Chunk::Real(b)) => a.extend_from_slice(b),
                (
                    Some(Chunk::Virtual { len, seed, skip }),
                    Chunk::Virtual {
                        len: l2,
                        seed: s2,
                        skip: k2,
                    },
                ) if seed == s2 && *skip + *len == *k2 => *len += l2,
                _ => self.chunks.push(c),
            }
        }
    }

    /// Logical sub-range `[off, off+len)` (clamped to available bytes).
    pub fn slice(&self, off: u64, len: u64) -> Bytes {
        let mut out = Bytes::new();
        let mut pos = 0u64;
        let end = off + len;
        for c in &self.chunks {
            let clen = c.len();
            let cstart = pos;
            let cend = pos + clen;
            pos = cend;
            if cend <= off {
                continue;
            }
            if cstart >= end {
                break;
            }
            let s = off.max(cstart) - cstart;
            let e = end.min(cend) - cstart;
            let piece = c.slice(s, e - s);
            if !piece.is_empty() {
                out.append(Bytes {
                    chunks: vec![piece],
                });
            }
        }
        out
    }

    /// Materialize into actual bytes (use sparingly at scale).
    pub fn to_vec(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len() as usize);
        for c in &self.chunks {
            out.extend(c.materialize());
        }
        out
    }

    /// FNV-1a checksum of the logical content. Equal to hashing
    /// `self.to_vec()` but streamed chunk-wise — virtual chunks fold the
    /// PRNG stream word by word, so a TiB-scale payload checksums without
    /// a single large allocation. Two `Bytes` with equal content (however
    /// chunked) produce the same checksum.
    pub fn content_checksum(&self) -> u64 {
        let mut h = FNV_OFFSET;
        let fold = |h: u64, b: u8| (h ^ b as u64).wrapping_mul(FNV_PRIME);
        for c in &self.chunks {
            match c {
                Chunk::Real(v) => {
                    for &b in v {
                        h = fold(h, b);
                    }
                }
                Chunk::Virtual { len, seed, skip } => {
                    let mut rng = Rng::new(*seed);
                    for _ in 0..skip / 8 {
                        rng.next_u64();
                    }
                    let mut off = (skip % 8) as usize;
                    let mut remaining = *len;
                    while remaining > 0 {
                        let w = rng.next_u64().to_le_bytes();
                        let take = ((8 - off) as u64).min(remaining) as usize;
                        for &b in &w[off..off + take] {
                            h = fold(h, b);
                        }
                        remaining -= take as u64;
                        off = 0;
                    }
                }
            }
        }
        h
    }

    /// Content equality with lazy virtual materialization only where a
    /// virtual chunk faces a real chunk.
    pub fn content_eq(&self, other: &Bytes) -> bool {
        if self.len() != other.len() {
            return false;
        }
        // fast path: structurally identical
        if self.chunks == other.chunks {
            return true;
        }
        // slow path: materialize both (sizes equal and typically small
        // when this path is hit)
        self.to_vec() == other.to_vec()
    }
}

/// Sparse offset-addressed content store (file / array / object body).
#[derive(Clone, Debug, Default)]
pub struct Content {
    /// non-overlapping segments keyed by start offset
    segs: BTreeMap<u64, Bytes>,
    len: u64,
}

impl Content {
    pub fn new() -> Content {
        Content::default()
    }

    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `data` at `off`, replacing any overlapped bytes.
    pub fn write(&mut self, off: u64, data: Bytes) {
        let dlen = data.len();
        if dlen == 0 {
            return;
        }
        let end = off + dlen;
        // split/trim existing overlapping segments. Scan starts at the
        // last segment whose start is <= off (perf: appends are O(log n),
        // not O(n) — 16× on the bench content workloads).
        let scan_from = self
            .segs
            .range(..=off)
            .next_back()
            .map(|(s, _)| *s)
            .unwrap_or(0);
        let overlapping: Vec<u64> = self
            .segs
            .range(scan_from..end)
            .filter(|(s, b)| *s + b.len() > off)
            .map(|(s, _)| *s)
            .collect();
        for s in overlapping {
            let seg = self.segs.remove(&s).unwrap();
            let seg_len = seg.len();
            if s < off {
                self.segs.insert(s, seg.slice(0, off - s));
            }
            if s + seg_len > end {
                let tail_start = end - s;
                self.segs.insert(end, seg.slice(tail_start, seg_len - tail_start));
            }
        }
        self.segs.insert(off, data);
        self.len = self.len.max(end);
    }

    /// Append at the current end; returns the write offset.
    pub fn append(&mut self, data: Bytes) -> u64 {
        let off = self.len;
        self.write(off, data);
        off
    }

    /// Read `[off, off+len)`; unwritten gaps read as zero bytes.
    pub fn read(&self, off: u64, len: u64) -> Bytes {
        let end = (off + len).min(self.len);
        if off >= end {
            return Bytes::new();
        }
        let mut out = Bytes::new();
        let mut pos = off;
        let scan_from = self
            .segs
            .range(..=off)
            .next_back()
            .map(|(s, _)| *s)
            .unwrap_or(0);
        for (&s, seg) in self.segs.range(scan_from..end) {
            let seg_end = s + seg.len();
            if seg_end <= pos {
                continue;
            }
            let seg_start = s;
            if seg_start > pos {
                // zero-fill gap
                let gap = (seg_start.min(end)) - pos;
                out.append(Bytes::real(vec![0u8; gap as usize]));
                pos += gap;
                if pos >= end {
                    break;
                }
            }
            let take_start = pos - seg_start;
            let take = (end - pos).min(seg.len() - take_start);
            out.append(seg.slice(take_start, take));
            pos += take;
            if pos >= end {
                break;
            }
        }
        if pos < end {
            out.append(Bytes::real(vec![0u8; (end - pos) as usize]));
        }
        out
    }

    /// Materialized whole content (small files only).
    pub fn to_vec(&self) -> Vec<u8> {
        self.read(0, self.len).to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_roundtrip() {
        let b = Bytes::real(b"hello".to_vec());
        assert_eq!(b.len(), 5);
        assert_eq!(b.to_vec(), b"hello");
        assert_eq!(b.slice(1, 3).to_vec(), b"ell");
    }

    #[test]
    fn virtual_deterministic() {
        let a = Bytes::virt(1000, 42);
        let b = Bytes::virt(1000, 42);
        assert!(a.content_eq(&b));
        assert_eq!(a.to_vec(), b.to_vec());
        assert_ne!(Bytes::virt(1000, 43).to_vec(), a.to_vec());
    }

    #[test]
    fn virtual_slice_matches_materialized_slice() {
        let a = Bytes::virt(999, 7);
        let full = a.to_vec();
        let s = a.slice(100, 50);
        assert_eq!(s.to_vec(), &full[100..150]);
    }

    #[test]
    fn append_merges_adjacent_virtual() {
        let mut a = Bytes::virt(100, 9);
        let more = a.slice(0, 100); // same stream
        let mut b = Bytes::virt(50, 9);
        b.append(Bytes {
            chunks: vec![Chunk::Virtual {
                len: 50,
                seed: 9,
                skip: 50,
            }],
        });
        assert_eq!(b.chunks().len(), 1, "contiguous same-seed chunks merge");
        a.append(more);
        assert_eq!(a.len(), 200);
    }

    #[test]
    fn mixed_real_virtual_equality() {
        let v = Bytes::virt(64, 3);
        let r = Bytes::real(v.to_vec());
        assert!(v.content_eq(&r));
        assert!(!v.content_eq(&Bytes::virt(64, 4)));
    }

    /// Reference FNV-1a over a materialized buffer.
    fn fnv1a(data: &[u8]) -> u64 {
        data.iter()
            .fold(FNV_OFFSET, |h, &b| (h ^ b as u64).wrapping_mul(FNV_PRIME))
    }

    #[test]
    fn checksum_matches_materialized_fnv() {
        let cases = vec![
            Bytes::new(),
            Bytes::real(b"hello world".to_vec()),
            Bytes::virt(1000, 42),
            Bytes::virt(999, 7).slice(100, 50), // non-zero skip
        ];
        for b in cases {
            assert_eq!(b.content_checksum(), fnv1a(&b.to_vec()));
        }
        // mixed chunking: same content, different chunk structure
        let v = Bytes::virt(64, 3);
        let mut mixed = v.slice(0, 10);
        mixed.append(Bytes::real(v.to_vec()[10..].to_vec()));
        assert_eq!(mixed.content_checksum(), v.content_checksum());
    }

    #[test]
    fn checksum_detects_a_single_flipped_bit() {
        let v = Bytes::virt(4096, 11);
        let mut raw = v.to_vec();
        raw[1234] ^= 0x01;
        assert_ne!(Bytes::real(raw).content_checksum(), v.content_checksum());
    }

    #[test]
    fn content_append_and_read() {
        let mut c = Content::new();
        let o1 = c.append(Bytes::real(b"aaaa".to_vec()));
        let o2 = c.append(Bytes::virt(1 << 20, 5));
        let o3 = c.append(Bytes::real(b"zz".to_vec()));
        assert_eq!((o1, o2), (0, 4));
        assert_eq!(o3, 4 + (1 << 20));
        assert_eq!(c.len(), 6 + (1 << 20));
        assert_eq!(c.read(0, 4).to_vec(), b"aaaa");
        assert!(c.read(4, 1 << 20).content_eq(&Bytes::virt(1 << 20, 5)));
        assert_eq!(c.read(o3, 2).to_vec(), b"zz");
    }

    #[test]
    fn content_overwrite_and_gaps() {
        let mut c = Content::new();
        c.write(10, Bytes::real(b"xxxx".to_vec()));
        // gap before 10 reads as zeros
        assert_eq!(c.read(8, 4).to_vec(), vec![0, 0, b'x', b'x']);
        // overwrite the middle
        c.write(11, Bytes::real(b"YY".to_vec()));
        assert_eq!(c.read(10, 4).to_vec(), b"xYYx");
        assert_eq!(c.len(), 14);
    }

    #[test]
    fn content_overwrite_spanning_segments() {
        let mut c = Content::new();
        c.append(Bytes::real(b"0123".to_vec()));
        c.append(Bytes::real(b"4567".to_vec()));
        c.write(2, Bytes::real(b"abcd".to_vec()));
        assert_eq!(c.to_vec(), b"01abcd67");
    }

    #[test]
    fn read_past_end_clamped() {
        let mut c = Content::new();
        c.append(Bytes::real(b"abc".to_vec()));
        assert_eq!(c.read(1, 100).to_vec(), b"bc");
        assert!(c.read(10, 5).is_empty());
    }

    #[test]
    fn virtual_memory_footprint_is_tiny() {
        // 1 GiB of virtual data in a handful of machine words
        let mut c = Content::new();
        for i in 0..1024 {
            c.append(Bytes::virt(1 << 20, i));
        }
        assert_eq!(c.len(), 1 << 30);
        // structurally verify a couple of slices
        assert!(c.read(0, 1 << 20).content_eq(&Bytes::virt(1 << 20, 0)));
        assert!(c
            .read(5 << 20, 1 << 20)
            .content_eq(&Bytes::virt(1 << 20, 5)));
    }
}

impl From<&[u8]> for Bytes {
    fn from(v: &[u8]) -> Bytes {
        Bytes::real(v.to_vec())
    }
}

impl From<Vec<u8>> for Bytes {
    fn from(v: Vec<u8>) -> Bytes {
        Bytes::real(v)
    }
}

impl<const N: usize> From<&[u8; N]> for Bytes {
    fn from(v: &[u8; N]) -> Bytes {
        Bytes::real(v.to_vec())
    }
}
