//! Minimal command-line argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    // `next_if` peeks and consumes in one step; a
                    // value-taking flag as the LAST argument falls to
                    // the flag branch, and `value_of` turns that into a
                    // usage error instead of a silent default
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// A value-taking option: `Ok(Some(v))` when given with a value,
    /// `Ok(None)` when absent, and a usage error when the flag was
    /// passed dangling (`--opt` as the last argument, or followed by
    /// another `--option`) — instead of silently falling back to a
    /// default.
    pub fn value_of(&self, name: &str) -> Result<Option<&str>, String> {
        match self.get(name) {
            Some(v) => Ok(Some(v)),
            None if self.flag(name) => Err(format!(
                "usage error: option --{name} requires a value (--{name} <value>)"
            )),
            None => Ok(None),
        }
    }

    /// Strict parsed option: the default when absent, a usage error on
    /// a dangling flag or an unparseable value — unlike [`Args::usize`]
    /// and friends, which silently fall back to the default.
    pub fn parsed_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, String> {
        match self.value_of(name)? {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                format!("usage error: invalid value `{v}` for --{name}")
            }),
        }
    }

    /// Strict byte-size option accepting unit suffixes (`--size 1MiB`).
    pub fn bytes_of(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.value_of(name)? {
            None => Ok(default),
            Some(v) => crate::util::humansize::parse_bytes_or_plain(v).ok_or_else(|| {
                format!("usage error: invalid size `{v}` for --{name}")
            }),
        }
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Byte-size option accepting unit suffixes (`--size 1MiB`).
    pub fn bytes(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(crate::util::humansize::parse_bytes_or_plain)
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        // NB: `--flag value`-style ambiguity is resolved greedily, so
        // boolean flags go after positionals (or use `--flag=`-less form
        // followed by another `--option`).
        let a = parse("run target --nodes 4 --size=1MiB --verbose");
        assert_eq!(a.positional, vec!["run", "target"]);
        assert_eq!(a.get("nodes"), Some("4"));
        assert_eq!(a.get("size"), Some("1MiB"));
        assert!(a.flag("verbose"));
        assert_eq!(a.usize("nodes", 1), 4);
        assert_eq!(a.bytes("size", 0), 1 << 20);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --check");
        assert!(a.flag("check"));
        assert_eq!(a.positional, vec!["x"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.get_or("absent", "dflt"), "dflt");
    }

    #[test]
    fn trailing_value_flag_does_not_panic_and_is_a_usage_error() {
        // `--system` as the last argument must parse cleanly (no
        // unwrap-on-missing-value code path left in the parser) ...
        let a = parse("run --system");
        assert_eq!(a.positional, vec!["run"]);
        assert!(a.flag("system"));
        assert_eq!(a.get("system"), None);
        // ... and the strict accessor turns it into a usage error
        // instead of the old silent fall-back to a default
        let err = a.value_of("system").unwrap_err();
        assert!(err.contains("--system"), "{err}");
        // present-with-value and absent both stay Ok
        let b = parse("--system daos");
        assert_eq!(b.value_of("system").unwrap(), Some("daos"));
        assert_eq!(b.value_of("testbed").unwrap(), None);
    }

    #[test]
    fn strict_numeric_accessors_reject_garbage_and_dangling_flags() {
        let a = parse("--servers 4 --size 1MiB");
        assert_eq!(a.parsed_or("servers", 1usize).unwrap(), 4);
        assert_eq!(a.parsed_or("missing", 7u32).unwrap(), 7);
        assert_eq!(a.bytes_of("size", 0).unwrap(), 1 << 20);
        assert_eq!(a.bytes_of("absent", 512).unwrap(), 512);
        // unparseable values are usage errors, not silent defaults
        let b = parse("--servers many --size huge");
        assert!(b.parsed_or("servers", 1usize).is_err());
        assert!(b.bytes_of("size", 0).is_err());
        // dangling value flags propagate the value_of usage error
        let c = parse("--servers");
        assert!(c.parsed_or("servers", 1usize).is_err());
        assert!(c.bytes_of("servers", 0).is_err());
    }
}
