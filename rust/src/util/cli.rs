//! Minimal command-line argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Byte-size option accepting unit suffixes (`--size 1MiB`).
    pub fn bytes(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(crate::util::humansize::parse_bytes_or_plain)
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        // NB: `--flag value`-style ambiguity is resolved greedily, so
        // boolean flags go after positionals (or use `--flag=`-less form
        // followed by another `--option`).
        let a = parse("run target --nodes 4 --size=1MiB --verbose");
        assert_eq!(a.positional, vec!["run", "target"]);
        assert_eq!(a.get("nodes"), Some("4"));
        assert_eq!(a.get("size"), Some("1MiB"));
        assert!(a.flag("verbose"));
        assert_eq!(a.usize("nodes", 1), 4);
        assert_eq!(a.bytes("size", 0), 1 << 20);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --check");
        assert!(a.flag("check"));
        assert_eq!(a.positional, vec!["x"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.get_or("absent", "dflt"), "dflt");
    }
}
