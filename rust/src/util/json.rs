//! Tiny JSON value type with emitter and parser (no `serde` offline).
//!
//! Used for machine-readable benchmark reports and config files. Covers
//! the full JSON grammar except surrogate-pair escapes in strings.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, lit: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(format!("expected `{lit}` at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => {
                self.eat("null")?;
                Ok(Json::Null)
            }
            Some(b't') => {
                self.eat("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.eat("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut arr = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    self.ws();
                    arr.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(arr));
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(":")?;
                    self.ws();
                    let v = self.value()?;
                    m.insert(k, v);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.peek() != Some(b'"') {
            return Err(format!("expected string at byte {}", self.i));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let j = Json::obj()
            .set("name", "fdb")
            .set("n", 42u64)
            .set("ok", true)
            .set("xs", vec![Json::Num(1.5), Json::Null]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Ab""#).unwrap();
        assert_eq!(j.as_str(), Some("Ab"));
    }
}
