//! The unified I/O engine: ONE bounded-concurrency scheduler behind
//! every batched FDB path (thesis §2.7's batched archive/retrieve, the
//! DAOS papers' queue-depth asynchrony).
//!
//! Before this module, `fdb.rs` hand-rolled four near-identical fan-outs
//! (batched archive, batched retrieve, direct retrieve, plan execution),
//! each with its own semaphore construction, session pool, `pop()`
//! panic site, in-flight accounting, and trace plumbing — accounting
//! that could silently diverge. [`IoEngine`] owns all of it exactly
//! once:
//!
//! - the **depth semaphore** ([`IoEngine::semaphore`] is the single
//!   `Resource::new("fdb/io-depth", …)` site; capacity = minted store
//!   sessions, `sessions.len().max(1)`),
//! - the **session pools** — store sessions ([`StoreSession`]) and
//!   catalogue sessions ([`CatalogueSession`]), checked out through an
//!   RAII [`Checkout`] guard that returns the session on drop and
//!   surfaces pool exhaustion as a typed [`FdbError::Backend`] instead
//!   of a panic,
//! - **in-flight instrumentation** (count + peak, admitted ops of any
//!   class — index lookups and data I/O share the one semaphore, so
//!   `inflight_peak() <= depth` covers both),
//! - **per-op-class trace/lock accounting** (span totals minus drained
//!   lock time, raw span windows via
//!   [`Trace::observe_span`](crate::sim::trace::Trace) so cross-class
//!   overlap stays observable).
//!
//! Every batched path is a thin *resolve → plan → execute* submission:
//! resolve locations (catalogue sessions run lookups at depth),
//! optionally plan (the streaming
//! [`StreamPlanner`](crate::fdb::plan::StreamPlanner) seals coalesced
//! ranges incrementally), execute over the pooled sessions. Streaming
//! plan execution means the first merged range can be *in flight while
//! later lookups are still resolving* — the whole-request pipelining
//! the contention paper credits for DAOS's edge.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};
use std::rc::Rc;
use std::task::Waker;

use crate::fdb::backend::{Catalogue, CatalogueSession, LocalBoxFuture, Store, StoreSession};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::plan::{PlanStats, StreamPlanner};
use crate::fdb::scrub::RangeCheck;
use crate::fdb::telemetry::{is_injected_fault, is_transient, EngineMetrics, MetricsRegistry};
use crate::fdb::{FdbError, ResilienceProfile};
use crate::sim::exec::{Sim, Sleep};
use crate::sim::futures::{boxed, join_all};
use crate::sim::resource::Resource;
use crate::sim::time::SimTime;
use crate::sim::trace::{OpClass, Trace};
use crate::util::content::Bytes;
use crate::util::rng::Rng;

/// RAII session checkout: holds one pooled session, pushes it back on
/// drop. Minted only under the depth semaphore, so the pool can never
/// be empty at checkout time — but if that invariant ever breaks the
/// caller gets a typed error, not a process abort.
pub(crate) struct Checkout<'a, T: ?Sized> {
    pool: &'a RefCell<Vec<Box<T>>>,
    item: Option<Box<T>>,
}

impl<'a, T: ?Sized> Checkout<'a, T> {
    fn new(pool: &'a RefCell<Vec<Box<T>>>, what: &str) -> Result<Checkout<'a, T>, FdbError> {
        match pool.borrow_mut().pop() {
            Some(item) => Ok(Checkout {
                pool,
                item: Some(item),
            }),
            None => Err(FdbError::Backend {
                backend: "io-engine",
                detail: format!("{what} session pool exhausted under the depth semaphore"),
            }),
        }
    }
}

impl<T: ?Sized> Deref for Checkout<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.item.as_deref().expect("session held until drop")
    }
}

impl<T: ?Sized> DerefMut for Checkout<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.item.as_deref_mut().expect("session held until drop")
    }
}

impl<T: ?Sized> Drop for Checkout<'_, T> {
    fn drop(&mut self) {
        if let Some(item) = self.item.take() {
            self.pool.borrow_mut().push(item);
        }
    }
}

/// RAII admission: created after the semaphore grant, releases the slot
/// and decrements the in-flight count on drop — every exit path of an
/// admitted op (success, typed error, checkout failure) restores the
/// engine's invariants the same way.
struct Admitted<'a> {
    engine: &'a IoEngine,
    sem: &'a Rc<Resource>,
}

impl Drop for Admitted<'_> {
    fn drop(&mut self) {
        let inflight = &self.engine.inflight;
        inflight.set(inflight.get() - 1);
        self.sem.release();
    }
}

/// The whole-field check set of a single-field read: one
/// [`RangeCheck`] when the location carries a content checksum, empty
/// (no verification) for legacy entries.
fn whole_checks(loc: &FieldLocation) -> Vec<RangeCheck> {
    loc.checksum()
        .map(|ck| vec![RangeCheck::whole(loc.length(), ck)])
        .unwrap_or_default()
}

/// Record the first error by *input index* — batches report the error
/// the serial path would have hit first, regardless of completion order.
fn note_failure(failed: &RefCell<Option<(usize, FdbError)>>, i: usize, e: FdbError) {
    let mut f = failed.borrow_mut();
    if f.as_ref().map(|(j, _)| i < *j).unwrap_or(true) {
        *f = Some((i, e));
    }
}

/// Run one store op under the engine's resilience policy: the op
/// expression is re-evaluated per attempt (each retry mints a fresh
/// future over the same session), raced against the per-op deadline,
/// and re-attempted with exponential backoff while the failure is
/// transient ([`is_transient`]) and attempts remain. A macro rather
/// than a method because stable Rust can't express "`FnMut` returning
/// a future that borrows the captured session" as a bound.
macro_rules! resilient {
    ($engine:expr, $class:expr, $op:expr) => {{
        let mut attempt: u32 = 0;
        loop {
            let r = $engine.with_deadline($class, $op).await;
            match r {
                Err(e) if $engine.should_retry(&e, attempt) => {
                    attempt += 1;
                    $engine.retry_backoff(attempt).await;
                }
                r => {
                    if attempt > 0 {
                        $engine.retry_outcome(r.is_ok());
                    }
                    break r;
                }
            }
        }
    }};
}

/// Races an op against its deadline timer. The op polls first, so an
/// op completing at the same virtual instant the deadline fires still
/// wins. `None` = the deadline fired; dropping the op future abandons
/// it (its backend timers fire harmlessly into the sim).
struct DeadlineRace<'a, T> {
    fut: LocalBoxFuture<'a, T>,
    timer: Sleep,
}

impl<'a, T> std::future::Future for DeadlineRace<'a, T> {
    type Output = Option<T>;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<Option<T>> {
        // Unpin: the op is already boxed and Sleep is plain state
        let this = self.get_mut();
        if let std::task::Poll::Ready(v) = this.fut.as_mut().poll(cx) {
            return std::task::Poll::Ready(Some(v));
        }
        match std::pin::Pin::new(&mut this.timer).poll(cx) {
            std::task::Poll::Ready(()) => std::task::Poll::Ready(None),
            std::task::Poll::Pending => std::task::Poll::Pending,
        }
    }
}

/// The shared bounded-concurrency scheduler. One per [`crate::fdb::Fdb`]
/// instance; interior-mutable so the executors borrow `&self` while the
/// caller keeps `&mut` access to its Store/Catalogue for the serial
/// halves.
pub(crate) struct IoEngine {
    depth: usize,
    store_pool: RefCell<Vec<Box<dyn StoreSession>>>,
    cat_pool: RefCell<Vec<Box<dyn CatalogueSession>>>,
    inflight: Cell<usize>,
    peak: Cell<usize>,
    sim: Sim,
    trace: Trace,
    /// Pre-bound per-op-class telemetry handles (`None` = metrics off,
    /// the zero-overhead default).
    metrics: Option<EngineMetrics>,
    /// The registry behind `metrics` — journal spans and the slow-op
    /// log go through it directly.
    registry: Option<MetricsRegistry>,
    /// Slow-op threshold (raw span duration, ns); 0 disables the log.
    slow_op_ns: u64,
    /// Retry/backoff/deadline policy (default: everything off).
    resilience: ResilienceProfile,
    /// Seeded jitter stream for retry backoff.
    retry_rng: RefCell<Rng>,
}

impl IoEngine {
    pub(crate) fn new(sim: &Sim) -> IoEngine {
        IoEngine {
            depth: 1,
            store_pool: RefCell::new(Vec::new()),
            cat_pool: RefCell::new(Vec::new()),
            inflight: Cell::new(0),
            peak: Cell::new(0),
            sim: sim.clone(),
            trace: Trace::new(),
            metrics: None,
            registry: None,
            slow_op_ns: 0,
            resilience: ResilienceProfile::default(),
            retry_rng: RefCell::new(Rng::new(0)),
        }
    }

    pub(crate) fn set_depth(&mut self, depth: usize) {
        self.depth = depth;
    }

    pub(crate) fn set_trace(&mut self, trace: Trace) {
        self.trace = trace;
    }

    /// Install the retry/backoff/deadline policy. The jitter stream is
    /// re-seeded from the profile so two runs with the same seed retry
    /// at identical virtual instants.
    pub(crate) fn set_resilience(&mut self, res: ResilienceProfile) {
        self.retry_rng = RefCell::new(Rng::new(res.seed).fork(0x7265_7472_79)); // "retry"
        self.resilience = res;
    }

    /// Attach a metrics registry: every admitted op records its
    /// admission wait and (lock-subtracted) service time into per-class
    /// histograms, byte counters and ok/err/fault outcome counters, a
    /// journal span, and — above `slow_op_us` — a slow-op log entry.
    /// Service times are recorded at the same sites with the same
    /// durations as [`Trace::record`], so registry histogram totals
    /// agree exactly with the trace's per-class totals.
    pub(crate) fn set_metrics(&mut self, reg: &MetricsRegistry, slow_op_us: u64) {
        self.metrics = Some(EngineMetrics::bind(reg));
        self.registry = Some(reg.clone());
        self.slow_op_ns = slow_op_us.saturating_mul(1_000);
    }

    /// Store sessions minted so far (0 until a batched op runs at
    /// depth > 1).
    pub(crate) fn store_sessions(&self) -> usize {
        self.store_pool.borrow().len()
    }

    /// High-water mark of concurrently admitted operations — catalogue
    /// lookups and store I/O share the one semaphore, so this never
    /// exceeds the configured depth.
    pub(crate) fn inflight_peak(&self) -> usize {
        self.peak.get()
    }

    /// Fill the store-session pool up to the configured depth. Returns
    /// whether the engine's fan-out paths can run; `false` (depth 1, or
    /// a backend without session support) keeps callers on the serial
    /// paths.
    pub(crate) fn ensure_store_sessions(&self, store: &mut dyn Store) -> bool {
        if self.depth <= 1 {
            return false;
        }
        let mut pool = self.store_pool.borrow_mut();
        while pool.len() < self.depth {
            match store.session() {
                Some(s) => pool.push(s),
                None => {
                    pool.clear();
                    return false;
                }
            }
        }
        true
    }

    /// Fill the catalogue-session pool up to the configured depth, so
    /// batched index lookups run at depth too. Returns whether lookups
    /// can fan out; `false` keeps them on the one serial index client
    /// (still pipelined against the data reads).
    pub(crate) fn ensure_cat_sessions(&self, catalogue: &mut dyn Catalogue) -> bool {
        if self.depth <= 1 {
            return false;
        }
        let mut pool = self.cat_pool.borrow_mut();
        while pool.len() < self.depth {
            match catalogue.session() {
                Some(s) => pool.push(s),
                None => {
                    pool.clear();
                    return false;
                }
            }
        }
        true
    }

    /// Drop the catalogue sessions (their reader-side caches with them);
    /// they are re-minted fresh on the next batched lookup.
    pub(crate) fn clear_catalogue_sessions(&self) {
        self.cat_pool.borrow_mut().clear();
    }

    /// Drain distributed-lock time accumulated by idle pooled sessions
    /// (serial-path ops share clients with prior fan-outs).
    pub(crate) fn take_pooled_lock_time(&self) -> SimTime {
        let mut lock = SimTime::ZERO;
        for s in self.store_pool.borrow().iter() {
            lock = lock + s.take_lock_time();
        }
        for c in self.cat_pool.borrow().iter() {
            lock = lock + c.take_lock_time();
        }
        lock
    }

    /// Flush every pooled store session's buffered writes (part of
    /// `Fdb::flush` — session buffers must be durable too).
    pub(crate) async fn flush_store_sessions(&self) -> Result<(), FdbError> {
        let mut pool = self.store_pool.take();
        let mut r = Ok(());
        for s in &mut pool {
            r = s.flush().await;
            if r.is_err() {
                break;
            }
        }
        self.store_pool.replace(pool);
        r
    }

    /// Wipe `ds` through every pooled store session: purges their
    /// per-dataset client state (open data files, absorbed tiered
    /// fields) while state for other datasets survives.
    pub(crate) async fn wipe_store_sessions(&self, ds: &Key) {
        let mut pool = self.store_pool.take();
        for s in &mut pool {
            s.wipe_dataset(ds).await;
        }
        self.store_pool.replace(pool);
    }

    /// THE semaphore: the one place the depth semaphore's name and
    /// capacity policy live. Capacity = minted store sessions (at least
    /// one server — `Resource` rejects zero).
    fn semaphore(&self) -> Rc<Resource> {
        Resource::new("fdb/io-depth", self.store_pool.borrow().len().max(1))
    }

    /// Count an admitted op in (call after the semaphore grant); the
    /// returned guard counts it out and releases the slot on drop.
    fn admit<'a>(&'a self, sem: &'a Rc<Resource>) -> Admitted<'a> {
        self.inflight.set(self.inflight.get() + 1);
        self.peak.set(self.peak.get().max(self.inflight.get()));
        if let Some(m) = &self.metrics {
            m.inflight_peak.set_max(self.peak.get() as u64);
        }
        Admitted { engine: self, sem }
    }

    /// Acquire the depth semaphore and count the op in, recording the
    /// admission wait — the queueing delay between asking for a slot
    /// and the grant — into `class`'s wait histogram. This is the
    /// "admission wait vs. service time" split: wait grows with
    /// saturation at high `--io-depth`, service time does not.
    async fn admit_waited<'a>(&'a self, sem: &'a Rc<Resource>, class: OpClass) -> Admitted<'a> {
        let tq = self.sim.now();
        sem.acquire().await;
        if let Some(m) = &self.metrics {
            m.probe(class).wait.observe_duration(self.sim.now() - tq);
        }
        self.admit(sem)
    }

    /// Record a finished op: span total (lock-subtracted) under `class`,
    /// raw window into the timeline; with metrics attached, the same
    /// lock-subtracted duration into the class's service histogram (so
    /// registry and trace totals agree exactly), an ok outcome, a
    /// journal span, and a slow-op entry when the *raw* duration meets
    /// the threshold.
    fn span(&self, class: OpClass, t0: SimTime, lock: SimTime, backend: &'static str) {
        let now = self.sim.now();
        self.trace.record(class, now - t0 - lock);
        self.trace.observe_span(class, t0, now);
        if let Some(m) = &self.metrics {
            m.probe(class).service.observe_duration(now - t0 - lock);
            m.probe(class).ok.inc();
        }
        if let Some(reg) = &self.registry {
            reg.record_span(self.inflight.get() as u64, class.label(), t0, now);
            if self.slow_op_ns > 0 && (now - t0).as_nanos() >= self.slow_op_ns {
                reg.record_slow_op(class, backend, now - t0);
            }
        }
    }

    /// Count a failed op's outcome: injected faults separately from
    /// organic errors; a surfaced integrity failure (an unrepaired
    /// checksum mismatch, never retried — [`is_transient`] rejects it)
    /// additionally bumps `integrity.corrupt`.
    fn op_err(&self, class: OpClass, e: &FdbError) {
        if let Some(m) = &self.metrics {
            if is_injected_fault(e) {
                m.probe(class).fault.inc();
            } else {
                m.probe(class).err.inc();
            }
        }
        if let (Some(reg), FdbError::Corrupt { .. }) = (&self.registry, e) {
            reg.counter("integrity.corrupt").inc();
        }
    }

    /// Race `fut` against the profile's per-op deadline. With no
    /// deadline configured this is a plain await; otherwise an op still
    /// pending when the timer fires is dropped and surfaces as
    /// [`FdbError::Timeout`] (counted under `engine.timeout.<class>`).
    async fn with_deadline<T>(
        &self,
        class: OpClass,
        fut: LocalBoxFuture<'_, Result<T, FdbError>>,
    ) -> Result<T, FdbError> {
        let micros = self.resilience.op_deadline_us;
        if micros == 0 {
            return fut.await;
        }
        let race = DeadlineRace {
            fut,
            timer: self.sim.sleep(SimTime::micros(micros)),
        };
        match race.await {
            Some(r) => r,
            None => {
                if let Some(reg) = &self.registry {
                    reg.counter(&format!("engine.timeout.{}", class.label())).inc();
                }
                Err(FdbError::Timeout {
                    class: class.label(),
                    micros,
                })
            }
        }
    }

    /// Whether attempt number `attempt` (0-based) failing with `e`
    /// warrants another go: only transient failures, and only while the
    /// profile's attempt budget lasts.
    fn should_retry(&self, e: &FdbError, attempt: u32) -> bool {
        attempt + 1 < self.resilience.max_attempts && is_transient(e)
    }

    /// Sleep the backoff before re-attempt `attempt` (1-based):
    /// `backoff_us * 2^(attempt-1)` plus up to half that of seeded
    /// jitter, in virtual time so retry storms stay deterministic and
    /// show up in the measured latency.
    async fn retry_backoff(&self, attempt: u32) {
        if let Some(reg) = &self.registry {
            reg.counter("engine.retry.attempts").inc();
        }
        let base = self
            .resilience
            .backoff_us
            .saturating_mul(1u64 << (attempt - 1).min(16));
        let jitter = self.retry_rng.borrow_mut().below(base / 2 + 1);
        self.sim.sleep(SimTime::micros(base + jitter)).await;
    }

    /// Count the final outcome of an op that needed at least one retry.
    fn retry_outcome(&self, recovered: bool) {
        if let Some(reg) = &self.registry {
            reg.counter(if recovered {
                "engine.retry.recovered"
            } else {
                "engine.retry.exhausted"
            })
            .inc();
        }
    }

    /// Record the batch's accumulated lock time once under
    /// [`OpClass::Lock`].
    fn record_lock(&self, lock: SimTime) {
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
            if let Some(m) = &self.metrics {
                m.probe(OpClass::Lock).service.observe_duration(lock);
            }
        }
    }

    /// Batched archive execution: one task per field, admitted by the
    /// depth semaphore, each writing through a checked-out store
    /// session. Locations return in input order; on errors the batch
    /// reports the first (by input index) error.
    pub(crate) async fn archive_batch(
        &self,
        ids: &[Key],
        datas: Vec<Bytes>,
        split: &[(Key, Key, Key)],
    ) -> Result<Vec<FieldLocation>, FdbError> {
        let n = ids.len();
        let sem = self.semaphore();
        let locs: RefCell<Vec<Option<FieldLocation>>> =
            RefCell::new((0..n).map(|_| None).collect());
        let failed: RefCell<Option<(usize, FdbError)>> = RefCell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        {
            let (locs, failed) = (&locs, &failed);
            let (sem, lock_total) = (&sem, &lock_total);
            let tasks: Vec<_> = datas
                .into_iter()
                .enumerate()
                .map(|(i, data)| {
                    let id = &ids[i];
                    let (ds, colloc, _elem) = &split[i];
                    boxed(async move {
                        let _adm = self.admit_waited(sem, OpClass::DataWrite).await;
                        let mut session = match Checkout::new(&self.store_pool, "store") {
                            Ok(s) => s,
                            Err(e) => return note_failure(failed, i, e),
                        };
                        let backend = session.name();
                        let nbytes = data.len();
                        let t0 = self.sim.now();
                        // data is virtual content — the per-attempt clone
                        // is a metadata copy, not a buffer copy
                        let r = resilient!(
                            self,
                            OpClass::DataWrite,
                            session.archive(ds, colloc, id, data.clone())
                        );
                        let lock = session.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        match r {
                            Ok(loc) => {
                                self.span(OpClass::DataWrite, t0, lock, backend);
                                if let Some(m) = &self.metrics {
                                    m.bytes_written.add(nbytes);
                                }
                                locs.borrow_mut()[i] = Some(loc);
                            }
                            Err(e) => {
                                self.op_err(OpClass::DataWrite, &e);
                                note_failure(failed, i, e)
                            }
                        }
                    })
                })
                .collect();
            join_all(tasks).await;
        }
        self.record_lock(lock_total.get());
        if let Some((_, e)) = failed.into_inner() {
            return Err(e);
        }
        // no recorded failure => every slot filled; if that invariant
        // ever breaks the caller gets a typed error, not a process abort
        let mut out = Vec::with_capacity(n);
        for loc in locs.into_inner() {
            out.push(loc.ok_or_else(|| FdbError::Backend {
                backend: "io-engine",
                detail: "archive batch finished with a missing field location \
                         but no recorded failure"
                    .to_string(),
            })?);
        }
        Ok(out)
    }

    /// Batched retrieve execution (uncoalesced): resolve each field's
    /// location — at depth through catalogue sessions when the backend
    /// mints them, else on the one serial index client — hand every
    /// resolved handle to a per-field read task via a one-shot slot,
    /// and read at depth through store sessions. Found `(id, bytes)`
    /// pairs return in input order; absent fields are skipped (cache
    /// semantics).
    pub(crate) async fn retrieve_batch(
        &self,
        catalogue: &mut dyn Catalogue,
        ids: &[Key],
        split: &[(Key, Key, Key)],
    ) -> Result<Vec<(Key, Bytes)>, FdbError> {
        let n = ids.len();
        let sem = self.semaphore();
        // locations (not bare handles) cross the slot: the read task
        // needs the carried checksum for its verified read
        let slots: Vec<Slot<Option<FieldLocation>>> = (0..n).map(|_| Slot::new()).collect();
        let out: RefCell<Vec<Option<(Key, Bytes)>>> =
            RefCell::new((0..n).map(|_| None).collect());
        let failed: RefCell<Option<(usize, FdbError)>> = RefCell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        let cat_depth = !self.cat_pool.borrow().is_empty();
        {
            let (slots, out, failed) = (&slots, &out, &failed);
            let (sem, lock_total) = (&sem, &lock_total);
            let mut tasks = Vec::new();
            if cat_depth {
                for (i, (id, (ds, colloc, elem))) in ids.iter().zip(split).enumerate() {
                    tasks.push(boxed(async move {
                        let _adm = self.admit_waited(sem, OpClass::IndexRead).await;
                        let mut cs = match Checkout::new(&self.cat_pool, "catalogue") {
                            Ok(s) => s,
                            Err(e) => {
                                note_failure(failed, i, e);
                                slots[i].put(None); // never strand the read task
                                return;
                            }
                        };
                        let backend = cs.name();
                        let t0 = self.sim.now();
                        let loc = cs.retrieve(ds, colloc, elem, id).await;
                        let lock = cs.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        self.span(OpClass::IndexRead, t0, lock, backend);
                        slots[i].put(loc);
                    }));
                }
            } else {
                tasks.push(boxed(async move {
                    let backend = catalogue.name();
                    for (i, (id, (ds, colloc, elem))) in ids.iter().zip(split).enumerate() {
                        let t0 = self.sim.now();
                        let loc = catalogue.retrieve(ds, colloc, elem, id).await;
                        let lock = catalogue.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        self.span(OpClass::IndexRead, t0, lock, backend);
                        slots[i].put(loc);
                    }
                }));
            }
            for (i, id) in ids.iter().enumerate() {
                tasks.push(boxed(async move {
                    let Some(loc) = slots[i].take().await else {
                        return; // absent field: cache semantics
                    };
                    let handle = DataHandle::from_location(&loc);
                    let checks = whole_checks(&loc);
                    let _adm = self.admit_waited(sem, OpClass::DataRead).await;
                    let mut session = match Checkout::new(&self.store_pool, "store") {
                        Ok(s) => s,
                        Err(e) => return note_failure(failed, i, e),
                    };
                    let backend = session.name();
                    let t0 = self.sim.now();
                    let r = resilient!(
                        self,
                        OpClass::DataRead,
                        session.read_verified(&handle, &checks)
                    );
                    let lock = session.take_lock_time();
                    lock_total.set(lock_total.get() + lock);
                    match r {
                        Ok(bytes) => {
                            self.span(OpClass::DataRead, t0, lock, backend);
                            if let Some(m) = &self.metrics {
                                m.bytes_read.add(bytes.len());
                            }
                            out.borrow_mut()[i] = Some((id.clone(), bytes));
                        }
                        Err(e) => {
                            self.op_err(OpClass::DataRead, &e);
                            note_failure(failed, i, e)
                        }
                    }
                }));
            }
            join_all(tasks).await;
        }
        self.record_lock(lock_total.get());
        if let Some((_, e)) = failed.into_inner() {
            return Err(e);
        }
        Ok(out.into_inner().into_iter().flatten().collect())
    }

    /// Streaming coalesced retrieve execution: resolve → plan → execute
    /// as one overlapped pipeline. Lookups resolve (at depth through
    /// catalogue sessions when available); a planner task feeds each
    /// resolved location — in input order — into a
    /// [`StreamPlanner`], which seals a merged range the moment it can
    /// no longer grow; sealed ranges stream through a pipe to `depth`
    /// range workers that issue them via
    /// [`Store::read_ranges`] — so the first data read is in flight
    /// while later index lookups are still resolving, instead of the
    /// planner waiting for the full location set. Merged ranges (not
    /// raw fields) are the unit of semaphore admission. Returns the
    /// per-input bytes (`None` = absent field) and the plan counters.
    pub(crate) async fn retrieve_streaming(
        &self,
        catalogue: &mut dyn Catalogue,
        ids: &[Key],
        split: &[(Key, Key, Key)],
        gap: u64,
        max_read: u64,
    ) -> Result<(Vec<Option<Bytes>>, PlanStats), FdbError> {
        let n = ids.len();
        let sem = self.semaphore();
        let slots: Vec<Slot<Option<FieldLocation>>> = (0..n).map(|_| Slot::new()).collect();
        let ranges: Pipe<crate::fdb::plan::PlannedRead> = Pipe::new();
        let out: RefCell<Vec<Option<Bytes>>> = RefCell::new((0..n).map(|_| None).collect());
        let failed: RefCell<Option<(usize, FdbError)>> = RefCell::new(None);
        let stats: Cell<PlanStats> = Cell::new(PlanStats::default());
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        let workers = self.store_pool.borrow().len().max(1);
        let cat_depth = !self.cat_pool.borrow().is_empty();
        {
            let (slots, out, failed) = (&slots, &out, &failed);
            let (sem, lock_total, ranges, stats) = (&sem, &lock_total, &ranges, &stats);
            let mut tasks = Vec::new();
            if cat_depth {
                for (i, (id, (ds, colloc, elem))) in ids.iter().zip(split).enumerate() {
                    tasks.push(boxed(async move {
                        let _adm = self.admit_waited(sem, OpClass::IndexRead).await;
                        let mut cs = match Checkout::new(&self.cat_pool, "catalogue") {
                            Ok(s) => s,
                            Err(e) => {
                                note_failure(failed, i, e);
                                slots[i].put(None);
                                return;
                            }
                        };
                        let backend = cs.name();
                        let t0 = self.sim.now();
                        let loc = cs.retrieve(ds, colloc, elem, id).await;
                        let lock = cs.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        self.span(OpClass::IndexRead, t0, lock, backend);
                        slots[i].put(loc);
                    }));
                }
            } else {
                tasks.push(boxed(async move {
                    let backend = catalogue.name();
                    for (i, (id, (ds, colloc, elem))) in ids.iter().zip(split).enumerate() {
                        let t0 = self.sim.now();
                        let loc = catalogue.retrieve(ds, colloc, elem, id).await;
                        let lock = catalogue.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        self.span(OpClass::IndexRead, t0, lock, backend);
                        slots[i].put(loc);
                    }
                }));
            }
            // the planner: consumes resolved locations in input order so
            // the emitted plan is deterministic, streams sealed ranges
            tasks.push(boxed(async move {
                let mut planner = StreamPlanner::new(gap, max_read);
                for (i, slot) in slots.iter().enumerate() {
                    if let Some(loc) = slot.take().await {
                        if let Some(sealed) = planner.push(i, &loc) {
                            ranges.push(sealed);
                        }
                    }
                }
                for sealed in planner.finish() {
                    ranges.push(sealed);
                }
                stats.set(planner.stats());
                ranges.close();
            }));
            // range workers: one per pooled session; merged ranges — not
            // raw fields — are the unit of semaphore admission
            for _ in 0..workers {
                tasks.push(boxed(async move {
                    while let Some(pr) = ranges.pop().await {
                        let _adm = self.admit_waited(sem, OpClass::DataRead).await;
                        // error ordering key: the range's first input pos
                        let fi = pr.fields.first().map(|f| f.0).unwrap_or(usize::MAX);
                        let mut session = match Checkout::new(&self.store_pool, "store") {
                            Ok(s) => s,
                            Err(e) => {
                                note_failure(failed, fi, e);
                                continue;
                            }
                        };
                        let backend = session.name();
                        let checks = pr.checks();
                        let t0 = self.sim.now();
                        let r = resilient!(
                            self,
                            OpClass::DataRead,
                            session.read_ranges_verified(
                                std::slice::from_ref(&pr.handle),
                                std::slice::from_ref(&checks),
                            )
                        );
                        let lock = session.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        match r {
                            Ok(mut bufs) => {
                                self.span(OpClass::DataRead, t0, lock, backend);
                                let buf = bufs.pop().expect("one buffer per handle");
                                if let Some(m) = &self.metrics {
                                    m.bytes_read.add(buf.len());
                                }
                                let mut out = out.borrow_mut();
                                for &(idx, rel, len) in &pr.fields {
                                    out[idx] = Some(buf.slice(rel, len));
                                }
                            }
                            Err(e) => {
                                self.op_err(OpClass::DataRead, &e);
                                note_failure(failed, fi, e)
                            }
                        }
                    }
                }));
            }
            join_all(tasks).await;
        }
        self.record_lock(lock_total.get());
        if let Some((_, e)) = failed.into_inner() {
            return Err(e);
        }
        Ok((out.into_inner(), stats.get()))
    }

    /// Batched direct-retrieve execution (the hash-OID fast path): the
    /// Store serves lookups too, so each admitted task resolves *and*
    /// reads through its own checked-out session — `depth` whole fields
    /// in flight, no lookup/read client contention.
    pub(crate) async fn direct_batch(
        &self,
        ids: &[Key],
        split: &[(Key, Key, Key)],
    ) -> Result<Vec<(Key, Bytes)>, FdbError> {
        let n = ids.len();
        let sem = self.semaphore();
        let out: RefCell<Vec<Option<(Key, Bytes)>>> =
            RefCell::new((0..n).map(|_| None).collect());
        let failed: RefCell<Option<(usize, FdbError)>> = RefCell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        {
            let (out, failed) = (&out, &failed);
            let (sem, lock_total) = (&sem, &lock_total);
            let tasks: Vec<_> = ids
                .iter()
                .enumerate()
                .map(|(i, id)| {
                    let (ds, _, _) = &split[i];
                    boxed(async move {
                        let _adm = self.admit_waited(sem, OpClass::DataRead).await;
                        let mut session = match Checkout::new(&self.store_pool, "store") {
                            Ok(s) => s,
                            Err(e) => return note_failure(failed, i, e),
                        };
                        let backend = session.name();
                        let t0 = self.sim.now();
                        let loc = session.retrieve_direct(ds, id).await;
                        let lock = session.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        self.span(OpClass::IndexRead, t0, lock, backend);
                        let Some(loc) = loc else {
                            return; // absent field: cache semantics
                        };
                        let h = DataHandle::from_location(&loc);
                        let checks = whole_checks(&loc);
                        let t1 = self.sim.now();
                        let r = resilient!(
                            self,
                            OpClass::DataRead,
                            session.read_verified(&h, &checks)
                        );
                        let lock = session.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        match r {
                            Ok(bytes) => {
                                self.span(OpClass::DataRead, t1, lock, backend);
                                if let Some(m) = &self.metrics {
                                    m.bytes_read.add(bytes.len());
                                }
                                out.borrow_mut()[i] = Some((id.clone(), bytes));
                            }
                            Err(e) => {
                                self.op_err(OpClass::DataRead, &e);
                                note_failure(failed, i, e)
                            }
                        }
                    })
                })
                .collect();
            join_all(tasks).await;
        }
        self.record_lock(lock_total.get());
        if let Some((_, e)) = failed.into_inner() {
            return Err(e);
        }
        Ok(out.into_inner().into_iter().flatten().collect())
    }
}

/// A single-producer in-process queue connecting pipeline stages. Waker
/// lists are woken wholesale, so it supports one producer and *many*
/// consumers (the engine's range workers all pop from one pipe; the
/// serial retrieve pipeline uses it single-consumer).
pub(crate) struct Pipe<T> {
    queue: RefCell<VecDeque<T>>,
    closed: Cell<bool>,
    wakers: RefCell<Vec<Waker>>,
}

impl<T> Pipe<T> {
    pub(crate) fn new() -> Pipe<T> {
        Pipe {
            queue: RefCell::new(VecDeque::new()),
            closed: Cell::new(false),
            wakers: RefCell::new(Vec::new()),
        }
    }

    pub(crate) fn push(&self, item: T) {
        self.queue.borrow_mut().push_back(item);
        for w in self.wakers.borrow_mut().drain(..) {
            w.wake();
        }
    }

    pub(crate) fn close(&self) {
        self.closed.set(true);
        for w in self.wakers.borrow_mut().drain(..) {
            w.wake();
        }
    }

    pub(crate) fn pop(&self) -> Pop<'_, T> {
        Pop { pipe: self }
    }
}

pub(crate) struct Pop<'a, T> {
    pipe: &'a Pipe<T>,
}

impl<'a, T> std::future::Future for Pop<'a, T> {
    type Output = Option<T>;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<Option<T>> {
        if let Some(item) = self.pipe.queue.borrow_mut().pop_front() {
            return std::task::Poll::Ready(Some(item));
        }
        if self.pipe.closed.get() {
            return std::task::Poll::Ready(None);
        }
        self.pipe.wakers.borrow_mut().push(cx.waker().clone());
        std::task::Poll::Pending
    }
}

/// A one-shot value slot connecting a lookup to its downstream task:
/// the producer `put`s exactly once, the single consumer
/// `take().await`s it. Waker-based so the consumer suspends cleanly
/// while earlier lookups are still resolving.
pub(crate) struct Slot<T> {
    value: RefCell<Option<T>>,
    waker: RefCell<Option<Waker>>,
}

impl<T> Slot<T> {
    pub(crate) fn new() -> Slot<T> {
        Slot {
            value: RefCell::new(None),
            waker: RefCell::new(None),
        }
    }

    pub(crate) fn put(&self, value: T) {
        *self.value.borrow_mut() = Some(value);
        if let Some(w) = self.waker.borrow_mut().take() {
            w.wake();
        }
    }

    pub(crate) fn take(&self) -> TakeSlot<'_, T> {
        TakeSlot { slot: self }
    }
}

pub(crate) struct TakeSlot<'a, T> {
    slot: &'a Slot<T>,
}

impl<'a, T> std::future::Future for TakeSlot<'a, T> {
    type Output = T;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<T> {
        if let Some(value) = self.slot.value.borrow_mut().take() {
            return std::task::Poll::Ready(value);
        }
        *self.slot.waker.borrow_mut() = Some(cx.waker().clone());
        std::task::Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::NullStore;

    #[test]
    fn checkout_on_empty_pool_is_a_typed_error_not_a_panic() {
        // the four pre-engine fan-outs all carried a
        // `pop().expect("session free under semaphore")` abort site;
        // the engine's invariant makes exhaustion unreachable, but if
        // it ever breaks the caller must get FdbError::Backend
        let pool: RefCell<Vec<Box<dyn StoreSession>>> = RefCell::new(Vec::new());
        let err = Checkout::new(&pool, "store").map(|_| ()).unwrap_err();
        match err {
            FdbError::Backend { backend, detail } => {
                assert_eq!(backend, "io-engine");
                assert!(detail.contains("exhausted"), "detail: {detail}");
            }
            other => panic!("expected Backend error, got {other:?}"),
        }
    }

    #[test]
    fn checkout_returns_the_session_on_drop() {
        let pool: RefCell<Vec<Box<dyn StoreSession>>> =
            RefCell::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        {
            let _one = Checkout::new(&pool, "store").unwrap();
            let _two = Checkout::new(&pool, "store").unwrap();
            assert_eq!(pool.borrow().len(), 0);
            assert!(Checkout::new(&pool, "store").is_err());
        }
        assert_eq!(pool.borrow().len(), 2, "drop must return both sessions");
    }

    #[test]
    fn admission_guard_restores_inflight_and_slot_on_drop() {
        use crate::fdb::backend::block_on_ready;
        let sim = Sim::new();
        let mut engine = IoEngine::new(&sim);
        engine.set_depth(2);
        engine.store_pool.borrow_mut().push(Box::new(NullStore));
        engine.store_pool.borrow_mut().push(Box::new(NullStore));
        let sem = engine.semaphore();
        assert_eq!(sem.servers(), 2, "capacity = minted sessions");
        block_on_ready(Box::pin(sem.acquire()));
        let adm = engine.admit(&sem);
        assert_eq!(engine.inflight.get(), 1);
        assert_eq!(engine.inflight_peak(), 1);
        drop(adm);
        assert_eq!(engine.inflight.get(), 0, "guard must count the op out");
        // the slot came back too: both servers acquire without queueing
        block_on_ready(Box::pin(sem.acquire()));
        block_on_ready(Box::pin(sem.acquire()));
    }

    #[test]
    fn deadline_converts_hung_op_into_typed_timeout() {
        let sim = Sim::new();
        let reg = MetricsRegistry::new();
        let mut engine = IoEngine::new(&sim);
        engine.set_metrics(&reg, 0);
        engine.set_resilience(ResilienceProfile::default().with_op_deadline_us(50));
        let hit = Rc::new(Cell::new(false));
        {
            let hit = hit.clone();
            let slow = sim.clone();
            sim.spawn(async move {
                let fut = boxed(async move {
                    slow.sleep(SimTime::micros(500)).await;
                    Ok(0u32)
                });
                match engine.with_deadline(OpClass::DataRead, fut).await {
                    Err(FdbError::Timeout { class, micros }) => {
                        assert_eq!(class, OpClass::DataRead.label());
                        assert_eq!(micros, 50);
                        hit.set(true);
                    }
                    other => panic!("expected a timeout, got {other:?}"),
                }
            });
        }
        let end = sim.run();
        assert!(hit.get());
        assert_eq!(end, SimTime::micros(50), "the caller unblocks at the deadline");
        assert_eq!(
            reg.counter_value(&format!("engine.timeout.{}", OpClass::DataRead.label())),
            1
        );
    }

    #[test]
    fn transient_failures_retry_with_backoff_and_recover() {
        let sim = Sim::new();
        let reg = MetricsRegistry::new();
        let mut engine = IoEngine::new(&sim);
        engine.set_metrics(&reg, 0);
        engine.set_resilience(
            ResilienceProfile::retries(4).with_backoff_us(10).with_seed(7),
        );
        let got = Rc::new(Cell::new(0u32));
        {
            let got = got.clone();
            sim.spawn(async move {
                let calls = Cell::new(0u32);
                let calls = &calls;
                let r: Result<u32, FdbError> = resilient!(engine, OpClass::DataRead, {
                    let n = calls.get();
                    calls.set(n + 1);
                    boxed(async move {
                        if n < 2 {
                            Err(FdbError::Backend {
                                backend: "fault",
                                detail: "injected transient Read error".to_string(),
                            })
                        } else {
                            Ok(7u32)
                        }
                    })
                });
                assert_eq!(calls.get(), 3, "two failures, one success");
                got.set(r.unwrap());
            });
        }
        let end = sim.run();
        assert_eq!(got.get(), 7);
        // exponential backoff in virtual time: 10µs then 20µs, plus jitter
        assert!(end >= SimTime::micros(30), "backoff must advance the clock");
        assert_eq!(reg.counter_value("engine.retry.attempts"), 2);
        assert_eq!(reg.counter_value("engine.retry.recovered"), 1);
        assert_eq!(reg.counter_value("engine.retry.exhausted"), 0);
    }

    #[test]
    fn retry_budget_exhausts_and_permanent_errors_never_retry() {
        let sim = Sim::new();
        let reg = MetricsRegistry::new();
        let mut engine = IoEngine::new(&sim);
        engine.set_metrics(&reg, 0);
        engine.set_resilience(ResilienceProfile::retries(2).with_backoff_us(5));
        sim.spawn(async move {
            // always-transient failure: one retry, then the budget is gone
            let transient = Cell::new(0u32);
            let (t, e) = (&transient, &engine);
            let r: Result<u32, FdbError> = resilient!(e, OpClass::DataRead, {
                t.set(t.get() + 1);
                boxed(async move {
                    Err(FdbError::Timeout {
                        class: "data-read",
                        micros: 1,
                    })
                })
            });
            assert!(r.is_err());
            assert_eq!(t.get(), 2, "max_attempts=2 => exactly two attempts");
            // permanent (unmarked) failure: no retry at all
            let permanent = Cell::new(0u32);
            let p = &permanent;
            let r: Result<u32, FdbError> = resilient!(e, OpClass::DataRead, {
                p.set(p.get() + 1);
                boxed(async move {
                    Err(FdbError::Backend {
                        backend: "posix",
                        detail: "enospc".to_string(),
                    })
                })
            });
            assert!(r.is_err());
            assert_eq!(p.get(), 1, "permanent errors burn no retry budget");
        });
        sim.run();
        assert_eq!(reg.counter_value("engine.retry.attempts"), 1);
        assert_eq!(reg.counter_value("engine.retry.exhausted"), 1);
        assert_eq!(reg.counter_value("engine.retry.recovered"), 0);
    }

    #[test]
    fn multi_consumer_pipe_hands_each_item_to_exactly_one_worker() {
        // two workers draining one pipe: every pushed item pops exactly
        // once, and close() releases both (a single-waker pipe would
        // strand one worker forever and hang the sim)
        let sim = Sim::new();
        let done = std::rc::Rc::new(RefCell::new(Vec::new()));
        {
            let done = done.clone();
            sim.spawn(async move {
                let pipe: Pipe<u32> = Pipe::new();
                let got: RefCell<Vec<u32>> = RefCell::new(Vec::new());
                {
                    let (pipe, got) = (&pipe, &got);
                    let producer = boxed(async move {
                        for i in 0..5u32 {
                            pipe.push(i);
                        }
                        pipe.close();
                    });
                    let workers = (0..2).map(|_| {
                        boxed(async move {
                            while let Some(v) = pipe.pop().await {
                                got.borrow_mut().push(v);
                            }
                        })
                    });
                    let mut tasks = vec![producer];
                    tasks.extend(workers);
                    join_all(tasks).await;
                }
                let mut items = got.into_inner();
                items.sort_unstable();
                *done.borrow_mut() = items;
            });
        }
        sim.run();
        assert_eq!(*done.borrow(), vec![0, 1, 2, 3, 4]);
    }
}
