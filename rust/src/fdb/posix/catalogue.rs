//! The FDB POSIX I/O Catalogue (thesis §2.7.2): in-memory partial + full
//! B-tree indexes with axes and URI stores, persisted to per-process
//! index/sub-TOC files on flush()/close(), bound together by the shared
//! TOC file, with masking and TOC pre-loading on the read side.

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use super::index;
use super::store::{fs_err, sanitize};
use super::toc::{Axes, IndexRef, TocRecord};
use crate::fdb::fault::wal::{self, RecoveryStats, WalRecord};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::request::Request;
use crate::fdb::schema::Schema;
use crate::fdb::telemetry::Counter;
use crate::fdb::FdbError;
use crate::lustre::{Fd, FsError, LustreClient, StripeSpec};

/// One collocation's live (in-memory) indexing state for a writer.
struct CollocState {
    /// entries since the last flush: elem canonical → (uri_id, off, len, ck)
    partial: BTreeMap<String, (u32, u64, u64, Option<u64>)>,
    /// all entries of this process lifetime
    full: BTreeMap<String, (u32, u64, u64, Option<u64>)>,
    axes_partial: Axes,
    axes_full: Axes,
    /// URI store: uri string → id, plus the ordered table
    uri_ids: HashMap<String, u32>,
    uris: Vec<String>,
    partial_fd: Fd,
    full_fd: Fd,
}

/// Per-dataset writer-side state.
struct DatasetState {
    dir: String,
    collocs: BTreeMap<String, CollocState>,
    subtoc_fd: Option<Fd>,
    toc_fd: Option<Fd>,
    /// durable mode: this process' write-ahead log (created on the
    /// first durable archive, committed at flush, unlinked at close)
    wal_fd: Option<Fd>,
    /// next WAL sequence number
    wal_seq: u64,
}

/// Reader-side pre-loaded state for one dataset (thesis "TOC pre-loading").
struct Preloaded {
    /// newest-first index references (full indexes before their masked
    /// sub-TOC partials, per reverse TOC order)
    refs: Vec<IndexRef>,
}

pub struct PosixCatalogue {
    pub(crate) client: LustreClient,
    root: String,
    schema: Schema,
    write_state: HashMap<String, DatasetState>,
    preloaded: HashMap<String, Preloaded>,
    /// reader-side index caching (IoProfile::preload_indexes): loaded
    /// index blobs are immutable — partial flushes append *new* blobs at
    /// new offsets and get new TOC records — so entries cached per
    /// (index file, blob offset) are always coherent
    index_cache_on: bool,
    index_cache: HashMap<(String, u64), Rc<Vec<index::IndexEntry>>>,
    /// durable mode ([`crate::fdb::IoProfile::durable`]): archive
    /// appends an fdatasync'd WAL intent before mutating the in-memory
    /// index, so a crashed producer's unflushed entries are recoverable
    durable: bool,
    /// inside an archive group ([`crate::fdb::backend::Catalogue::begin_archive_group`]):
    /// durable intents append WITHOUT their per-op fdatasync and the
    /// dataset is marked dirty; `end_archive_group` issues ONE barrier
    /// per dirty WAL — group commit. Crash semantics are unchanged: the
    /// batch is only reported archived after the group barrier, and an
    /// intent is never fdatasync'd after its index mutation *becomes
    /// observable* (nothing is observable until `archive_many` returns).
    in_group: bool,
    /// datasets whose WAL took un-synced intents in the current group
    group_dirty: std::collections::HashSet<String>,
    /// WAL fdatasync barriers issued so far (per-intent + group + commit
    /// watermarks) — observability for the group-commit tests. A shared
    /// telemetry [`Counter`] handle so the builder can serve the same
    /// count from the metrics registry (`cat.<label>.wal_syncs`);
    /// standalone (registry-less) by default.
    wal_syncs: Counter,
    /// corrupt index blobs hit on the read path (typed
    /// [`FdbError::Corrupt`] from the blob parser): the lookup skips the
    /// rotten blob — an older index may still resolve the entry — but
    /// the damage is counted, never silently swallowed
    index_corrupt: Counter,
}

impl PosixCatalogue {
    pub fn new(client: LustreClient, root: &str, schema: Schema) -> PosixCatalogue {
        PosixCatalogue {
            client,
            root: root.to_string(),
            schema,
            write_state: HashMap::new(),
            preloaded: HashMap::new(),
            index_cache_on: false,
            index_cache: HashMap::new(),
            durable: false,
            in_group: false,
            group_dirty: std::collections::HashSet::new(),
            wal_syncs: Counter::new(),
            index_corrupt: Counter::new(),
        }
    }

    /// WAL fdatasync barriers issued so far. A durable N-field
    /// `archive_many` batch costs 1 (group commit); N single-field
    /// `archive` calls cost N. Thin shim over the shared counter
    /// handle, which doubles as the registry's `cat.<label>.wal_syncs`.
    pub fn wal_sync_count(&self) -> u64 {
        self.wal_syncs.get()
    }

    /// Replace the WAL-sync counter with a registry-owned handle (the
    /// builder wires `cat.<label>.wal_syncs` here when metrics are
    /// attached), preserving any already-counted barriers.
    pub fn with_wal_counter(mut self, counter: Counter) -> PosixCatalogue {
        counter.add(self.wal_syncs.get());
        self.wal_syncs = counter;
        self
    }

    /// Corrupt index blobs skipped on the read path so far.
    pub fn index_corrupt_count(&self) -> u64 {
        self.index_corrupt.get()
    }

    /// Replace the corrupt-blob counter with a registry-owned handle
    /// (`cat.<label>.index_corrupt`), preserving any already-counted
    /// damage.
    pub fn with_corrupt_counter(mut self, counter: Counter) -> PosixCatalogue {
        counter.add(self.index_corrupt.get());
        self.index_corrupt = counter;
        self
    }

    /// Enable reader-side index-blob caching (the real FDB loads indexes
    /// whole and keeps them; the default-off 3-read point lookup models
    /// the thesis' uncached cost).
    pub fn with_index_cache(mut self, on: bool) -> PosixCatalogue {
        self.index_cache_on = on;
        self
    }

    /// Enable write-ahead logging (default off = exact legacy
    /// behaviour). See [`crate::fdb::fault::wal`] for the format and
    /// recovery semantics.
    pub fn with_durable(mut self, on: bool) -> PosixCatalogue {
        self.durable = on;
        self
    }

    fn ds_dir(&self, ds: &Key) -> String {
        format!("{}/{}", self.root, ds.canonical())
    }

    fn toc_path(dir: &str) -> String {
        format!("{dir}/toc")
    }

    /// Dataset init: mkdir, TOC creation + Init record, schema copy.
    /// All steps tolerate racing writers (thesis consistency mechanisms);
    /// real filesystem failures (a root path component that is a regular
    /// file, ...) surface as [`FdbError::Backend`] — the mkdir here used
    /// to be the last remaining archive-path panic.
    async fn ensure_dataset(&mut self, ds: &Key) -> Result<&mut DatasetState, FdbError> {
        let dsc = ds.canonical();
        if !self.write_state.contains_key(&dsc) {
            let dir = self.ds_dir(ds);
            match self.client.mkdir(&dir).await {
                Ok(()) | Err(FsError::AlreadyExists) => {}
                Err(e) => return Err(fs_err("mkdir", &dir, e)),
            }
            let toc_path = Self::toc_path(&dir);
            let toc_fd = match self.client.create(&toc_path, StripeSpec::default_layout()).await
            {
                Ok(fd) => {
                    // we won the race: write the Init header + schema copy
                    let rec = TocRecord::Init { dataset: dsc.clone() }.encode();
                    self.client
                        .write(&fd, &rec)
                        .await
                        .map_err(|e| fs_err("write", &toc_path, e))?;
                    self.client
                        .fdatasync(&fd)
                        .await
                        .map_err(|e| fs_err("fdatasync", &toc_path, e))?;
                    let schema_path = format!("{dir}/schema");
                    if let Ok(sfd) = self
                        .client
                        .create(&schema_path, StripeSpec::default_layout())
                        .await
                    {
                        let text = self.schema.to_text();
                        self.client
                            .write(&sfd, text.as_bytes())
                            .await
                            .map_err(|e| fs_err("write", &schema_path, e))?;
                        self.client
                            .fdatasync(&sfd)
                            .await
                            .map_err(|e| fs_err("fdatasync", &schema_path, e))?;
                    }
                    fd
                }
                // lost the race: a peer owns the Init record
                Err(FsError::AlreadyExists) => self
                    .client
                    .open_append(&toc_path)
                    .await
                    .map_err(|e| fs_err("open", &toc_path, e))?
                    .ok_or_else(|| fs_err("open", &toc_path, FsError::NotFound))?,
                Err(e) => return Err(fs_err("create", &toc_path, e)),
            };
            self.write_state.insert(
                dsc.clone(),
                DatasetState {
                    dir,
                    collocs: BTreeMap::new(),
                    subtoc_fd: None,
                    toc_fd: Some(toc_fd),
                    wal_fd: None,
                    wal_seq: 0,
                },
            );
        }
        Ok(self.write_state.get_mut(&dsc).unwrap())
    }

    /// Catalogue archive(): pure in-memory indexing (no I/O beyond
    /// first-call file creation). Fallible: dataset init and index-file
    /// creation hit the filesystem.
    pub async fn archive(
        &mut self,
        ds: &Key,
        colloc: &Key,
        elem: &Key,
        loc: &FieldLocation,
    ) -> Result<(), FdbError> {
        // URI store: split the location into a file root + (offset, len);
        // the content checksum rides alongside — posix entries carry it
        // in the index entry, other backends inside their full URI
        let (uri_root, off, len) = match loc {
            FieldLocation::PosixFile {
                path,
                offset,
                length,
                ..
            } => (format!("posix://{path}"), *offset, *length),
            other => (other.to_uri(), 0, other.length()),
        };
        self.archive_raw(ds, colloc, elem, uri_root, off, len, loc.checksum())
            .await
    }

    /// URI root of a tombstone entry (see [`Self::forget`]): no reader
    /// can expand it, so newest-wins masking hides every older entry for
    /// the identifier.
    pub(crate) const TOMBSTONE_URI: &'static str = "tombstone://";

    /// Drop an identifier from the index by archiving a **tombstone** —
    /// an entry whose URI root expands to nothing. The retrieve/list
    /// paths need zero changes: masking does the forgetting, and the
    /// tombstone persists through the regular flush()/WAL machinery
    /// (fsck ghost-drops are therefore themselves crash-safe in durable
    /// mode).
    pub async fn forget(
        &mut self,
        ds: &Key,
        colloc: &Key,
        elem: &Key,
    ) -> Result<bool, FdbError> {
        self.archive_raw(
            ds,
            colloc,
            elem,
            Self::TOMBSTONE_URI.to_string(),
            0,
            0,
            None,
        )
        .await?;
        Ok(true)
    }

    /// The shared indexing path behind [`Self::archive`] and
    /// [`Self::forget`]: dataset/collocation init, the durable-mode WAL
    /// intent, then the in-memory index mutation.
    #[allow(clippy::too_many_arguments)]
    async fn archive_raw(
        &mut self,
        ds: &Key,
        colloc: &Key,
        elem: &Key,
        uri_root: String,
        off: u64,
        len: u64,
        ck: Option<u64>,
    ) -> Result<(), FdbError> {
        let client_id = self.client.id;
        let state = self.ensure_dataset(ds).await?;
        let dir = state.dir.clone();
        let cc = colloc.canonical();
        if !state.collocs.contains_key(&cc) {
            // create the pair of per-process index files
            let base = format!("{dir}/{}.{}", sanitize(&cc), client_id);
            let ppath = format!("{base}.pindex");
            let partial_fd = self
                .client
                .create(&ppath, StripeSpec::default_layout())
                .await
                .map_err(|e| fs_err("create", &ppath, e))?;
            let fpath = format!("{base}.findex");
            let full_fd = self
                .client
                .create(&fpath, StripeSpec::default_layout())
                .await
                .map_err(|e| fs_err("create", &fpath, e))?;
            let state = self.write_state.get_mut(&ds.canonical()).unwrap();
            state.collocs.insert(
                cc.clone(),
                CollocState {
                    partial: BTreeMap::new(),
                    full: BTreeMap::new(),
                    axes_partial: Axes::new(),
                    axes_full: Axes::new(),
                    uri_ids: HashMap::new(),
                    uris: Vec::new(),
                    partial_fd,
                    full_fd,
                },
            );
        }
        let ec = elem.canonical();
        // durable mode: log the intent (fdatasync'd) BEFORE any in-memory
        // mutation, so an entry is either recoverable from the WAL or was
        // never indexed — a crash can't leave an unlogged index entry.
        // Inside an archive group the per-intent barrier is deferred to
        // `end_archive_group` (one fdatasync per batch, not per field).
        if self.durable {
            let dsc = ds.canonical();
            let (wal_fd, seq) = self.ensure_wal(&dsc).await?;
            let rec = WalRecord::Intent {
                seq,
                colloc: cc.clone(),
                elem: ec.clone(),
                uri: uri_root.clone(),
                offset: off,
                length: len,
                ck,
            }
            .encode();
            self.client
                .write(&wal_fd, &rec)
                .await
                .map_err(|e| fs_err("write", wal_fd.path(), e))?;
            if self.in_group {
                self.group_dirty.insert(dsc);
            } else {
                self.client
                    .fdatasync(&wal_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", wal_fd.path(), e))?;
                self.wal_syncs.inc();
            }
        }
        let state = self.write_state.get_mut(&ds.canonical()).unwrap();
        let cs = state.collocs.get_mut(&cc).unwrap();
        let next_id = cs.uris.len() as u32;
        let uri_id = *cs.uri_ids.entry(uri_root.clone()).or_insert_with(|| {
            cs.uris.push(uri_root);
            next_id
        });
        cs.partial.insert(ec.clone(), (uri_id, off, len, ck));
        cs.full.insert(ec, (uri_id, off, len, ck));
        cs.axes_partial.insert_key(elem);
        cs.axes_full.insert_key(elem);
        Ok(())
    }

    /// Durable mode: lazily create this process' per-dataset WAL file
    /// and hand out the next intent sequence number.
    async fn ensure_wal(&mut self, dsc: &str) -> Result<(Fd, u64), FdbError> {
        let needs_wal = {
            let state = self.write_state.get(dsc).unwrap();
            state.wal_fd.is_none()
        };
        if needs_wal {
            let dir = self.write_state.get(dsc).unwrap().dir.clone();
            let path = format!("{dir}/p{}.wal", self.client.id);
            let fd = match self.client.create(&path, StripeSpec::default_layout()).await {
                Ok(fd) => fd,
                // a same-id predecessor left a WAL behind: append to it
                Err(FsError::AlreadyExists) => self
                    .client
                    .open_append(&path)
                    .await
                    .map_err(|e| fs_err("open", &path, e))?
                    .ok_or_else(|| fs_err("open", &path, FsError::NotFound))?,
                Err(e) => return Err(fs_err("create", &path, e)),
            };
            self.write_state.get_mut(dsc).unwrap().wal_fd = Some(fd);
        }
        let state = self.write_state.get_mut(dsc).unwrap();
        let seq = state.wal_seq;
        state.wal_seq += 1;
        Ok((state.wal_fd.clone().unwrap(), seq))
    }

    /// Enter group-commit mode: durable intents appended until
    /// [`Self::end_archive_group`] skip their per-op fdatasync.
    pub fn begin_archive_group(&mut self) {
        self.in_group = true;
    }

    /// Leave group-commit mode, issuing ONE fdatasync barrier per WAL
    /// that took intents during the group. Nothing archived in the group
    /// may be reported durable until this returns.
    pub async fn end_archive_group(&mut self) -> Result<(), FdbError> {
        self.in_group = false;
        let dirty: Vec<String> = self.group_dirty.drain().collect();
        for dsc in dirty {
            let wal_fd = self
                .write_state
                .get(&dsc)
                .and_then(|state| state.wal_fd.clone());
            if let Some(wal_fd) = wal_fd {
                self.client
                    .fdatasync(&wal_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", wal_fd.path(), e))?;
                self.wal_syncs.inc();
            }
        }
        Ok(())
    }

    /// Catalogue flush(): persist partial indexes, then sub-TOC entries
    /// (creating the sub-TOC and its TOC pointer on first flush). In
    /// durable mode a successful flush appends a WAL commit watermark:
    /// everything logged so far is now reachable through the sub-TOC, so
    /// recovery need not replay it.
    pub async fn flush(&mut self) -> Result<(), FdbError> {
        let client_id = self.client.id;
        let datasets: Vec<String> = self.write_state.keys().cloned().collect();
        for dsc in datasets {
            // collect work first (borrow discipline)
            let dirty: Vec<String> = {
                let state = self.write_state.get(&dsc).unwrap();
                state
                    .collocs
                    .iter()
                    .filter(|(_, cs)| !cs.partial.is_empty())
                    .map(|(k, _)| k.clone())
                    .collect()
            };
            if dirty.is_empty() {
                continue;
            }
            // ensure sub-TOC exists + TOC pointer appended (first flush)
            let (dir, needs_subtoc) = {
                let state = self.write_state.get(&dsc).unwrap();
                (state.dir.clone(), state.subtoc_fd.is_none())
            };
            if needs_subtoc {
                let path = format!("{dir}/p{client_id}.subtoc");
                let fd = self
                    .client
                    .create(&path, StripeSpec::default_layout())
                    .await
                    .map_err(|e| fs_err("create", &path, e))?;
                // contend to append the pointer to the shared TOC
                let toc_fd = {
                    let state = self.write_state.get(&dsc).unwrap();
                    state.toc_fd.clone().unwrap()
                };
                let rec = TocRecord::SubToc { path: path.clone() }.encode();
                self.client
                    .write(&toc_fd, &rec)
                    .await
                    .map_err(|e| fs_err("write", toc_fd.path(), e))?;
                self.client
                    .fdatasync(&toc_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", toc_fd.path(), e))?;
                self.write_state.get_mut(&dsc).unwrap().subtoc_fd = Some(fd);
            }
            for cc in dirty {
                // serialize the partial index and append it to the pindex file
                let (blob, subtoc_rec, partial_fd, subtoc_fd) = {
                    let state = self.write_state.get_mut(&dsc).unwrap();
                    let cs = state.collocs.get_mut(&cc).unwrap();
                    let entries: Vec<index::IndexEntry> = cs
                        .partial
                        .iter()
                        .map(|(elem, &(uri_id, offset, length, ck))| index::IndexEntry {
                            elem: elem.clone(),
                            uri_id,
                            offset,
                            length,
                            ck,
                        })
                        .collect();
                    let blob = index::serialize(&entries);
                    let offset = self.client.cached_size(&cs.partial_fd);
                    let r = IndexRef {
                        colloc: cc.clone(),
                        index_path: cs.partial_fd.path().to_string(),
                        offset,
                        length: blob.len() as u64,
                        axes: cs.axes_partial.clone(),
                        uris: cs.uris.clone(),
                    };
                    cs.partial.clear();
                    cs.axes_partial = Axes::new();
                    (
                        blob,
                        TocRecord::Index(r).encode(),
                        cs.partial_fd.clone(),
                        state.subtoc_fd.clone().unwrap(),
                    )
                };
                self.client
                    .write(&partial_fd, &blob)
                    .await
                    .map_err(|e| fs_err("write", partial_fd.path(), e))?;
                self.client
                    .fdatasync(&partial_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", partial_fd.path(), e))?;
                self.client
                    .write(&subtoc_fd, &subtoc_rec)
                    .await
                    .map_err(|e| fs_err("write", subtoc_fd.path(), e))?;
                self.client
                    .fdatasync(&subtoc_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", subtoc_fd.path(), e))?;
            }
            // durable mode: everything logged below this watermark is now
            // persisted in the sub-TOC chain — mark it committed
            let wal = {
                let state = self.write_state.get(&dsc).unwrap();
                state.wal_fd.clone().map(|fd| (fd, state.wal_seq))
            };
            if let Some((wal_fd, watermark)) = wal {
                let rec = WalRecord::Commit { seq: watermark }.encode();
                self.client
                    .write(&wal_fd, &rec)
                    .await
                    .map_err(|e| fs_err("write", wal_fd.path(), e))?;
                self.client
                    .fdatasync(&wal_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", wal_fd.path(), e))?;
                self.wal_syncs.inc();
            }
        }
        Ok(())
    }

    /// Catalogue close(): persist full indexes, append their TOC entries,
    /// and mask the now-superseded sub-TOCs. In durable mode the WAL is
    /// unlinked at the end: the full index supersedes every logged intent.
    pub async fn close(&mut self) -> Result<(), FdbError> {
        let datasets: Vec<String> = self.write_state.keys().cloned().collect();
        for dsc in datasets {
            let collocs: Vec<String> = {
                let state = self.write_state.get(&dsc).unwrap();
                state
                    .collocs
                    .iter()
                    .filter(|(_, cs)| !cs.full.is_empty())
                    .map(|(k, _)| k.clone())
                    .collect()
            };
            for cc in collocs {
                let (blob, toc_rec, full_fd, toc_fd) = {
                    let state = self.write_state.get_mut(&dsc).unwrap();
                    let cs = state.collocs.get_mut(&cc).unwrap();
                    let entries: Vec<index::IndexEntry> = cs
                        .full
                        .iter()
                        .map(|(elem, &(uri_id, offset, length, ck))| index::IndexEntry {
                            elem: elem.clone(),
                            uri_id,
                            offset,
                            length,
                            ck,
                        })
                        .collect();
                    let blob = index::serialize(&entries);
                    let r = IndexRef {
                        colloc: cc.clone(),
                        index_path: cs.full_fd.path().to_string(),
                        offset: 0,
                        length: blob.len() as u64,
                        axes: cs.axes_full.clone(),
                        uris: cs.uris.clone(),
                    };
                    (
                        blob,
                        TocRecord::Index(r).encode(),
                        cs.full_fd.clone(),
                        state.toc_fd.clone().unwrap(),
                    )
                };
                self.client
                    .write(&full_fd, &blob)
                    .await
                    .map_err(|e| fs_err("write", full_fd.path(), e))?;
                self.client
                    .fdatasync(&full_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", full_fd.path(), e))?;
                self.client
                    .write(&toc_fd, &toc_rec)
                    .await
                    .map_err(|e| fs_err("write", toc_fd.path(), e))?;
                self.client
                    .fdatasync(&toc_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", toc_fd.path(), e))?;
            }
            // mask this process' sub-TOC
            let (subtoc_path, toc_fd) = {
                let state = self.write_state.get(&dsc).unwrap();
                (
                    state.subtoc_fd.as_ref().map(|f| f.path().to_string()),
                    state.toc_fd.clone(),
                )
            };
            if let (Some(path), Some(toc_fd)) = (subtoc_path, toc_fd) {
                let rec = TocRecord::Mask { path }.encode();
                self.client
                    .write(&toc_fd, &rec)
                    .await
                    .map_err(|e| fs_err("write", toc_fd.path(), e))?;
                self.client
                    .fdatasync(&toc_fd)
                    .await
                    .map_err(|e| fs_err("fdatasync", toc_fd.path(), e))?;
            }
            // durable mode: the full index above covers every logged
            // intent — retire this process' WAL (best-effort: a leftover
            // WAL only costs a no-op replay on recovery)
            let wal_path = {
                let state = self.write_state.get_mut(&dsc).unwrap();
                state.wal_fd.take().map(|fd| fd.path().to_string())
            };
            if let Some(path) = wal_path {
                let _ = self.client.unlink(&path).await;
            }
        }
        Ok(())
    }

    /// WAL recovery: scan the dataset directory for write-ahead logs
    /// left by crashed producers, replay every uncommitted intent through
    /// the regular archive path, and retire the dead logs.
    ///
    /// Replay goes through [`Self::archive`], so in durable mode each
    /// recovered entry is re-logged under *this* process' WAL first —
    /// recovery is itself crash-safe. Replay is idempotent: entries key
    /// on the element's canonical form, and a processed WAL is unlinked
    /// (durable mode) or re-replayed to the same state. Intents whose
    /// data file does not cover the logged range (the producer died
    /// between the WAL append and the data landing) are skipped and
    /// counted as `data_missing`.
    pub async fn recover(&mut self, ds: &Key) -> Result<RecoveryStats, FdbError> {
        let mut stats = RecoveryStats::default();
        let dir = self.ds_dir(ds);
        let own_wal = format!("p{}.wal", self.client.id);
        let children = match self.client.readdir(&dir).await {
            Ok(c) => c,
            // dataset never created: nothing to recover
            Err(FsError::NotFound) => return Ok(stats),
            Err(e) => return Err(fs_err("readdir", &dir, e)),
        };
        for child in children {
            if !child.ends_with(".wal") || child == own_wal {
                continue;
            }
            let path = format!("{dir}/{child}");
            let Ok(bytes) = self.client.read_all(&path).await else {
                continue; // raced with another recoverer — fine
            };
            let (records, torn) = wal::parse_stream(&bytes.to_vec());
            stats.wal_files += 1;
            stats.torn_bytes += torn;
            let intents = records
                .iter()
                .filter(|r| matches!(r, WalRecord::Intent { .. }))
                .count();
            let replay: Vec<WalRecord> =
                wal::uncommitted(&records).into_iter().cloned().collect();
            stats.committed += intents - replay.len();
            for rec in replay {
                let WalRecord::Intent {
                    colloc,
                    elem,
                    uri,
                    offset,
                    length,
                    ck,
                    ..
                } = rec
                else {
                    continue;
                };
                let ckey = Key::parse(&colloc).unwrap_or_default();
                let ekey = Key::parse(&elem).unwrap_or_default();
                // a crashed fsck's ghost-drop: re-apply the tombstone
                if uri == Self::TOMBSTONE_URI {
                    self.archive_raw(
                        ds,
                        &ckey,
                        &ekey,
                        Self::TOMBSTONE_URI.to_string(),
                        0,
                        0,
                        None,
                    )
                    .await?;
                    stats.replayed += 1;
                    continue;
                }
                // durability gate: only replay entries whose data the
                // store actually persisted before the crash
                let loc = if let Some(p) = uri.strip_prefix("posix://") {
                    match self.client.stat(p).await {
                        Some(size) if offset + length <= size => {
                            // integrity gate: when the intent carries a
                            // content checksum, read the persisted range
                            // back and verify it — a corrupt replay
                            // target must never be indexed
                            if let Some(want) = ck {
                                let good = match self.client.open(p).await {
                                    Ok(Some(fd)) => {
                                        match self.client.read(&fd, offset, length).await {
                                            Ok(bytes) => bytes.content_checksum() == want,
                                            Err(_) => false,
                                        }
                                    }
                                    _ => false,
                                };
                                if !good {
                                    stats.data_corrupt += 1;
                                    continue;
                                }
                            }
                            FieldLocation::PosixFile {
                                path: p.to_string(),
                                offset,
                                length,
                                checksum: ck,
                            }
                        }
                        _ => {
                            stats.data_missing += 1;
                            continue;
                        }
                    }
                } else {
                    match FieldLocation::parse_uri(&uri) {
                        Some(l) => l,
                        None => {
                            stats.data_missing += 1;
                            continue;
                        }
                    }
                };
                self.archive(ds, &ckey, &ekey, &loc).await?;
                stats.replayed += 1;
            }
            // durable mode re-logged every replayed intent above, so the
            // dead producer's WAL can go; without the WAL safety net the
            // old log must survive until our own flush
            if self.durable {
                let _ = self.client.unlink(&path).await;
            }
        }
        // recovered entries become visible at the next flush; drop any
        // stale pre-loaded TOC view so readers re-scan afterwards
        self.invalidate_preload(ds);
        Ok(stats)
    }

    /// TOC pre-loading (thesis): read the TOC + all unmasked sub-TOCs,
    /// rebuilding every IndexRef (with axes + URI stores) in memory.
    async fn ensure_preloaded(&mut self, ds: &Key) {
        let dsc = ds.canonical();
        if self.preloaded.contains_key(&dsc) {
            return;
        }
        let dir = self.ds_dir(ds);
        let toc_path = Self::toc_path(&dir);
        let toc_bytes = match self.client.read_all(&toc_path).await {
            Ok(b) => b.to_vec(),
            Err(_) => {
                self.preloaded.insert(dsc, Preloaded { refs: Vec::new() });
                return;
            }
        };
        let records = TocRecord::parse_stream(&toc_bytes);
        // reverse scan: collect masks before visiting sub-TOCs
        let mut masked: std::collections::HashSet<String> = std::collections::HashSet::new();
        let mut refs: Vec<IndexRef> = Vec::new();
        for rec in records.iter().rev() {
            match rec {
                TocRecord::Mask { path } => {
                    masked.insert(path.clone());
                }
                TocRecord::Index(r) => refs.push(r.clone()),
                TocRecord::SubToc { path } => {
                    if masked.contains(path) {
                        continue;
                    }
                    if let Ok(bytes) = self.client.read_all(path).await {
                        let bytes = bytes.to_vec();
                        for sub in TocRecord::parse_stream(&bytes).iter().rev() {
                            if let TocRecord::Index(r) = sub {
                                refs.push(r.clone());
                            }
                        }
                    }
                }
                TocRecord::Init { .. } => {}
            }
        }
        self.preloaded.insert(dsc, Preloaded { refs });
    }

    /// Drop cached pre-loaded state (new flushes become visible — used by
    /// consumers that re-list per step, like PGEN). Also drops cached
    /// index blobs under the dataset's directory: they stay coherent for
    /// live files, but a wiped dataset must not serve ghost entries.
    pub fn invalidate_preload(&mut self, ds: &Key) {
        self.preloaded.remove(&ds.canonical());
        // trailing '/' so a sibling dataset whose directory name merely
        // shares a prefix keeps its (still-coherent) cached blobs
        let dir = format!("{}/", self.ds_dir(ds));
        self.index_cache.retain(|(path, _), _| !path.starts_with(&dir));
    }

    /// Cached whole-blob load (index caching mode): one eager read per
    /// (index file, blob offset), in-memory afterwards — how the real
    /// FDB treats its loaded B-tree indexes.
    async fn load_index_cached(&mut self, r: &IndexRef) -> Rc<Vec<index::IndexEntry>> {
        let key = (r.index_path.clone(), r.offset);
        if let Some(hit) = self.index_cache.get(&key) {
            return hit.clone();
        }
        let entries = Rc::new(self.load_index_full(r).await);
        // only cache blobs that parsed: an empty result may be a
        // transient read failure rather than an empty index
        if !entries.is_empty() {
            self.index_cache.insert(key, entries.clone());
        }
        entries
    }

    /// Unwrap a blob-parser result: a typed [`FdbError::Corrupt`] is
    /// counted (`index_corrupt`) and mapped to `None` so the caller
    /// skips the rotten blob — an older index may still hold the entry.
    fn parsed<T>(&self, r: Result<T, FdbError>) -> Option<T> {
        match r {
            Ok(v) => Some(v),
            Err(_) => {
                self.index_corrupt.inc();
                None
            }
        }
    }

    /// Load one index blob from its file: 3 reads (prelude, header, page)
    /// for a point lookup; `2 + npages` reads for a full scan.
    async fn load_index_lookup(
        &mut self,
        r: &IndexRef,
        elem: &Key,
    ) -> Option<(u32, u64, u64, Option<u64>)> {
        let fd = self.client.open(&r.index_path).await.ok()??;
        let prelude = self.client.read(&fd, r.offset, 12).await.ok()?.to_vec();
        let (header_len, count, v2) = self.parsed(index::parse_prelude(&prelude))?;
        let hdr_bytes = self
            .client
            .read(&fd, r.offset + 12, header_len as u64)
            .await
            .ok()?
            .to_vec();
        let header = self.parsed(index::parse_header(&hdr_bytes, count, v2))?;
        let ec = elem.canonical();
        let page = index::page_for(&header, &ec)?;
        let page_bytes = self
            .client
            .read(&fd, r.offset + page.off, page.len)
            .await
            .ok()?
            .to_vec();
        let entries = self.parsed(index::parse_page(&page_bytes, v2))?;
        entries
            .into_iter()
            .find(|e| e.elem == ec)
            .map(|e| (e.uri_id, e.offset, e.length, e.ck))
    }

    async fn load_index_full(&mut self, r: &IndexRef) -> Vec<index::IndexEntry> {
        let Some(fd) = self.client.open(&r.index_path).await.ok().flatten() else {
            return Vec::new();
        };
        let Ok(prelude) = self.client.read(&fd, r.offset, 12).await else {
            return Vec::new();
        };
        let Some((header_len, count, v2)) = self.parsed(index::parse_prelude(&prelude.to_vec()))
        else {
            return Vec::new();
        };
        let Ok(hdr_bytes) = self
            .client
            .read(&fd, r.offset + 12, header_len as u64)
            .await
        else {
            return Vec::new();
        };
        let Some(header) = self.parsed(index::parse_header(&hdr_bytes.to_vec(), count, v2))
        else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for p in &header.pages {
            if let Ok(bytes) = self.client.read(&fd, r.offset + p.off, p.len).await {
                if let Some(es) = self.parsed(index::parse_page(&bytes.to_vec(), v2)) {
                    out.extend(es);
                }
            }
        }
        out
    }

    fn expand_uri(
        r: &IndexRef,
        uri_id: u32,
        off: u64,
        len: u64,
        ck: Option<u64>,
    ) -> Option<FieldLocation> {
        let root = r.uris.get(uri_id as usize)?;
        if let Some(path) = root.strip_prefix("posix://") {
            Some(FieldLocation::PosixFile {
                path: path.to_string(),
                offset: off,
                length: len,
                checksum: ck,
            })
        } else {
            // non-posix roots are full URIs (checksum included); unknown
            // schemes — tombstones — expand to nothing, masking every
            // older entry for the identifier
            FieldLocation::parse_uri(root)
        }
    }

    /// Catalogue axis(): merged values for one element dimension.
    pub async fn axis(&mut self, ds: &Key, colloc: &Key, dim: &str) -> Vec<String> {
        self.ensure_preloaded(ds).await;
        let cc = colloc.canonical();
        let pre = &self.preloaded[&ds.canonical()];
        let mut vals: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for r in pre.refs.iter().filter(|r| r.colloc == cc) {
            vals.extend(r.axes.values(dim));
        }
        vals.into_iter().collect()
    }

    /// Catalogue retrieve(): newest matching index wins. In index-cache
    /// mode the blob is loaded whole once and point lookups are served
    /// from memory; otherwise each lookup pays the 3-read chain.
    pub async fn retrieve(
        &mut self,
        ds: &Key,
        colloc: &Key,
        elem: &Key,
    ) -> Option<FieldLocation> {
        self.ensure_preloaded(ds).await;
        let cc = colloc.canonical();
        let candidates: Vec<IndexRef> = self.preloaded[&ds.canonical()]
            .refs
            .iter()
            .filter(|r| r.colloc == cc && r.axes.may_contain(elem))
            .cloned()
            .collect();
        let ec = elem.canonical();
        for r in candidates {
            if self.index_cache_on {
                let entries = self.load_index_cached(&r).await;
                if let Some(e) = entries.iter().find(|e| e.elem == ec) {
                    return Self::expand_uri(&r, e.uri_id, e.offset, e.length, e.ck);
                }
            } else if let Some((uri_id, off, len, ck)) = self.load_index_lookup(&r, elem).await
            {
                return Self::expand_uri(&r, uri_id, off, len, ck);
            }
        }
        None
    }

    /// Catalogue list(): all indexed (identifier, location) pairs of the
    /// dataset matching the request. Newest entry wins per identifier.
    pub async fn list(&mut self, ds: &Key, request: &Request) -> Vec<(Key, FieldLocation)> {
        self.ensure_preloaded(ds).await;
        let refs: Vec<IndexRef> = self.preloaded[&ds.canonical()].refs.clone();
        let mut seen: std::collections::HashSet<String> = std::collections::HashSet::new();
        let mut out = Vec::new();
        for r in refs {
            // collocation filter: all request dims fixed in the colloc key
            // must match
            let ck = Key::parse(&r.colloc).unwrap_or_default();
            let fixed = request.fixed_key();
            let colloc_conflict = ck
                .0
                .iter()
                .any(|(d, v)| fixed.get(d).map(|fv| fv != v).unwrap_or(false));
            if colloc_conflict {
                continue;
            }
            let entries = if self.index_cache_on {
                self.load_index_cached(&r).await
            } else {
                Rc::new(self.load_index_full(&r).await)
            };
            for e in entries.iter() {
                let ek = Key::parse(&e.elem).unwrap_or_default();
                let full = ds.merged(&ck).merged(&ek);
                if !request.matches(&full) {
                    continue;
                }
                if !seen.insert(full.canonical()) {
                    continue; // an older duplicate — masked by newer
                }
                if let Some(loc) = Self::expand_uri(&r, e.uri_id, e.offset, e.length, e.ck) {
                    out.push((full, loc));
                }
            }
        }
        out
    }
}

impl crate::fdb::backend::Catalogue for PosixCatalogue {
    fn name(&self) -> &'static str {
        "posix"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        _id: &'a Key,
        loc: &'a FieldLocation,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(PosixCatalogue::archive(self, ds, colloc, elem, loc))
    }

    fn flush<'a>(&'a mut self) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(PosixCatalogue::flush(self))
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::CatalogueSession>> {
        // a forked client is a new reader process: lookups go through the
        // published TOC chain (`preloaded`), which is exactly what the
        // main client's reads consult too — read-equivalent by
        // construction, with its own client for concurrent lookups
        Some(Box::new(
            PosixCatalogue::new(self.client.fork(), &self.root, self.schema.clone())
                .with_index_cache(self.index_cache_on)
                .with_durable(self.durable)
                // sessions share the parent's WAL-sync counter handle
                .with_wal_counter(self.wal_syncs.clone())
                // ... and its corrupt-blob tally
                .with_corrupt_counter(self.index_corrupt.clone()),
        ))
    }

    fn forget<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        _id: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<bool, FdbError>> {
        Box::pin(PosixCatalogue::forget(self, ds, colloc, elem))
    }

    fn begin_archive_group(&mut self) {
        PosixCatalogue::begin_archive_group(self);
    }

    fn end_archive_group<'a>(
        &'a mut self,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(PosixCatalogue::end_archive_group(self))
    }

    fn close<'a>(&'a mut self) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(PosixCatalogue::close(self))
    }

    fn recover_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<RecoveryStats, FdbError>> {
        Box::pin(PosixCatalogue::recover(self, ds))
    }

    fn retrieve<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        _id: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(PosixCatalogue::retrieve(self, ds, colloc, elem))
    }

    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Vec<String>> {
        Box::pin(PosixCatalogue::axis(self, ds, colloc, dim))
    }

    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Vec<(Key, FieldLocation)>> {
        Box::pin(PosixCatalogue::list(self, ds, request))
    }

    fn invalidate_preload(&mut self, ds: &Key) {
        PosixCatalogue::invalidate_preload(self, ds);
    }

    fn deregister_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, ()> {
        // the Store wipe unlinked the dataset's files; drop any stale
        // pre-loaded TOC view so readers re-scan
        PosixCatalogue::invalidate_preload(self, ds);
        crate::fdb::backend::ready(())
    }

    fn take_lock_time(&self) -> crate::sim::time::SimTime {
        self.client.take_lock_time()
    }
}
