//! Serialized paged index — the on-media form of the POSIX Catalogue's
//! B*-tree indexes (thesis §2.7.2).
//!
//! Layout of one index blob (appended to a partial or full index file):
//!
//! ```text
//! [magic u32][header_len u32][count u32]          <- 12-byte prelude
//! header: npages u32, then per page:
//!   first_elem str, page_off u64 (relative to blob start), page_len u64
//! pages: sequence of entries
//!   entry: elem str, uri_id u32, offset u64, length u64
//!          (v2 blobs append: has_ck u8, ck u64 if has_ck == 1)
//! ```
//!
//! Two magics coexist: [`MAGIC`] marks legacy v1 blobs (entries without
//! content checksums), [`MAGIC2`] the v2 form whose entries carry an
//! optional field checksum. Writers emit v2; readers accept both, so
//! indexes persisted before the integrity work keep resolving (their
//! entries are simply unverified).
//!
//! Lookup therefore costs three read ops (prelude → header → leaf page);
//! a full scan costs `2 + npages` — reproducing the "multiple read system
//! calls" behaviour of the real FDB's B*-trees.
//!
//! Every parse function returns a typed [`FdbError::Corrupt`] on
//! truncated or bit-flipped input (they used to be `Option`s the callers
//! unwrapped or silently dropped), so a rotten index blob surfaces as an
//! integrity fault instead of a panic or a silently-absent entry.

use crate::fdb::wire::{Dec, Enc};
use crate::fdb::FdbError;

/// v1 blobs: entries without content checksums.
pub const MAGIC: u32 = 0xFDB_1DE7;
/// v2 blobs: entries carry an optional content checksum.
pub const MAGIC2: u32 = 0xFDB_1DE8;
/// Target serialized page size (like a 4 KiB B-tree node).
pub const PAGE_BYTES: usize = 4096;

fn corrupt(detail: String) -> FdbError {
    FdbError::Corrupt {
        what: "index",
        detail,
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IndexEntry {
    pub elem: String,
    pub uri_id: u32,
    pub offset: u64,
    pub length: u64,
    /// content checksum of the field payload (v2 blobs; `None` for
    /// legacy v1 entries — existence/length-checked only)
    pub ck: Option<u64>,
}

#[derive(Clone, Debug)]
pub struct PageMeta {
    pub first_elem: String,
    /// offset of the page relative to the blob start
    pub off: u64,
    pub len: u64,
}

#[derive(Clone, Debug)]
pub struct IndexHeader {
    pub count: u32,
    pub pages: Vec<PageMeta>,
    /// whether the blob's pages use the v2 entry encoding
    pub v2: bool,
}

/// Serialize `entries` (must be sorted by `elem`) into a v2 index blob.
pub fn serialize(entries: &[IndexEntry]) -> Vec<u8> {
    debug_assert!(entries.windows(2).all(|w| w[0].elem <= w[1].elem));
    // 1. cut entries into pages of ~PAGE_BYTES
    let mut pages: Vec<(String, Vec<u8>)> = Vec::new();
    let mut cur = Enc::new();
    let mut cur_first: Option<String> = None;
    for e in entries {
        if cur_first.is_none() {
            cur_first = Some(e.elem.clone());
        }
        cur.str(&e.elem).u32(e.uri_id).u64(e.offset).u64(e.length);
        match e.ck {
            Some(ck) => {
                cur.u8(1).u64(ck);
            }
            None => {
                cur.u8(0);
            }
        }
        if cur.buf.len() >= PAGE_BYTES {
            pages.push((cur_first.take().unwrap(), std::mem::take(&mut cur).finish()));
            cur = Enc::new();
        }
    }
    if cur_first.is_some() {
        pages.push((cur_first.unwrap(), cur.finish()));
    }
    // 2. header
    let mut header = Enc::new();
    header.u32(pages.len() as u32);
    // compute page offsets: prelude(12) + header_len + payload offsets.
    // header size depends on its own content only (offsets are u64s we
    // fill after a first pass measuring the header length).
    let mut measure = Enc::new();
    measure.u32(pages.len() as u32);
    for (first, data) in &pages {
        measure.str(first).u64(0).u64(data.len() as u64);
    }
    let header_len = measure.finish().len();
    let mut off = 12 + header_len as u64;
    for (first, data) in &pages {
        header.str(first).u64(off).u64(data.len() as u64);
        off += data.len() as u64;
    }
    let header = header.finish();
    debug_assert_eq!(header.len(), header_len);
    // 3. assemble
    let mut out = Enc::new();
    out.u32(MAGIC2);
    let mut blob = out.finish();
    blob.extend_from_slice(&(header.len() as u32).to_le_bytes());
    blob.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    blob.extend_from_slice(&header);
    for (_, data) in pages {
        blob.extend_from_slice(&data);
    }
    blob
}

/// Parse the 12-byte prelude → (header_len, entry count, v2?).
pub fn parse_prelude(bytes: &[u8]) -> Result<(u32, u32, bool), FdbError> {
    let mut d = Dec::new(bytes);
    let magic = d
        .u32()
        .ok_or_else(|| corrupt(format!("prelude truncated: {} bytes", bytes.len())))?;
    let v2 = match magic {
        MAGIC => false,
        MAGIC2 => true,
        other => {
            return Err(corrupt(format!(
                "bad magic {other:#010x} (want {MAGIC:#010x} or {MAGIC2:#010x})"
            )))
        }
    };
    let header_len = d
        .u32()
        .ok_or_else(|| corrupt("prelude truncated before header_len".into()))?;
    let count = d
        .u32()
        .ok_or_else(|| corrupt("prelude truncated before count".into()))?;
    Ok((header_len, count, v2))
}

/// Parse the header region (bytes immediately after the prelude).
pub fn parse_header(bytes: &[u8], count: u32, v2: bool) -> Result<IndexHeader, FdbError> {
    let mut d = Dec::new(bytes);
    let npages = d
        .u32()
        .ok_or_else(|| corrupt("header truncated before page count".into()))?;
    let mut pages = Vec::with_capacity(npages as usize);
    for i in 0..npages {
        let first_elem = d
            .str()
            .ok_or_else(|| corrupt(format!("header truncated in page {i}/{npages} key")))?;
        let off = d
            .u64()
            .ok_or_else(|| corrupt(format!("header truncated in page {i}/{npages} offset")))?;
        let len = d
            .u64()
            .ok_or_else(|| corrupt(format!("header truncated in page {i}/{npages} length")))?;
        pages.push(PageMeta {
            first_elem,
            off,
            len,
        });
    }
    Ok(IndexHeader { count, pages, v2 })
}

/// Parse one page's entries (`v2` selects the entry encoding).
pub fn parse_page(bytes: &[u8], v2: bool) -> Result<Vec<IndexEntry>, FdbError> {
    let mut d = Dec::new(bytes);
    let mut out = Vec::new();
    while d.remaining() > 0 {
        let at = out.len();
        let elem = d
            .str()
            .ok_or_else(|| corrupt(format!("page truncated in entry {at} key")))?;
        let uri_id = d
            .u32()
            .ok_or_else(|| corrupt(format!("page truncated in entry {at} uri id")))?;
        let offset = d
            .u64()
            .ok_or_else(|| corrupt(format!("page truncated in entry {at} offset")))?;
        let length = d
            .u64()
            .ok_or_else(|| corrupt(format!("page truncated in entry {at} length")))?;
        let ck = if v2 {
            match d
                .u8()
                .ok_or_else(|| corrupt(format!("page truncated in entry {at} ck flag")))?
            {
                0 => None,
                1 => Some(d.u64().ok_or_else(|| {
                    corrupt(format!("page truncated in entry {at} checksum"))
                })?),
                f => return Err(corrupt(format!("entry {at}: bad ck flag {f}"))),
            }
        } else {
            None
        };
        out.push(IndexEntry {
            elem,
            uri_id,
            offset,
            length,
            ck,
        });
    }
    Ok(out)
}

/// Which page may contain `elem` (binary search over first keys).
pub fn page_for<'h>(header: &'h IndexHeader, elem: &str) -> Option<&'h PageMeta> {
    if header.pages.is_empty() {
        return None;
    }
    let idx = match header
        .pages
        .binary_search_by(|p| p.first_elem.as_str().cmp(elem))
    {
        Ok(i) => i,
        Err(0) => return None, // elem sorts before the first page
        Err(i) => i - 1,
    };
    Some(&header.pages[idx])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(n: usize) -> Vec<IndexEntry> {
        let mut v: Vec<IndexEntry> = (0..n)
            .map(|i| IndexEntry {
                elem: format!("param=p{:04},step={:03}", i % 7, i),
                uri_id: (i % 3) as u32,
                offset: (i * 1024) as u64,
                length: 1024,
                ck: if i % 2 == 0 { Some(i as u64) } else { None },
            })
            .collect();
        v.sort_by(|a, b| a.elem.cmp(&b.elem));
        v
    }

    fn parse_all(blob: &[u8]) -> Vec<IndexEntry> {
        let (hl, count, v2) = parse_prelude(&blob[..12]).unwrap();
        let header = parse_header(&blob[12..12 + hl as usize], count, v2).unwrap();
        let mut out = Vec::new();
        for p in &header.pages {
            out.extend(
                parse_page(&blob[p.off as usize..(p.off + p.len) as usize], v2).unwrap(),
            );
        }
        out
    }

    #[test]
    fn roundtrip_small() {
        let es = entries(5);
        let blob = serialize(&es);
        assert_eq!(parse_all(&blob), es);
    }

    #[test]
    fn roundtrip_multipage() {
        let es = entries(2000);
        let blob = serialize(&es);
        let (hl, count, v2) = parse_prelude(&blob[..12]).unwrap();
        assert_eq!(count, 2000);
        assert!(v2);
        let header = parse_header(&blob[12..12 + hl as usize], count, v2).unwrap();
        assert!(header.pages.len() > 5, "expected multiple pages");
        assert_eq!(parse_all(&blob), es);
    }

    #[test]
    fn lookup_via_page_directory() {
        let es = entries(2000);
        let blob = serialize(&es);
        let (hl, count, v2) = parse_prelude(&blob[..12]).unwrap();
        let header = parse_header(&blob[12..12 + hl as usize], count, v2).unwrap();
        for probe in [0usize, 1, 999, 1999] {
            let elem = &es[probe].elem;
            let page = page_for(&header, elem).unwrap();
            let items =
                parse_page(&blob[page.off as usize..(page.off + page.len) as usize], v2)
                    .unwrap();
            let found = items.iter().find(|e| &e.elem == elem).unwrap();
            assert_eq!(found, &es[probe]);
        }
    }

    #[test]
    fn missing_key_page_scan_misses() {
        let es = entries(100);
        let blob = serialize(&es);
        let (hl, count, v2) = parse_prelude(&blob[..12]).unwrap();
        let header = parse_header(&blob[12..12 + hl as usize], count, v2).unwrap();
        if let Some(page) = page_for(&header, "zzz=unknown") {
            let items =
                parse_page(&blob[page.off as usize..(page.off + page.len) as usize], v2)
                    .unwrap();
            assert!(items.iter().all(|e| e.elem != "zzz=unknown"));
        }
    }

    #[test]
    fn empty_index() {
        let blob = serialize(&[]);
        let (hl, count, v2) = parse_prelude(&blob[..12]).unwrap();
        assert_eq!(count, 0);
        let header = parse_header(&blob[12..12 + hl as usize], count, v2).unwrap();
        assert!(header.pages.is_empty());
        assert!(page_for(&header, "anything").is_none());
    }

    #[test]
    fn legacy_v1_blob_parses_without_checksums() {
        // hand-assemble a v1 blob: MAGIC prelude + one page of v1 entries
        let mut page = Enc::new();
        page.str("step=1").u32(0).u64(0).u64(512);
        page.str("step=2").u32(0).u64(512).u64(512);
        let page = page.finish();
        let mut header = Enc::new();
        header.u32(1);
        let mut measure = Enc::new();
        measure.u32(1).str("step=1").u64(0).u64(0);
        let hl = measure.finish().len();
        header
            .str("step=1")
            .u64(12 + hl as u64)
            .u64(page.len() as u64);
        let header = header.finish();
        assert_eq!(header.len(), hl);
        let mut blob = Vec::new();
        blob.extend_from_slice(&MAGIC.to_le_bytes());
        blob.extend_from_slice(&(header.len() as u32).to_le_bytes());
        blob.extend_from_slice(&2u32.to_le_bytes());
        blob.extend_from_slice(&header);
        blob.extend_from_slice(&page);
        let parsed = parse_all(&blob);
        assert_eq!(parsed.len(), 2);
        assert!(parsed.iter().all(|e| e.ck.is_none()));
        assert_eq!(parsed[1].offset, 512);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut blob = serialize(&entries(3));
        blob[0] ^= 0xFF;
        let err = parse_prelude(&blob[..12]).unwrap_err();
        assert!(matches!(err, FdbError::Corrupt { what: "index", .. }), "{err}");
    }

    #[test]
    fn truncated_blob_is_typed_corrupt_not_panic() {
        let blob = serialize(&entries(40));
        // prelude shorter than 12 bytes
        assert!(matches!(
            parse_prelude(&blob[..7]),
            Err(FdbError::Corrupt { .. })
        ));
        let (hl, count, v2) = parse_prelude(&blob[..12]).unwrap();
        // header cut mid-page-directory
        let hdr = &blob[12..12 + hl as usize];
        assert!(matches!(
            parse_header(&hdr[..hdr.len() / 2], count, v2),
            Err(FdbError::Corrupt { .. })
        ));
        // page cut mid-entry
        let header = parse_header(hdr, count, v2).unwrap();
        let p = &header.pages[0];
        let page = &blob[p.off as usize..(p.off + p.len) as usize];
        assert!(matches!(
            parse_page(&page[..page.len() - 3], v2),
            Err(FdbError::Corrupt { .. })
        ));
    }

    #[test]
    fn bit_flipped_page_is_typed_corrupt() {
        let es = entries(8);
        let blob = serialize(&es);
        let (hl, count, v2) = parse_prelude(&blob[..12]).unwrap();
        let header = parse_header(&blob[12..12 + hl as usize], count, v2).unwrap();
        let p = &header.pages[0];
        let mut page = blob[p.off as usize..(p.off + p.len) as usize].to_vec();
        // flip a bit in the high byte of the first entry's key-length
        // prefix so the string read runs far off the end of the page
        page[2] ^= 0x40;
        match parse_page(&page, v2) {
            Err(FdbError::Corrupt { what, .. }) => assert_eq!(what, "index"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }
}
