//! Serialized paged index — the on-media form of the POSIX Catalogue's
//! B*-tree indexes (thesis §2.7.2).
//!
//! Layout of one index blob (appended to a partial or full index file):
//!
//! ```text
//! [magic u32][header_len u32][count u32]          <- 12-byte prelude
//! header: npages u32, then per page:
//!   first_elem str, page_off u64 (relative to blob start), page_len u64
//! pages: sequence of entries
//!   entry: elem str, uri_id u32, offset u64, length u64
//! ```
//!
//! Lookup therefore costs three read ops (prelude → header → leaf page);
//! a full scan costs `2 + npages` — reproducing the "multiple read system
//! calls" behaviour of the real FDB's B*-trees.

use crate::fdb::wire::{Dec, Enc};

pub const MAGIC: u32 = 0xFDB_1DE7;
/// Target serialized page size (like a 4 KiB B-tree node).
pub const PAGE_BYTES: usize = 4096;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IndexEntry {
    pub elem: String,
    pub uri_id: u32,
    pub offset: u64,
    pub length: u64,
}

#[derive(Clone, Debug)]
pub struct PageMeta {
    pub first_elem: String,
    /// offset of the page relative to the blob start
    pub off: u64,
    pub len: u64,
}

#[derive(Clone, Debug)]
pub struct IndexHeader {
    pub count: u32,
    pub pages: Vec<PageMeta>,
}

/// Serialize `entries` (must be sorted by `elem`) into an index blob.
pub fn serialize(entries: &[IndexEntry]) -> Vec<u8> {
    debug_assert!(entries.windows(2).all(|w| w[0].elem <= w[1].elem));
    // 1. cut entries into pages of ~PAGE_BYTES
    let mut pages: Vec<(String, Vec<u8>)> = Vec::new();
    let mut cur = Enc::new();
    let mut cur_first: Option<String> = None;
    for e in entries {
        if cur_first.is_none() {
            cur_first = Some(e.elem.clone());
        }
        cur.str(&e.elem).u32(e.uri_id).u64(e.offset).u64(e.length);
        if cur.buf.len() >= PAGE_BYTES {
            pages.push((cur_first.take().unwrap(), std::mem::take(&mut cur).finish()));
            cur = Enc::new();
        }
    }
    if cur_first.is_some() {
        pages.push((cur_first.unwrap(), cur.finish()));
    }
    // 2. header
    let mut header = Enc::new();
    header.u32(pages.len() as u32);
    // compute page offsets: prelude(12) + header_len + payload offsets.
    // header size depends on its own content only (offsets are u64s we
    // fill after a first pass measuring the header length).
    let mut measure = Enc::new();
    measure.u32(pages.len() as u32);
    for (first, data) in &pages {
        measure.str(first).u64(0).u64(data.len() as u64);
    }
    let header_len = measure.finish().len();
    let mut off = 12 + header_len as u64;
    for (first, data) in &pages {
        header.str(first).u64(off).u64(data.len() as u64);
        off += data.len() as u64;
    }
    let header = header.finish();
    debug_assert_eq!(header.len(), header_len);
    // 3. assemble
    let mut out = Enc::new();
    out.u32(MAGIC);
    let mut blob = out.finish();
    blob.extend_from_slice(&(header.len() as u32).to_le_bytes());
    blob.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    blob.extend_from_slice(&header);
    for (_, data) in pages {
        blob.extend_from_slice(&data);
    }
    blob
}

/// Parse the 12-byte prelude → (header_len, entry count).
pub fn parse_prelude(bytes: &[u8]) -> Option<(u32, u32)> {
    let mut d = Dec::new(bytes);
    if d.u32()? != MAGIC {
        return None;
    }
    let header_len = d.u32()?;
    let count = d.u32()?;
    Some((header_len, count))
}

/// Parse the header region (bytes immediately after the prelude).
pub fn parse_header(bytes: &[u8], count: u32) -> Option<IndexHeader> {
    let mut d = Dec::new(bytes);
    let npages = d.u32()?;
    let mut pages = Vec::with_capacity(npages as usize);
    for _ in 0..npages {
        pages.push(PageMeta {
            first_elem: d.str()?,
            off: d.u64()?,
            len: d.u64()?,
        });
    }
    Some(IndexHeader { count, pages })
}

/// Parse one page's entries.
pub fn parse_page(bytes: &[u8]) -> Option<Vec<IndexEntry>> {
    let mut d = Dec::new(bytes);
    let mut out = Vec::new();
    while d.remaining() > 0 {
        out.push(IndexEntry {
            elem: d.str()?,
            uri_id: d.u32()?,
            offset: d.u64()?,
            length: d.u64()?,
        });
    }
    Some(out)
}

/// Which page may contain `elem` (binary search over first keys).
pub fn page_for<'h>(header: &'h IndexHeader, elem: &str) -> Option<&'h PageMeta> {
    if header.pages.is_empty() {
        return None;
    }
    let idx = match header
        .pages
        .binary_search_by(|p| p.first_elem.as_str().cmp(elem))
    {
        Ok(i) => i,
        Err(0) => return None, // elem sorts before the first page
        Err(i) => i - 1,
    };
    Some(&header.pages[idx])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(n: usize) -> Vec<IndexEntry> {
        let mut v: Vec<IndexEntry> = (0..n)
            .map(|i| IndexEntry {
                elem: format!("param=p{:04},step={:03}", i % 7, i),
                uri_id: (i % 3) as u32,
                offset: (i * 1024) as u64,
                length: 1024,
            })
            .collect();
        v.sort_by(|a, b| a.elem.cmp(&b.elem));
        v
    }

    fn parse_all(blob: &[u8]) -> Vec<IndexEntry> {
        let (hl, count) = parse_prelude(&blob[..12]).unwrap();
        let header = parse_header(&blob[12..12 + hl as usize], count).unwrap();
        let mut out = Vec::new();
        for p in &header.pages {
            out.extend(
                parse_page(&blob[p.off as usize..(p.off + p.len) as usize]).unwrap(),
            );
        }
        out
    }

    #[test]
    fn roundtrip_small() {
        let es = entries(5);
        let blob = serialize(&es);
        assert_eq!(parse_all(&blob), es);
    }

    #[test]
    fn roundtrip_multipage() {
        let es = entries(2000);
        let blob = serialize(&es);
        let (hl, count) = parse_prelude(&blob[..12]).unwrap();
        assert_eq!(count, 2000);
        let header = parse_header(&blob[12..12 + hl as usize], count).unwrap();
        assert!(header.pages.len() > 5, "expected multiple pages");
        assert_eq!(parse_all(&blob), es);
    }

    #[test]
    fn lookup_via_page_directory() {
        let es = entries(2000);
        let blob = serialize(&es);
        let (hl, count) = parse_prelude(&blob[..12]).unwrap();
        let header = parse_header(&blob[12..12 + hl as usize], count).unwrap();
        for probe in [0usize, 1, 999, 1999] {
            let elem = &es[probe].elem;
            let page = page_for(&header, elem).unwrap();
            let items =
                parse_page(&blob[page.off as usize..(page.off + page.len) as usize]).unwrap();
            let found = items.iter().find(|e| &e.elem == elem).unwrap();
            assert_eq!(found, &es[probe]);
        }
    }

    #[test]
    fn missing_key_page_scan_misses() {
        let es = entries(100);
        let blob = serialize(&es);
        let (hl, count) = parse_prelude(&blob[..12]).unwrap();
        let header = parse_header(&blob[12..12 + hl as usize], count).unwrap();
        if let Some(page) = page_for(&header, "zzz=unknown") {
            let items =
                parse_page(&blob[page.off as usize..(page.off + page.len) as usize]).unwrap();
            assert!(items.iter().all(|e| e.elem != "zzz=unknown"));
        }
    }

    #[test]
    fn empty_index() {
        let blob = serialize(&[]);
        let (hl, count) = parse_prelude(&blob[..12]).unwrap();
        assert_eq!(count, 0);
        let header = parse_header(&blob[12..12 + hl as usize], count).unwrap();
        assert!(header.pages.is_empty());
        assert!(page_for(&header, "anything").is_none());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut blob = serialize(&entries(3));
        blob[0] ^= 0xFF;
        assert!(parse_prelude(&blob[..12]).is_none());
    }
}
