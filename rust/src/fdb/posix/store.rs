//! The FDB POSIX I/O Store (thesis §2.7.2): per-process data files under
//! a directory per dataset key, buffered writes, persistence on flush(),
//! 8×8 MiB striping on Lustre.

use std::collections::{HashMap, HashSet};

use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::FdbError;
use crate::lustre::{Fd, FsError, LustreClient, StripeSpec};
use crate::util::content::Bytes;

/// Typed backend error for a failed filesystem operation (replaces the
/// former `panic!`/`expect` sites on the archive path). Shared with the
/// POSIX Catalogue, whose archive path has the same error surface.
pub(crate) fn fs_err(op: &str, path: &str, e: FsError) -> FdbError {
    FdbError::Backend {
        backend: "posix",
        detail: format!("{op} {path}: {e}"),
    }
}

pub struct PosixStore {
    pub(crate) client: LustreClient,
    root: String,
    /// per (dataset, collocation): the process-unique data file
    data_files: HashMap<(String, String), Fd>,
    known_dirs: HashSet<String>,
    file_counter: u64,
}

impl PosixStore {
    pub fn new(client: LustreClient, root: &str) -> PosixStore {
        PosixStore {
            client,
            root: root.to_string(),
            data_files: HashMap::new(),
            known_dirs: HashSet::new(),
            file_counter: 0,
        }
    }

    pub fn dataset_dir(&self, ds: &Key) -> String {
        format!("{}/{}", self.root, ds.canonical())
    }

    /// Create-if-missing of the dataset directory (atomic mkdir). A
    /// real failure (e.g. a path component that is a regular file)
    /// surfaces as [`FdbError::Backend`] — it used to panic.
    pub(crate) async fn ensure_dir(&mut self, dir: &str) -> Result<(), FdbError> {
        if self.known_dirs.contains(dir) {
            return Ok(());
        }
        match self.client.mkdir(dir).await {
            Ok(()) | Err(FsError::AlreadyExists) => {}
            Err(e) => return Err(fs_err("mkdir", dir, e)),
        }
        self.known_dirs.insert(dir.to_string());
        Ok(())
    }

    /// Store archive(): buffer the object into the per-process data file;
    /// returns a location descriptor immediately (data not yet durable).
    pub async fn archive(
        &mut self,
        ds: &Key,
        colloc: &Key,
        data: Bytes,
    ) -> Result<FieldLocation, FdbError> {
        let dir = self.dataset_dir(ds);
        self.ensure_dir(&dir).await?;
        let key = (ds.canonical(), colloc.canonical());
        if !self.data_files.contains_key(&key) {
            // unique per process: collocation + client id + counter
            // (stands in for host+pid+time in the real naming scheme)
            let path = format!(
                "{dir}/{}.{}.{}.data",
                sanitize(&colloc.canonical()),
                self.client.id,
                self.file_counter
            );
            self.file_counter += 1;
            let fd = self
                .client
                .create(&path, StripeSpec::fdb_data())
                .await
                .map_err(|e| fs_err("create", &path, e))?;
            self.data_files.insert(key.clone(), fd);
        }
        let fd = self.data_files.get(&key).unwrap().clone();
        let length = data.len();
        let offset = self
            .client
            .write_data(&fd, data)
            .await
            .map_err(|e| fs_err("write", fd.path(), e))?;
        Ok(FieldLocation::PosixFile {
            path: fd.path().to_string(),
            offset,
            length,
            checksum: None,
        })
    }

    /// Store flush(): fdatasync every data file this process wrote.
    pub async fn flush(&mut self) -> Result<(), FdbError> {
        let fds: Vec<Fd> = self.data_files.values().cloned().collect();
        for fd in fds {
            self.client
                .fdatasync(&fd)
                .await
                .map_err(|e| fs_err("fdatasync", fd.path(), e))?;
        }
        Ok(())
    }

    /// Open a data file for reading; a missing file or a failed open is
    /// a typed backend error (it used to panic).
    async fn open_data(&mut self, path: &str) -> Result<Fd, FdbError> {
        self.client
            .open(path)
            .await
            .map_err(|e| fs_err("open", path, e))?
            .ok_or_else(|| fs_err("open", path, FsError::NotFound))
    }

    /// Read the byte ranges of a (merged) POSIX handle.
    pub async fn read_ranges(
        &mut self,
        path: &str,
        ranges: &[(u64, u64)],
    ) -> Result<Bytes, FdbError> {
        let fd = self.open_data(path).await?;
        let mut out = Bytes::new();
        for &(off, len) in ranges {
            out.append(
                self.client
                    .read(&fd, off, len)
                    .await
                    .map_err(|e| fs_err("read", path, e))?,
            );
        }
        Ok(out)
    }

    /// Profiling helper: drain DLM lock time accumulated by this client.
    pub fn take_lock_time(&self) -> crate::sim::time::SimTime {
        self.client.take_lock_time()
    }

    /// Unlink every file of the dataset directory (fdb-wipe).
    pub async fn wipe_dataset(&mut self, ds: &Key) -> bool {
        let dir = self.dataset_dir(ds);
        let Ok(children) = self.client.readdir(&dir).await else {
            return false;
        };
        let any = !children.is_empty();
        for child in children {
            let _ = self.client.unlink(&format!("{dir}/{child}")).await;
        }
        self.data_files
            .retain(|(d, _), _| d != &ds.canonical());
        any
    }
}

impl crate::fdb::backend::Store for PosixStore {
    fn name(&self) -> &'static str {
        "posix"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        _id: &'a Key,
        data: Bytes,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<FieldLocation, crate::fdb::FdbError>>
    {
        Box::pin(PosixStore::archive(self, ds, colloc, data))
    }

    fn flush<'a>(
        &'a mut self,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), crate::fdb::FdbError>> {
        Box::pin(PosixStore::flush(self))
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a crate::fdb::DataHandle,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<Bytes, crate::fdb::FdbError>> {
        Box::pin(async move {
            match handle {
                crate::fdb::DataHandle::Posix { path, ranges } => {
                    self.read_ranges(path, ranges).await
                }
                other => Err(crate::fdb::FdbError::BackendMismatch {
                    store: "posix",
                    handle: other.backend_name(),
                }),
            }
        })
    }

    /// The vectored read path: one open per distinct data file for the
    /// whole batch (the read planner's merged ranges usually share a
    /// file), then ranged reads against the cached descriptors.
    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [crate::fdb::DataHandle],
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<Vec<Bytes>, crate::fdb::FdbError>> {
        Box::pin(async move {
            let mut fds: HashMap<&str, Fd> = HashMap::new();
            let mut out = Vec::with_capacity(handles.len());
            for handle in handles {
                let crate::fdb::DataHandle::Posix { path, ranges } = handle else {
                    return Err(crate::fdb::FdbError::BackendMismatch {
                        store: "posix",
                        handle: handle.backend_name(),
                    });
                };
                let fd = match fds.get(path.as_str()) {
                    Some(fd) => fd.clone(),
                    None => {
                        let fd = self.open_data(path).await?;
                        fds.insert(path.as_str(), fd.clone());
                        fd
                    }
                };
                let mut bytes = Bytes::new();
                for &(off, len) in ranges {
                    bytes.append(
                        self.client
                            .read(&fd, off, len)
                            .await
                            .map_err(|e| fs_err("read", path, e))?,
                    );
                }
                out.push(bytes);
            }
            Ok(out)
        })
    }

    /// Scrub repair: rewrite the handle's byte ranges in place from
    /// verified data (positional writes + fdatasync). The shared-file
    /// layout makes this the canonical-copy repair under replication.
    fn repair<'a>(
        &'a mut self,
        handle: &'a crate::fdb::DataHandle,
        data: Bytes,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<bool, crate::fdb::FdbError>> {
        Box::pin(async move {
            let crate::fdb::DataHandle::Posix { path, ranges } = handle else {
                return Err(crate::fdb::FdbError::BackendMismatch {
                    store: "posix",
                    handle: handle.backend_name(),
                });
            };
            let fd = self.open_data(path).await?;
            let mut rel = 0u64;
            for &(off, len) in ranges {
                self.client
                    .pwrite_data(&fd, off, data.slice(rel, len))
                    .await
                    .map_err(|e| fs_err("pwrite", path, e))?;
                rel += len;
            }
            self.client
                .fdatasync(&fd)
                .await
                .map_err(|e| fs_err("fdatasync", path, e))?;
            Ok(true)
        })
    }

    /// Orphan detection: every `*.data` file under the dataset directory
    /// (quarantined `*.orphan` files are already out of the data path).
    fn scrub_inventory<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Option<Vec<(String, u64)>>> {
        Box::pin(async move {
            let dir = self.dataset_dir(ds);
            let Ok(children) = self.client.readdir(&dir).await else {
                // no dataset directory: nothing stored, nothing orphaned
                return Some(Vec::new());
            };
            let mut out = Vec::new();
            for child in children {
                if !child.ends_with(".data") {
                    continue;
                }
                let path = format!("{dir}/{child}");
                if let Some(size) = self.client.stat(&path).await {
                    out.push((format!("posix://{path}"), size));
                }
            }
            Some(out)
        })
    }

    /// Orphan repair: copy the unreferenced data file aside as
    /// `<path>.orphan` and unlink the original (no rename in the
    /// simulated VFS), so reads can never resolve into it again.
    fn quarantine_object<'a>(
        &'a mut self,
        _ds: &'a Key,
        container: &'a str,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<bool, crate::fdb::FdbError>> {
        Box::pin(async move {
            let Some(path) = container.strip_prefix("posix://") else {
                return Ok(false);
            };
            let bytes = self
                .client
                .read_all(path)
                .await
                .map_err(|e| fs_err("read", path, e))?;
            let aside = format!("{path}.orphan");
            let fd = self
                .client
                .create(&aside, StripeSpec::fdb_data())
                .await
                .map_err(|e| fs_err("create", &aside, e))?;
            self.client
                .write_data(&fd, bytes)
                .await
                .map_err(|e| fs_err("write", &aside, e))?;
            self.client
                .fdatasync(&fd)
                .await
                .map_err(|e| fs_err("fdatasync", &aside, e))?;
            self.client
                .unlink(path)
                .await
                .map_err(|e| fs_err("unlink", path, e))?;
            Ok(true)
        })
    }

    fn supports_wipe(&self) -> bool {
        true
    }

    fn wipe_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, bool> {
        Box::pin(PosixStore::wipe_dataset(self, ds))
    }

    fn take_lock_time(&self) -> crate::sim::time::SimTime {
        PosixStore::take_lock_time(self)
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::StoreSession>> {
        // a session is a full store over a forked client: its own client
        // id (unique data-file names), page cache, and DLM identity —
        // like one more rank of the same writer job
        Some(Box::new(PosixStore::new(self.client.fork(), &self.root)))
    }
}

/// Replace path-hostile characters in canonical keys.
pub(crate) fn sanitize(s: &str) -> String {
    s.replace(['/', '\\'], "_")
}
