//! TOC / sub-TOC record formats (thesis §2.7.2, Figs 2.5–2.10).
//!
//! The shared TOC file binds all per-process structures together:
//! `Init` (dataset header), `SubToc` (pointer appended on first flush),
//! `Index` (full-index entry appended at close), `Mask` (signals readers
//! to skip superseded sub-TOCs). Sub-TOC files hold `IndexRef` records:
//! one per flushed partial index, carrying the axes + URI store so
//! readers get summaries without scanning index pages.
//!
//! Records are framed `[type u8][len u32][payload]`; appends are atomic
//! (single O_APPEND write < block size for TOC pointers — the POSIX
//! guarantee the thesis relies on).

use std::collections::{BTreeMap, BTreeSet};

use crate::fdb::key::Key;
use crate::fdb::wire::{Dec, Enc};

/// Axes: per element-dimension value summaries (thesis "axes" helper).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Axes(pub BTreeMap<String, BTreeSet<String>>);

impl Axes {
    pub fn new() -> Axes {
        Axes::default()
    }

    /// Record all dims of an element key.
    pub fn insert_key(&mut self, elem: &Key) {
        for (dim, val) in &elem.0 {
            self.0
                .entry(dim.clone())
                .or_default()
                .insert(val.clone());
        }
    }

    /// Could this axes summary contain the element key?
    pub fn may_contain(&self, elem: &Key) -> bool {
        elem.0.iter().all(|(dim, val)| {
            self.0
                .get(dim)
                .map(|vals| vals.contains(val))
                .unwrap_or(false)
        })
    }

    pub fn values(&self, dim: &str) -> Vec<String> {
        self.0
            .get(dim)
            .map(|s| s.iter().cloned().collect())
            .unwrap_or_default()
    }

    pub fn merge(&mut self, other: &Axes) {
        for (dim, vals) in &other.0 {
            self.0.entry(dim.clone()).or_default().extend(vals.iter().cloned());
        }
    }

    fn encode(&self, e: &mut Enc) {
        e.u32(self.0.len() as u32);
        for (dim, vals) in &self.0 {
            e.str(dim).u32(vals.len() as u32);
            for v in vals {
                e.str(v);
            }
        }
    }

    fn decode(d: &mut Dec) -> Option<Axes> {
        let ndims = d.u32()?;
        let mut out = BTreeMap::new();
        for _ in 0..ndims {
            let dim = d.str()?;
            let nvals = d.u32()?;
            let mut set = BTreeSet::new();
            for _ in 0..nvals {
                set.insert(d.str()?);
            }
            out.insert(dim, set);
        }
        Some(Axes(out))
    }
}

/// A pointer to one serialized index blob + its summaries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IndexRef {
    /// canonical collocation key
    pub colloc: String,
    pub index_path: String,
    /// blob offset within the index file
    pub offset: u64,
    pub length: u64,
    pub axes: Axes,
    /// URI store: uri_id → data-file URI root
    pub uris: Vec<String>,
}

impl IndexRef {
    fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.str(&self.colloc)
            .str(&self.index_path)
            .u64(self.offset)
            .u64(self.length);
        self.axes.encode(&mut e);
        e.u32(self.uris.len() as u32);
        for u in &self.uris {
            e.str(u);
        }
        e.finish()
    }

    fn decode(bytes: &[u8]) -> Option<IndexRef> {
        let mut d = Dec::new(bytes);
        let colloc = d.str()?;
        let index_path = d.str()?;
        let offset = d.u64()?;
        let length = d.u64()?;
        let axes = Axes::decode(&mut d)?;
        let nuris = d.u32()?;
        let mut uris = Vec::with_capacity(nuris as usize);
        for _ in 0..nuris {
            uris.push(d.str()?);
        }
        Some(IndexRef {
            colloc,
            index_path,
            offset,
            length,
            axes,
            uris,
        })
    }
}

/// A TOC (or sub-TOC) record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TocRecord {
    /// dataset initialisation header
    Init { dataset: String },
    /// pointer to a per-process sub-TOC file
    SubToc { path: String },
    /// a full-index entry (appended at Catalogue close())
    Index(IndexRef),
    /// mask: readers skip the named sub-TOC path
    Mask { path: String },
}

impl TocRecord {
    pub fn encode(&self) -> Vec<u8> {
        let (tag, payload): (u8, Vec<u8>) = match self {
            TocRecord::Init { dataset } => {
                let mut e = Enc::new();
                e.str(dataset);
                (0, e.finish())
            }
            TocRecord::SubToc { path } => {
                let mut e = Enc::new();
                e.str(path);
                (1, e.finish())
            }
            TocRecord::Index(r) => (2, r.encode()),
            TocRecord::Mask { path } => {
                let mut e = Enc::new();
                e.str(path);
                (3, e.finish())
            }
        };
        let mut out = Vec::with_capacity(payload.len() + 5);
        out.push(tag);
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Parse a whole TOC/sub-TOC file into records (in append order).
    /// Tolerates a torn trailing record (dropped, like the real FDB).
    pub fn parse_stream(bytes: &[u8]) -> Vec<TocRecord> {
        let mut out = Vec::new();
        let mut pos = 0usize;
        while pos + 5 <= bytes.len() {
            let tag = bytes[pos];
            let len =
                u32::from_le_bytes(bytes[pos + 1..pos + 5].try_into().unwrap()) as usize;
            if pos + 5 + len > bytes.len() {
                break; // torn tail
            }
            let payload = &bytes[pos + 5..pos + 5 + len];
            pos += 5 + len;
            let rec = match tag {
                0 => Dec::new(payload).str().map(|dataset| TocRecord::Init { dataset }),
                1 => Dec::new(payload).str().map(|path| TocRecord::SubToc { path }),
                2 => IndexRef::decode(payload).map(TocRecord::Index),
                3 => Dec::new(payload).str().map(|path| TocRecord::Mask { path }),
                _ => None,
            };
            match rec {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_ref() -> IndexRef {
        let mut axes = Axes::new();
        axes.insert_key(&Key::of(&[("step", "1"), ("param", "v")]));
        axes.insert_key(&Key::of(&[("step", "2"), ("param", "v")]));
        IndexRef {
            colloc: "levtype=sfc,type=ef".into(),
            index_path: "/fdb/ds/x.index".into(),
            offset: 4096,
            length: 512,
            axes,
            uris: vec!["posix:///fdb/ds/x.data".into()],
        }
    }

    #[test]
    fn record_stream_roundtrip() {
        let records = vec![
            TocRecord::Init {
                dataset: "class=od,date=20231201".into(),
            },
            TocRecord::SubToc {
                path: "/fdb/ds/p0.subtoc".into(),
            },
            TocRecord::Index(sample_ref()),
            TocRecord::Mask {
                path: "/fdb/ds/p0.subtoc".into(),
            },
        ];
        let mut bytes = Vec::new();
        for r in &records {
            bytes.extend(r.encode());
        }
        let parsed = TocRecord::parse_stream(&bytes);
        assert_eq!(parsed, records);
    }

    #[test]
    fn torn_tail_dropped() {
        let mut bytes = TocRecord::Init {
            dataset: "d".into(),
        }
        .encode();
        let full = TocRecord::SubToc {
            path: "/x".into(),
        }
        .encode();
        bytes.extend_from_slice(&full[..full.len() - 1]); // torn
        let parsed = TocRecord::parse_stream(&bytes);
        assert_eq!(parsed.len(), 1);
    }

    #[test]
    fn axes_summary_logic() {
        let mut axes = Axes::new();
        axes.insert_key(&Key::of(&[("step", "1"), ("param", "v")]));
        assert!(axes.may_contain(&Key::of(&[("step", "1"), ("param", "v")])));
        assert!(!axes.may_contain(&Key::of(&[("step", "2"), ("param", "v")])));
        assert!(!axes.may_contain(&Key::of(&[("step", "1"), ("number", "0")])));
        assert_eq!(axes.values("step"), vec!["1"]);
        assert!(axes.values("missing").is_empty());
    }

    #[test]
    fn axes_merge() {
        let mut a = Axes::new();
        a.insert_key(&Key::of(&[("step", "1")]));
        let mut b = Axes::new();
        b.insert_key(&Key::of(&[("step", "2"), ("param", "t")]));
        a.merge(&b);
        assert_eq!(a.values("step"), vec!["1", "2"]);
        assert_eq!(a.values("param"), vec!["t"]);
    }
}
