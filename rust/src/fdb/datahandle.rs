//! DataHandles: deferred readers returned by `retrieve()` (thesis
//! §2.7.1). POSIX handles support **merging** — adjacent/sorted ranges of
//! the same file coalesce so bulk reads become few large I/O ops. Object
//! backends don't merge (one array/object per field — nothing to merge,
//! §3.1.1), but multi-part handles still batch the read loop.

use super::location::FieldLocation;
use crate::daos::Oid;

/// A deferred reader for one or more field locations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DataHandle {
    Posix {
        path: String,
        /// sorted (offset, length) ranges, coalesced where adjacent
        ranges: Vec<(u64, u64)>,
    },
    Daos {
        pool: String,
        cont: String,
        parts: Vec<(Oid, u64)>,
    },
    Rados {
        pool: String,
        ns: String,
        parts: Vec<(String, u64, u64)>,
    },
    S3 {
        bucket: String,
        parts: Vec<(String, u64)>,
    },
    Null {
        length: u64,
    },
}

impl DataHandle {
    pub fn from_location(loc: &FieldLocation) -> DataHandle {
        match loc {
            FieldLocation::PosixFile {
                path,
                offset,
                length,
                ..
            } => DataHandle::Posix {
                path: path.clone(),
                ranges: vec![(*offset, *length)],
            },
            FieldLocation::DaosArray {
                pool,
                cont,
                oid,
                length,
                ..
            } => DataHandle::Daos {
                pool: pool.clone(),
                cont: cont.clone(),
                parts: vec![(*oid, *length)],
            },
            FieldLocation::RadosObj {
                pool,
                ns,
                name,
                offset,
                length,
                ..
            } => DataHandle::Rados {
                pool: pool.clone(),
                ns: ns.clone(),
                parts: vec![(name.clone(), *offset, *length)],
            },
            FieldLocation::S3Obj {
                bucket,
                key,
                length,
                ..
            } => DataHandle::S3 {
                bucket: bucket.clone(),
                parts: vec![(key.clone(), *length)],
            },
            FieldLocation::Null { length } => DataHandle::Null { length: *length },
        }
    }

    /// Which backend family this handle belongs to (for
    /// [`crate::fdb::FdbError::BackendMismatch`] diagnostics).
    pub fn backend_name(&self) -> &'static str {
        match self {
            DataHandle::Posix { .. } => "posix",
            DataHandle::Daos { .. } => "daos",
            DataHandle::Rados { .. } => "rados",
            DataHandle::S3 { .. } => "s3",
            DataHandle::Null { .. } => "null",
        }
    }

    /// Total bytes this handle will deliver.
    pub fn total_len(&self) -> u64 {
        match self {
            DataHandle::Posix { ranges, .. } => ranges.iter().map(|(_, l)| l).sum(),
            DataHandle::Daos { parts, .. } => parts.iter().map(|(_, l)| l).sum(),
            DataHandle::Rados { parts, .. } => parts.iter().map(|(_, _, l)| l).sum(),
            DataHandle::S3 { parts, .. } => parts.iter().map(|(_, l)| l).sum(),
            DataHandle::Null { length } => *length,
        }
    }

    /// Number of I/O operations reading this handle will issue.
    pub fn io_ops(&self) -> usize {
        match self {
            DataHandle::Posix { ranges, .. } => ranges.len(),
            DataHandle::Daos { parts, .. } => parts.len(),
            DataHandle::Rados { parts, .. } => parts.len(),
            DataHandle::S3 { parts, .. } => parts.len(),
            DataHandle::Null { .. } => 0,
        }
    }

    /// Try to merge `other` into `self`. Returns `other` back on
    /// incompatibility (different backend/file).
    pub fn merge(&mut self, other: DataHandle) -> Option<DataHandle> {
        match (self, other) {
            (
                DataHandle::Posix { path, ranges },
                DataHandle::Posix {
                    path: p2,
                    ranges: r2,
                },
            ) if *path == p2 => {
                ranges.extend(r2);
                ranges.sort_unstable();
                // coalesce adjacent/overlapping
                let mut merged: Vec<(u64, u64)> = Vec::with_capacity(ranges.len());
                for &(off, len) in ranges.iter() {
                    match merged.last_mut() {
                        Some((moff, mlen)) if *moff + *mlen >= off => {
                            let end = (off + len).max(*moff + *mlen);
                            *mlen = end - *moff;
                        }
                        _ => merged.push((off, len)),
                    }
                }
                *ranges = merged;
                None
            }
            (
                DataHandle::Daos { pool, cont, parts },
                DataHandle::Daos {
                    pool: p2,
                    cont: c2,
                    parts: q2,
                },
            ) if *pool == p2 && *cont == c2 => {
                parts.extend(q2);
                None
            }
            (
                DataHandle::Rados { pool, ns, parts },
                DataHandle::Rados {
                    pool: p2,
                    ns: n2,
                    parts: q2,
                },
            ) if *pool == p2 && *ns == n2 => {
                parts.extend(q2);
                None
            }
            (
                DataHandle::S3 { bucket, parts },
                DataHandle::S3 {
                    bucket: b2,
                    parts: q2,
                },
            ) if *bucket == b2 => {
                parts.extend(q2);
                None
            }
            (DataHandle::Null { length }, DataHandle::Null { length: l2 }) => {
                *length += l2;
                None
            }
            (_, other) => Some(other),
        }
    }

    /// Merge a batch of handles into as few as possible (preserving
    /// first-seen order of incompatible groups). Ranges are accumulated
    /// per group and coalesced once at the end (perf: avoids re-sorting
    /// per merge — O(n log n) total instead of O(n² log n)).
    pub fn merge_all(handles: Vec<DataHandle>) -> Vec<DataHandle> {
        let mut out: Vec<DataHandle> = Vec::new();
        'next: for h in handles {
            let mut h = h;
            for existing in &mut out {
                match existing.absorb(h) {
                    None => continue 'next,
                    Some(back) => h = back,
                }
            }
            out.push(h);
        }
        for h in &mut out {
            h.normalize();
        }
        out
    }

    /// Like [`DataHandle::merge`] but defers range coalescing (used by
    /// `merge_all`; caller must `normalize()` afterwards).
    fn absorb(&mut self, other: DataHandle) -> Option<DataHandle> {
        match (self, other) {
            (
                DataHandle::Posix { path, ranges },
                DataHandle::Posix {
                    path: p2,
                    ranges: r2,
                },
            ) if *path == p2 => {
                ranges.extend(r2);
                None
            }
            (a, b) => a.merge(b),
        }
    }

    /// Sort + coalesce POSIX ranges (idempotent).
    pub fn normalize(&mut self) {
        if let DataHandle::Posix { ranges, .. } = self {
            ranges.sort_unstable();
            let mut merged: Vec<(u64, u64)> = Vec::with_capacity(ranges.len());
            for &(off, len) in ranges.iter() {
                match merged.last_mut() {
                    Some((moff, mlen)) if *moff + *mlen >= off => {
                        let end = (off + len).max(*moff + *mlen);
                        *mlen = end - *moff;
                    }
                    _ => merged.push((off, len)),
                }
            }
            *ranges = merged;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn posix(path: &str, off: u64, len: u64) -> DataHandle {
        DataHandle::from_location(&FieldLocation::PosixFile {
            path: path.into(),
            offset: off,
            length: len,
            checksum: None,
        })
    }

    #[test]
    fn posix_adjacent_ranges_coalesce() {
        let mut a = posix("/d/f", 0, 100);
        assert!(a.merge(posix("/d/f", 100, 50)).is_none());
        match &a {
            DataHandle::Posix { ranges, .. } => assert_eq!(ranges, &vec![(0, 150)]),
            _ => unreachable!(),
        }
        assert_eq!(a.io_ops(), 1);
        assert_eq!(a.total_len(), 150);
    }

    #[test]
    fn posix_sparse_ranges_stay_separate() {
        let mut a = posix("/d/f", 0, 100);
        a.merge(posix("/d/f", 500, 100));
        assert_eq!(a.io_ops(), 2);
    }

    #[test]
    fn posix_out_of_order_sorted() {
        let mut a = posix("/d/f", 500, 10);
        a.merge(posix("/d/f", 0, 10));
        match &a {
            DataHandle::Posix { ranges, .. } => {
                assert_eq!(ranges, &vec![(0, 10), (500, 10)])
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn different_files_do_not_merge() {
        let mut a = posix("/d/f1", 0, 10);
        let back = a.merge(posix("/d/f2", 0, 10));
        assert!(back.is_some());
    }

    #[test]
    fn merge_all_groups_by_file() {
        let hs = vec![
            posix("/d/a", 0, 10),
            posix("/d/b", 0, 10),
            posix("/d/a", 10, 10),
            posix("/d/b", 20, 10),
        ];
        let merged = DataHandle::merge_all(hs);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].io_ops(), 1); // /d/a coalesced 0..20
        assert_eq!(merged[1].io_ops(), 2); // /d/b sparse
    }

    #[test]
    fn daos_parts_concatenate() {
        let l1 = FieldLocation::DaosArray {
            pool: "p".into(),
            cont: "c".into(),
            oid: Oid::new(1, 1),
            length: 5,
            checksum: None,
        };
        let l2 = FieldLocation::DaosArray {
            pool: "p".into(),
            cont: "c".into(),
            oid: Oid::new(1, 2),
            length: 6,
            checksum: None,
        };
        let merged = DataHandle::merge_all(vec![
            DataHandle::from_location(&l1),
            DataHandle::from_location(&l2),
        ]);
        assert_eq!(merged.len(), 1);
        assert_eq!(merged[0].total_len(), 11);
        assert_eq!(merged[0].io_ops(), 2); // no real merge possible (§3.1.1)
    }
}
