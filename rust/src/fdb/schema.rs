//! The FDB schema: which identifier dimensions form the dataset,
//! collocation, and element sub-keys (thesis §2.7).
//!
//! Two stock schemas matter for the reproduction:
//! * [`Schema::default_posix`] — the operational schema used with the
//!   POSIX backends: collocation = `type,levtype` (many parallel
//!   processes share a collocation key; fine with per-process files).
//! * [`Schema::daos_variant`] — the modified schema used with the
//!   DAOS/Ceph backends: `number,levelist` join the collocation key so
//!   parallel processes never contend on the same index KV (§3.1).

use super::key::Key;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schema {
    pub dataset: Vec<String>,
    pub collocation: Vec<String>,
    pub element: Vec<String>,
}

fn dims(names: &[&str]) -> Vec<String> {
    names.iter().map(|s| s.to_string()).collect()
}

impl Schema {
    /// Operational POSIX-backend schema.
    pub fn default_posix() -> Schema {
        Schema {
            dataset: dims(&["class", "expver", "stream", "date", "time"]),
            collocation: dims(&["type", "levtype"]),
            element: dims(&["step", "number", "levelist", "param"]),
        }
    }

    /// Modified schema for object-store backends (avoids index-KV
    /// contention across parallel writers).
    pub fn daos_variant() -> Schema {
        Schema {
            dataset: dims(&["class", "expver", "stream", "date", "time"]),
            collocation: dims(&["type", "levtype", "number", "levelist"]),
            element: dims(&["step", "param"]),
        }
    }

    /// All dims an identifier must carry.
    pub fn all_dims(&self) -> Vec<String> {
        let mut v = self.dataset.clone();
        v.extend(self.collocation.clone());
        v.extend(self.element.clone());
        v
    }

    /// Split a full identifier into (dataset, collocation, element) keys.
    pub fn split(&self, id: &Key) -> Result<(Key, Key, Key), SchemaError> {
        let ds = id
            .project(&self.dataset)
            .ok_or_else(|| SchemaError::missing(&self.dataset, id))?;
        let co = id
            .project(&self.collocation)
            .ok_or_else(|| SchemaError::missing(&self.collocation, id))?;
        let el = id
            .project(&self.element)
            .ok_or_else(|| SchemaError::missing(&self.element, id))?;
        Ok((ds, co, el))
    }

    /// Serialize for the in-dataset schema copy (`schema` file / KV).
    pub fn to_text(&self) -> String {
        format!(
            "dataset: {}\ncollocation: {}\nelement: {}\n",
            self.dataset.join(","),
            self.collocation.join(","),
            self.element.join(",")
        )
    }

    /// Parse the `to_text` form.
    pub fn parse(text: &str) -> Result<Schema, SchemaError> {
        let mut dataset = None;
        let mut collocation = None;
        let mut element = None;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once(':')
                .ok_or(SchemaError::Malformed)?;
            let vals: Vec<String> = v
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            match k.trim() {
                "dataset" => dataset = Some(vals),
                "collocation" => collocation = Some(vals),
                "element" => element = Some(vals),
                _ => return Err(SchemaError::Malformed),
            }
        }
        Ok(Schema {
            dataset: dataset.ok_or(SchemaError::Malformed)?,
            collocation: collocation.ok_or(SchemaError::Malformed)?,
            element: element.ok_or(SchemaError::Malformed)?,
        })
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SchemaError {
    MissingDims { wanted: String, got: String },
    Malformed,
}

impl SchemaError {
    fn missing(wanted: &[String], id: &Key) -> SchemaError {
        SchemaError::MissingDims {
            wanted: wanted.join(","),
            got: id.canonical(),
        }
    }
}

impl std::fmt::Display for SchemaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchemaError::MissingDims { wanted, got } => {
                write!(f, "identifier `{got}` missing schema dims `{wanted}`")
            }
            SchemaError::Malformed => write!(f, "malformed schema text"),
        }
    }
}
impl std::error::Error for SchemaError {}

/// The thesis' example identifier (Listing 2.1) — used across tests.
pub fn example_identifier() -> Key {
    Key::of(&[
        ("class", "od"),
        ("expver", "0001"),
        ("stream", "oper"),
        ("date", "20231201"),
        ("time", "1200"),
        ("type", "ef"),
        ("levtype", "sfc"),
        ("step", "1"),
        ("number", "13"),
        ("levelist", "1"),
        ("param", "v"),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_matches_thesis_listing() {
        let schema = Schema::default_posix();
        let id = example_identifier();
        let (ds, co, el) = schema.split(&id).unwrap();
        assert_eq!(
            ds.canonical(),
            "class=od,date=20231201,expver=0001,stream=oper,time=1200"
        );
        assert_eq!(co.canonical(), "levtype=sfc,type=ef");
        assert_eq!(el.canonical(), "levelist=1,number=13,param=v,step=1");
    }

    #[test]
    fn daos_variant_moves_number_levelist() {
        let schema = Schema::daos_variant();
        let (_, co, el) = schema.split(&example_identifier()).unwrap();
        assert_eq!(co.canonical(), "levelist=1,levtype=sfc,number=13,type=ef");
        assert_eq!(el.canonical(), "param=v,step=1");
    }

    #[test]
    fn split_rejects_missing_dims() {
        let schema = Schema::default_posix();
        let id = Key::of(&[("class", "od")]);
        assert!(schema.split(&id).is_err());
    }

    #[test]
    fn text_roundtrip() {
        let s = Schema::daos_variant();
        let back = Schema::parse(&s.to_text()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Schema::parse("nonsense").is_err());
        assert!(Schema::parse("dataset: a\n").is_err());
    }
}
