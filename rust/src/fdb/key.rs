//! Metadata keys: the scientifically-meaningful identifiers of the FDB.
//!
//! A [`Key`] is an ordered set of `dimension=value` pairs (thesis
//! Listing 2.1). Identifiers are *full* keys naming exactly one object;
//! the schema splits them into dataset / collocation / element sub-keys.

use std::collections::BTreeMap;
use std::fmt;

/// An ordered `dim=value` map with a canonical textual form.
#[derive(Clone, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Key(pub BTreeMap<String, String>);

impl Key {
    pub fn new() -> Key {
        Key::default()
    }

    /// Build from `("dim", "value")` pairs.
    pub fn of(pairs: &[(&str, &str)]) -> Key {
        Key(pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect())
    }

    /// Parse the canonical form `a=1,b=2`. Whitespace tolerated.
    pub fn parse(s: &str) -> Result<Key, String> {
        let mut map = BTreeMap::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("bad key component `{part}`"))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Key(map))
    }

    pub fn get(&self, dim: &str) -> Option<&str> {
        self.0.get(dim).map(|s| s.as_str())
    }

    pub fn set(&mut self, dim: &str, value: impl Into<String>) {
        self.0.insert(dim.to_string(), value.into());
    }

    pub fn with(mut self, dim: &str, value: impl Into<String>) -> Key {
        self.set(dim, value);
        self
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn dims(&self) -> impl Iterator<Item = &str> {
        self.0.keys().map(|s| s.as_str())
    }

    /// Canonical text: dims in lexicographic order, `a=1,b=2`.
    pub fn canonical(&self) -> String {
        let mut out = String::new();
        for (i, (k, v)) in self.0.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push('=');
            out.push_str(v);
        }
        out
    }

    /// Sub-key projection over `dims`; `None` if any dim is missing.
    pub fn project(&self, dims: &[String]) -> Option<Key> {
        let mut out = BTreeMap::new();
        for d in dims {
            out.insert(d.clone(), self.0.get(d)?.clone());
        }
        Some(Key(out))
    }

    /// Does `self` (a partial key) match `other` (a full key)?
    /// Every dim present in `self` must match exactly in `other`.
    pub fn matches(&self, other: &Key) -> bool {
        self.0
            .iter()
            .all(|(k, v)| other.0.get(k).map(|ov| ov == v).unwrap_or(false))
    }

    /// Merge: `other`'s dims override/extend `self`'s.
    pub fn merged(&self, other: &Key) -> Key {
        let mut m = self.0.clone();
        for (k, v) in &other.0 {
            m.insert(k.clone(), v.clone());
        }
        Key(m)
    }
}

impl fmt::Display for Key {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.canonical())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_is_sorted_and_stable() {
        let k = Key::of(&[("stream", "oper"), ("class", "od"), ("date", "20231201")]);
        assert_eq!(k.canonical(), "class=od,date=20231201,stream=oper");
        let re = Key::parse(&k.canonical()).unwrap();
        assert_eq!(k, re);
    }

    #[test]
    fn parse_tolerates_whitespace() {
        let k = Key::parse(" a = 1 , b = 2 ").unwrap();
        assert_eq!(k.get("a"), Some("1"));
        assert_eq!(k.get("b"), Some("2"));
    }

    #[test]
    fn parse_rejects_bad_component() {
        assert!(Key::parse("novalue").is_err());
    }

    #[test]
    fn project_full_and_missing() {
        let k = Key::of(&[("a", "1"), ("b", "2"), ("c", "3")]);
        let p = k
            .project(&["a".to_string(), "c".to_string()])
            .unwrap();
        assert_eq!(p.canonical(), "a=1,c=3");
        assert!(k.project(&["z".to_string()]).is_none());
    }

    #[test]
    fn partial_match() {
        let full = Key::of(&[("step", "1"), ("param", "v"), ("levelist", "10")]);
        assert!(Key::of(&[("step", "1")]).matches(&full));
        assert!(Key::new().matches(&full));
        assert!(!Key::of(&[("step", "2")]).matches(&full));
        assert!(!Key::of(&[("absent", "x")]).matches(&full));
    }

    #[test]
    fn merged_overrides() {
        let a = Key::of(&[("x", "1"), ("y", "2")]);
        let b = Key::of(&[("y", "9"), ("z", "3")]);
        assert_eq!(a.merged(&b).canonical(), "x=1,y=9,z=3");
    }
}
