//! The FDB DAOS Store (thesis §3.1.1): a DAOS array per archived object,
//! immediate persistence, no-op flush(), no daos_array_get_size on the
//! read path (lengths ride in the location descriptors).

use std::rc::Rc;

use crate::daos::{Container, DaosClient, ObjClass, Oid, Pool};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::util::content::Bytes;

pub struct DaosStore {
    pub(crate) client: DaosClient,
    pool_label: String,
    /// object class for field arrays (default OC_S1; override for
    /// sharding/redundancy experiments — Figs 4.10/4.27/4.28)
    pub array_class: ObjClass,
    /// hash-OID mode (thesis §3.1.2 future-work optimisation): array
    /// OIDs derive from the identifier hash, letting retrieve() skip the
    /// index lookup at the cost of a daos_array_get_size RPC
    pub hash_oids: bool,
    pool: Option<Rc<Pool>>,
    cont_cache: std::collections::HashMap<String, Rc<Container>>,
}

/// The deterministic OID of an identifier in hash-OID mode (hi=5
/// namespace avoids collision with allocator-assigned hi=1 OIDs).
pub fn hashed_oid(id: &crate::fdb::key::Key) -> Oid {
    Oid::new(5, crate::ceph::hash_name(&id.canonical()))
}

impl DaosStore {
    pub fn new(client: DaosClient, pool_label: &str) -> DaosStore {
        DaosStore {
            client,
            pool_label: pool_label.to_string(),
            array_class: ObjClass::S1,
            hash_oids: false,
            pool: None,
            cont_cache: std::collections::HashMap::new(),
        }
    }

    async fn pool(&mut self) -> Rc<Pool> {
        if self.pool.is_none() {
            self.pool = Some(
                self.client
                    .pool_connect(&self.pool_label)
                    .await
                    .expect("daos pool must exist"),
            );
        }
        self.pool.as_ref().unwrap().clone()
    }

    pub(crate) async fn dataset_cont(&mut self, ds: &Key) -> Rc<Container> {
        let label = ds.canonical();
        if let Some(c) = self.cont_cache.get(&label) {
            return c.clone();
        }
        let pool = self.pool().await;
        let cont = self
            .client
            .cont_create_with_label(&pool, &label)
            .await
            .expect("cont create");
        self.cont_cache.insert(label, cont.clone());
        cont
    }

    /// Store archive(): new array per object; durable and visible on
    /// return. The collocation key does NOT affect placement (§3.1.1).
    pub async fn archive(&mut self, ds: &Key, _colloc: &Key, data: Bytes) -> FieldLocation {
        let cont = self.dataset_cont(ds).await;
        let oid = self.client.alloc_oid(&cont).await;
        let arr = self
            .client
            .array_open_with_attr(&cont, oid, self.array_class);
        let length = data.len();
        self.client.array_write_data(&arr, 0, data).await;
        FieldLocation::DaosArray {
            pool: self.pool_label.clone(),
            cont: cont.label.clone(),
            oid,
            length,
            checksum: None,
        }
    }

    /// Hash-OID archive: the array OID is a pure function of the full
    /// identifier — no allocator round trips, and readers can reach the
    /// data without consulting the index.
    pub async fn archive_hashed(
        &mut self,
        ds: &Key,
        id: &crate::fdb::key::Key,
        data: Bytes,
    ) -> FieldLocation {
        let cont = self.dataset_cont(ds).await;
        let oid = hashed_oid(id);
        let arr = self
            .client
            .array_open_with_attr(&cont, oid, self.array_class);
        let length = data.len();
        self.client.array_write_data(&arr, 0, data).await;
        FieldLocation::DaosArray {
            pool: self.pool_label.clone(),
            cont: cont.label.clone(),
            oid,
            length,
            checksum: None,
        }
    }

    /// Hash-OID retrieve fast path: one daos_array_get_size RPC replaces
    /// the axis-preload + index kv_get chain. `None` when absent.
    pub async fn retrieve_hashed(
        &mut self,
        ds: &Key,
        id: &crate::fdb::key::Key,
    ) -> Option<FieldLocation> {
        let label = ds.canonical();
        let pool = self.pool().await;
        let cont = self.client.cont_open(&pool, &label).await.ok()??;
        let oid = hashed_oid(id);
        let arr = self
            .client
            .array_open_with_attr(&cont, oid, self.array_class);
        let length = self.client.array_get_size(&arr).await.ok()?;
        Some(FieldLocation::DaosArray {
            pool: self.pool_label.clone(),
            cont: label,
            oid,
            length,
            checksum: None,
        })
    }

    /// flush(): nothing to do — archive() persisted immediately.
    pub async fn flush(&mut self) {}

    /// Destroy the dataset container (one admin op — thesis §3.1).
    pub async fn wipe_dataset(&mut self, ds: &Key) -> bool {
        let pool = self.pool().await;
        let label = ds.canonical();
        self.cont_cache.remove(&label);
        self.client.cont_destroy(&pool, &label)
    }

    /// Read the parts of a DAOS handle (array per field; no merging).
    pub async fn read_parts(&mut self, cont_label: &str, parts: &[(Oid, u64)]) -> Bytes {
        let pool = self.pool().await;
        let cont = self
            .client
            .cont_open(&pool, cont_label)
            .await
            .expect("cont open")
            .expect("container must exist");
        let mut out = Bytes::new();
        for &(oid, len) in parts {
            let arr = self
                .client
                .array_open_with_attr(&cont, oid, self.array_class);
            // no daos_array_get_size: length came from the descriptor
            out.append(self.client.array_read(&arr, 0, len).await.expect("read"));
        }
        out
    }
}

impl crate::fdb::backend::Store for DaosStore {
    fn name(&self) -> &'static str {
        "daos"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<FieldLocation, crate::fdb::FdbError>>
    {
        Box::pin(async move {
            Ok(if self.hash_oids {
                DaosStore::archive_hashed(self, ds, id, data).await
            } else {
                DaosStore::archive(self, ds, colloc, data).await
            })
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a crate::fdb::DataHandle,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<Bytes, crate::fdb::FdbError>> {
        Box::pin(async move {
            match handle {
                crate::fdb::DataHandle::Daos { cont, parts, .. } => {
                    Ok(self.read_parts(cont, parts).await)
                }
                other => Err(crate::fdb::FdbError::BackendMismatch {
                    store: "daos",
                    handle: other.backend_name(),
                }),
            }
        })
    }

    fn direct_retrieve_enabled(&self) -> bool {
        // hash-OID mode resolves fully-specified identifiers without the
        // Catalogue (thesis §3.1.2)
        self.hash_oids
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(DaosStore::retrieve_hashed(self, ds, id))
    }

    fn supports_wipe(&self) -> bool {
        true
    }

    fn wipe_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, bool> {
        Box::pin(DaosStore::wipe_dataset(self, ds))
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::StoreSession>> {
        // own client handle = own event queue: container creation is
        // create-if-absent and OID batches come from shared container
        // state, so concurrent sessions never collide
        let mut s = DaosStore::new(self.client.fork(), &self.pool_label);
        s.array_class = self.array_class;
        s.hash_oids = self.hash_oids;
        Some(Box::new(s))
    }
}
