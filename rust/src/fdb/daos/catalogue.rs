//! The FDB DAOS Catalogue (thesis §3.1.2): a network of key-values —
//! root KV (datasets) → dataset KV (collocations) → index KVs (elements)
//! with axis KVs summarising indexed values. All insertions are
//! immediately persistent and visible; flush() and close() are no-ops.

use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::daos::{Container, DaosClient, KvHandle, ObjClass, Oid, Pool};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::request::Request;
use crate::fdb::schema::Schema;

/// OID namespace tags for the KV network.
fn index_kv_oid(colloc: &str) -> Oid {
    Oid::new(2, crate::ceph::hash_name(colloc))
}

fn axis_kv_oid(colloc: &str, dim: &str) -> Oid {
    Oid::new(3, crate::ceph::hash_name(&format!("{colloc}\u{1}{dim}")))
}

pub struct DaosCatalogue {
    pub(crate) client: DaosClient,
    pool_label: String,
    root_cont_label: String,
    schema: Schema,
    pool: Option<Rc<Pool>>,
    root_cont: Option<Rc<Container>>,
    dataset_conts: HashMap<String, Rc<Container>>,
    /// writer-side: (dataset, colloc) pairs already initialised
    known_collocs: HashSet<(String, String)>,
    /// writer-side axis dedup: (colloc, dim, value) already inserted
    axis_history: HashSet<(String, String, String)>,
    /// reader-side pre-loaded axes per (dataset, colloc): dim → values
    axes_cache: HashMap<(String, String), HashMap<String, Vec<String>>>,
}

impl DaosCatalogue {
    pub fn new(client: DaosClient, pool_label: &str, root_cont: &str, schema: Schema) -> Self {
        DaosCatalogue {
            client,
            pool_label: pool_label.to_string(),
            root_cont_label: root_cont.to_string(),
            schema,
            pool: None,
            root_cont: None,
            dataset_conts: HashMap::new(),
            known_collocs: HashSet::new(),
            axis_history: HashSet::new(),
            axes_cache: HashMap::new(),
        }
    }

    async fn pool(&mut self) -> Rc<Pool> {
        if self.pool.is_none() {
            self.pool = Some(
                self.client
                    .pool_connect(&self.pool_label)
                    .await
                    .expect("daos pool must exist"),
            );
        }
        self.pool.as_ref().unwrap().clone()
    }

    async fn root_kv(&mut self) -> (Rc<Container>, KvHandle) {
        if self.root_cont.is_none() {
            let pool = self.pool().await;
            let cont = self
                .client
                .cont_create_with_label(&pool, &self.root_cont_label)
                .await
                .expect("root cont");
            self.root_cont = Some(cont);
        }
        let cont = self.root_cont.as_ref().unwrap().clone();
        let kv = self.client.kv_open(&cont, Oid::ROOT_KV, ObjClass::S1);
        (cont, kv)
    }

    /// Open (or create, for writers) the dataset container + its KV.
    async fn dataset_cont(&mut self, ds: &Key, create: bool) -> Option<Rc<Container>> {
        let label = ds.canonical();
        if let Some(c) = self.dataset_conts.get(&label) {
            return Some(c.clone());
        }
        let (_root_cont, root_kv) = self.root_kv().await;
        let known = self
            .client
            .kv_get(&root_kv, &label)
            .await
            .expect("root kv get");
        let pool = self.pool().await;
        let cont = if known.is_some() {
            self.client.cont_open(&pool, &label).await.expect("open")?
        } else if create {
            let cont = self
                .client
                .cont_create_with_label(&pool, &label)
                .await
                .expect("cont create");
            // dataset KV: record the dataset key + schema copy
            let ds_kv = self.client.kv_open(&cont, Oid::ROOT_KV, ObjClass::S1);
            self.client.kv_put(&ds_kv, "key", label.as_bytes()).await;
            self.client
                .kv_put(&ds_kv, "schema", self.schema.to_text().as_bytes())
                .await;
            // index the dataset in the root KV (racing puts are idempotent)
            let uri = format!("daoskv://{}/{}", self.pool_label, label);
            self.client.kv_put(&root_kv, &label, uri.as_bytes()).await;
            cont
        } else {
            return None;
        };
        self.dataset_conts.insert(label, cont.clone());
        Some(cont)
    }

    fn ds_kv(&self, cont: &Rc<Container>) -> KvHandle {
        self.client.kv_open(cont, Oid::ROOT_KV, ObjClass::S1)
    }

    /// Catalogue archive(): index the element in the collocation's index
    /// KV + axis KVs; everything durable and visible on return.
    pub async fn archive(&mut self, ds: &Key, colloc: &Key, elem: &Key, loc: &FieldLocation) {
        let cont = self
            .dataset_cont(ds, true)
            .await
            .expect("writer creates dataset");
        let cc = colloc.canonical();
        let pair = (ds.canonical(), cc.clone());
        let idx_kv = self
            .client
            .kv_open(&cont, index_kv_oid(&cc), ObjClass::S1);
        if !self.known_collocs.contains(&pair) {
            // first archive for this collocation: init index KV + dataset KV entry
            let ds_kv = self.ds_kv(&cont);
            let found = self
                .client
                .kv_get(&ds_kv, &format!("colloc:{cc}"))
                .await
                .expect("get");
            if found.is_none() {
                self.client.kv_put(&idx_kv, "key", cc.as_bytes()).await;
                let dims: Vec<String> = elem.dims().map(String::from).collect();
                self.client
                    .kv_put(&idx_kv, "axes", dims.join(",").as_bytes())
                    .await;
                let uri = format!("daoskv://{}/{}/{}", self.pool_label, cont.label, cc);
                self.client
                    .kv_put(&ds_kv, &format!("colloc:{cc}"), uri.as_bytes())
                    .await;
            }
            self.known_collocs.insert(pair);
        }
        // the element entry itself
        self.client
            .kv_put(&idx_kv, &elem.canonical(), loc.to_uri().as_bytes())
            .await;
        // axis entries (deduped in-process)
        for (dim, val) in &elem.0 {
            let hk = (cc.clone(), dim.clone(), val.clone());
            if self.axis_history.contains(&hk) {
                continue;
            }
            let axis_kv = self
                .client
                .kv_open(&cont, axis_kv_oid(&cc, dim), ObjClass::S1);
            self.client.kv_put(&axis_kv, val, &[1]).await;
            self.axis_history.insert(hk);
        }
    }

    /// flush(): no-op — everything already persistent (§3.1.2).
    pub async fn flush(&mut self) {}

    /// Remove a dataset's root-KV registration after container destroy.
    pub async fn deregister_dataset(&mut self, ds: &Key) {
        let label = ds.canonical();
        let (_cont, root_kv) = self.root_kv().await;
        self.client.kv_remove(&root_kv, &label).await;
        self.dataset_conts.remove(&label);
        self.known_collocs.retain(|(d, _)| d != &label);
        self.axes_cache.retain(|(d, _), _| d != &label);
    }

    /// close(): no-op — no partial/full index distinction on DAOS.
    pub async fn close(&mut self) {}

    /// Axis pre-loading on first retrieve for a (dataset, colloc) pair.
    async fn ensure_axes(&mut self, ds: &Key, colloc: &Key) -> Option<()> {
        let key = (ds.canonical(), colloc.canonical());
        if self.axes_cache.contains_key(&key) {
            return Some(());
        }
        let cont = self.dataset_cont(ds, false).await?;
        let cc = colloc.canonical();
        let idx_kv = self
            .client
            .kv_open(&cont, index_kv_oid(&cc), ObjClass::S1);
        let dims_raw = self.client.kv_get(&idx_kv, "axes").await.ok()??;
        let dims = String::from_utf8(dims_raw).ok()?;
        let mut axes = HashMap::new();
        for dim in dims.split(',').filter(|d| !d.is_empty()) {
            let axis_kv = self
                .client
                .kv_open(&cont, axis_kv_oid(&cc, dim), ObjClass::S1);
            let mut vals = self.client.kv_list(&axis_kv).await;
            vals.sort();
            axes.insert(dim.to_string(), vals);
        }
        self.axes_cache.insert(key, axes);
        Some(())
    }

    /// Invalidate cached axes (for re-listing consumers).
    pub fn invalidate_preload(&mut self, ds: &Key) {
        let dsc = ds.canonical();
        self.axes_cache.retain(|(d, _), _| d != &dsc);
    }

    pub async fn axis(&mut self, ds: &Key, colloc: &Key, dim: &str) -> Vec<String> {
        if self.ensure_axes(ds, colloc).await.is_none() {
            return Vec::new();
        }
        self.axes_cache[&(ds.canonical(), colloc.canonical())]
            .get(dim)
            .cloned()
            .unwrap_or_default()
    }

    /// Catalogue retrieve(): axes check then one kv_get on the index KV.
    pub async fn retrieve(
        &mut self,
        ds: &Key,
        colloc: &Key,
        elem: &Key,
    ) -> Option<FieldLocation> {
        self.ensure_axes(ds, colloc).await?;
        {
            let axes = &self.axes_cache[&(ds.canonical(), colloc.canonical())];
            for (dim, val) in &elem.0 {
                let known = axes.get(dim)?;
                if !known.contains(val) {
                    return None; // pre-loaded summary says it can't exist
                }
            }
        }
        let cont = self.dataset_cont(ds, false).await?;
        let cc = colloc.canonical();
        let idx_kv = self
            .client
            .kv_open(&cont, index_kv_oid(&cc), ObjClass::S1);
        let raw = self
            .client
            .kv_get(&idx_kv, &elem.canonical())
            .await
            .ok()??;
        FieldLocation::parse_uri(&String::from_utf8(raw).ok()?)
    }

    /// Catalogue list(): dataset KV listing, then per-index listings +
    /// gets (many small ops — the DAOS list() cost noted in §3.1.2).
    pub async fn list(&mut self, ds: &Key, request: &Request) -> Vec<(Key, FieldLocation)> {
        let Some(cont) = self.dataset_cont(ds, false).await else {
            return Vec::new();
        };
        let ds_kv = self.ds_kv(&cont);
        let keys = self.client.kv_list(&ds_kv).await;
        let fixed = request.fixed_key();
        let mut out = Vec::new();
        for k in keys {
            let Some(cc) = k.strip_prefix("colloc:") else {
                continue;
            };
            // fetch the entry (uri) — even though we can derive the OID,
            // the real backend does this get (thesis notes the potential
            // hash-OID optimisation as future work)
            let _ = self.client.kv_get(&ds_kv, &k).await;
            let ck = Key::parse(cc).unwrap_or_default();
            let conflict = ck
                .0
                .iter()
                .any(|(d, v)| fixed.get(d).map(|fv| fv != v).unwrap_or(false));
            if conflict {
                continue;
            }
            let idx_kv = self.client.kv_open(&cont, index_kv_oid(cc), ObjClass::S1);
            for elem_key in self.client.kv_list(&idx_kv).await {
                if elem_key == "key" || elem_key == "axes" {
                    continue;
                }
                let ek = Key::parse(&elem_key).unwrap_or_default();
                let full = ds.merged(&ck).merged(&ek);
                if !request.matches(&full) {
                    continue;
                }
                if let Ok(Some(raw)) = self.client.kv_get(&idx_kv, &elem_key).await {
                    if let Some(loc) =
                        FieldLocation::parse_uri(&String::from_utf8(raw).unwrap_or_default())
                    {
                        out.push((full, loc));
                    }
                }
            }
        }
        out
    }
}

impl crate::fdb::backend::Catalogue for DaosCatalogue {
    fn name(&self) -> &'static str {
        "daos"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        _id: &'a Key,
        loc: &'a FieldLocation,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), crate::fdb::FdbError>> {
        // DAOS index inserts are kv_puts into created-on-demand KVs —
        // no fallible filesystem surface on this path
        Box::pin(async move {
            DaosCatalogue::archive(self, ds, colloc, elem, loc).await;
            Ok(())
        })
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::CatalogueSession>> {
        // index KVs live server-side and puts are immediately visible, so
        // a forked client reading the same pool/containers is
        // read-equivalent; it re-resolves pool + KV handles lazily
        Some(Box::new(DaosCatalogue::new(
            self.client.fork(),
            &self.pool_label,
            &self.root_cont_label,
            self.schema.clone(),
        )))
    }

    fn retrieve<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        _id: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(DaosCatalogue::retrieve(self, ds, colloc, elem))
    }

    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Vec<String>> {
        Box::pin(DaosCatalogue::axis(self, ds, colloc, dim))
    }

    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Vec<(Key, FieldLocation)>> {
        Box::pin(DaosCatalogue::list(self, ds, request))
    }

    fn invalidate_preload(&mut self, ds: &Key) {
        DaosCatalogue::invalidate_preload(self, ds);
    }

    fn deregister_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, ()> {
        Box::pin(DaosCatalogue::deregister_dataset(self, ds))
    }
}
