//! The vectored read planner: coalesce adjacent field reads into large
//! ranged I/Os on the batched retrieve paths.
//!
//! The paper's domain-agnostic analysis shows per-field I/O is where a
//! POSIX file system falls furthest below hardware bandwidth: NWP
//! retrievals issue huge numbers of small reads, and the DAOS companion
//! papers attribute much of the object stores' edge to avoiding exactly
//! that small-op regime (op-count reduction is also the lever that
//! survives contention, arXiv:2409.18682). Fields archived together sit
//! back-to-back in the same physical container — a per-process POSIX
//! data file, a spanned RADOS object — so the catalogue-resolved
//! `(position, FieldLocation)` list of a batched retrieve is highly
//! mergeable: group by container, sort by offset, read runs of adjacent
//! fields as ONE ranged I/O, then slice the merged buffer back into
//! per-field bytes in input order.
//!
//! Two [`IoProfile`](crate::fdb::IoProfile) knobs steer the planner:
//! `coalesce_gap` (max hole bytes a merged read reads through between
//! two fields; 0 = planner off, exact legacy behaviour) and
//! `coalesce_max` (cap on one merged read's size). Plans are executed by
//! [`Fdb::retrieve_many`](crate::fdb::Fdb::retrieve_many) — serially at
//! depth 1 through [`Store::read_ranges`](crate::fdb::Store), or through
//! the I/O-depth semaphore with **merged ranges, not raw fields, as the
//! unit of in-flight admission**.

use std::collections::HashMap;

use super::datahandle::DataHandle;
use super::location::FieldLocation;

/// Physical container identity: the unit adjacent reads can merge
/// within. DAOS arrays and S3 objects are keyed so repeated locations
/// (duplicate identifiers in one batch) still collapse to one read;
/// Null fields carry no container identity and pass through untouched.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
enum Container {
    Posix {
        path: String,
    },
    Rados {
        pool: String,
        ns: String,
        name: String,
    },
    Daos {
        pool: String,
        cont: String,
        oid: crate::daos::Oid,
    },
    S3 {
        bucket: String,
        key: String,
    },
    /// unmergeable location: unique per input position
    Single(usize),
}

/// (container, offset within it, length) of one located field.
fn classify(pos: usize, loc: &FieldLocation) -> (Container, u64, u64) {
    match loc {
        FieldLocation::PosixFile {
            path,
            offset,
            length,
            ..
        } => (
            Container::Posix { path: path.clone() },
            *offset,
            *length,
        ),
        FieldLocation::RadosObj {
            pool,
            ns,
            name,
            offset,
            length,
            ..
        } => (
            Container::Rados {
                pool: pool.clone(),
                ns: ns.clone(),
                name: name.clone(),
            },
            *offset,
            *length,
        ),
        FieldLocation::DaosArray {
            pool,
            cont,
            oid,
            length,
            ..
        } => (
            Container::Daos {
                pool: pool.clone(),
                cont: cont.clone(),
                oid: *oid,
            },
            0,
            *length,
        ),
        FieldLocation::S3Obj {
            bucket,
            key,
            length,
            ..
        } => (
            Container::S3 {
                bucket: bucket.clone(),
                key: key.clone(),
            },
            0,
            *length,
        ),
        FieldLocation::Null { length } => (Container::Single(pos), 0, *length),
    }
}

/// The ranged handle covering `[start, start+len)` of the container the
/// prototype location lives in.
fn ranged_handle(proto: &FieldLocation, start: u64, len: u64) -> DataHandle {
    match proto {
        FieldLocation::PosixFile { path, .. } => DataHandle::Posix {
            path: path.clone(),
            ranges: vec![(start, len)],
        },
        FieldLocation::RadosObj { pool, ns, name, .. } => DataHandle::Rados {
            pool: pool.clone(),
            ns: ns.clone(),
            parts: vec![(name.clone(), start, len)],
        },
        // array/object containers always span from 0 (classify pins
        // their members there), so `len` alone describes the range
        FieldLocation::DaosArray { pool, cont, oid, .. } => DataHandle::Daos {
            pool: pool.clone(),
            cont: cont.clone(),
            parts: vec![(*oid, len)],
        },
        FieldLocation::S3Obj { bucket, key, .. } => DataHandle::S3 {
            bucket: bucket.clone(),
            parts: vec![(key.clone(), len)],
        },
        FieldLocation::Null { .. } => DataHandle::Null { length: len },
    }
}

/// One planned ranged I/O and the input fields it delivers.
#[derive(Clone, Debug)]
pub struct PlannedRead {
    /// the (possibly merged) handle to read in one backend op
    pub handle: DataHandle,
    /// `(input position, offset inside the merged buffer, length)` —
    /// how to slice the merged buffer back into per-field bytes
    pub fields: Vec<(usize, u64, u64)>,
    /// the member fields' content checksums, aligned with `fields` —
    /// what the executor turns into per-slice
    /// [`crate::fdb::scrub::RangeCheck`]s (`None` = legacy entry,
    /// unverified)
    pub cks: Vec<Option<u64>>,
}

impl PlannedRead {
    /// The verification set for this read's buffer: one range check per
    /// checksummed member field (legacy members contribute nothing).
    pub fn checks(&self) -> Vec<crate::fdb::scrub::RangeCheck> {
        self.fields
            .iter()
            .zip(&self.cks)
            .filter_map(|(&(_, rel, len), ck)| {
                ck.map(|ck| crate::fdb::scrub::RangeCheck { rel, len, ck })
            })
            .collect()
    }
}

/// Counters a plan reports (and [`crate::fdb::Fdb`] accumulates across
/// plans as its per-instance coalescing trace).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// field reads requested
    pub ops_in: u64,
    /// ranged I/Os planned
    pub ops_out: u64,
    /// reads saved by merging (`ops_in - ops_out`)
    pub ops_merged: u64,
    /// hole bytes merged reads read through (`coalesce_gap` merges only)
    pub bytes_read_through: u64,
}

impl PlanStats {
    pub fn absorb(&mut self, o: PlanStats) {
        self.ops_in += o.ops_in;
        self.ops_out += o.ops_out;
        self.ops_merged += o.ops_merged;
        self.bytes_read_through += o.bytes_read_through;
    }
}

/// A coalesced read plan over one batched retrieve's located fields.
#[derive(Clone, Debug)]
pub struct ReadPlan {
    pub reads: Vec<PlannedRead>,
    pub stats: PlanStats,
}

impl ReadPlan {
    /// Build a plan over catalogue-resolved `(input position, location)`
    /// pairs. `gap` is the largest hole a merged read reads through;
    /// `max_read` caps one merged read's size (0 = unbounded; a single
    /// field larger than the cap still reads whole — it cannot split).
    /// Plan order is deterministic: containers in first-seen input
    /// order, ranges by ascending offset.
    pub fn build(fields: &[(usize, FieldLocation)], gap: u64, max_read: u64) -> ReadPlan {
        struct Member {
            pos: usize,
            off: u64,
            len: u64,
            ck: Option<u64>,
        }
        // group by container, preserving first-seen order
        let mut groups: Vec<(Vec<Member>, FieldLocation)> = Vec::new();
        let mut index: HashMap<Container, usize> = HashMap::new();
        for &(pos, ref loc) in fields {
            let (key, off, len) = classify(pos, loc);
            let gi = *index.entry(key).or_insert_with(|| {
                groups.push((Vec::new(), loc.clone()));
                groups.len() - 1
            });
            groups[gi].0.push(Member {
                pos,
                off,
                len,
                ck: loc.checksum(),
            });
        }
        let mut reads = Vec::new();
        let mut read_through = 0u64;
        for (mut members, proto) in groups {
            members.sort_by_key(|m| (m.off, m.pos));
            let mut i = 0;
            while i < members.len() {
                let start = members[i].off;
                let mut end = start + members[i].len;
                let mut j = i + 1;
                while j < members.len() {
                    let m = &members[j];
                    if m.off > end.saturating_add(gap) {
                        break; // hole exceeds the read-through budget
                    }
                    let new_end = end.max(m.off + m.len);
                    if max_read > 0 && new_end - start > max_read {
                        break; // merged read would exceed the size cap
                    }
                    read_through += m.off.saturating_sub(end);
                    end = new_end;
                    j += 1;
                }
                let fields: Vec<(usize, u64, u64)> = members[i..j]
                    .iter()
                    .map(|m| (m.pos, m.off - start, m.len))
                    .collect();
                let cks: Vec<Option<u64>> = members[i..j].iter().map(|m| m.ck).collect();
                reads.push(PlannedRead {
                    handle: ranged_handle(&proto, start, end - start),
                    fields,
                    cks,
                });
                i = j;
            }
        }
        let ops_in = fields.len() as u64;
        let ops_out = reads.len() as u64;
        ReadPlan {
            reads,
            stats: PlanStats {
                ops_in,
                ops_out,
                ops_merged: ops_in - ops_out,
                bytes_read_through: read_through,
            },
        }
    }
}

/// One container's open (still-growing) run inside a [`StreamPlanner`].
struct OpenRun {
    proto: FieldLocation,
    start: u64,
    end: u64,
    fields: Vec<(usize, u64, u64)>,
    /// member checksums, aligned with `fields`
    cks: Vec<Option<u64>>,
    /// first-seen order, so [`StreamPlanner::finish`] drains
    /// deterministically
    seq: u64,
}

/// The incremental twin of [`ReadPlan::build`]: locations are pushed
/// one at a time as the catalogue resolves them, and a merged range is
/// emitted the moment its run can no longer grow — so the engine can
/// have the range *in flight* while later lookups are still resolving
/// (streaming plan execution), instead of waiting for the full location
/// set.
///
/// One run stays open **per container** (an I/O-depth writer round-
/// robins a batch across its session data files, so consecutive
/// arrivals alternate containers; a single global run would flush on
/// every switch and plan nothing but singletons). Merging uses the same
/// `gap`/`max_read` rules as the batch planner; when per-container
/// arrivals are offset-ascending — the common case, batches retrieve in
/// archive order — the emitted ranges are identical to the batch plan's.
/// Out-of-order arrivals only cost extra ops (the run flushes and
/// reopens), never wrong bytes.
pub struct StreamPlanner {
    gap: u64,
    max_read: u64,
    open: HashMap<Container, OpenRun>,
    next_seq: u64,
    ops_in: u64,
    ops_out: u64,
    read_through: u64,
}

impl StreamPlanner {
    pub fn new(gap: u64, max_read: u64) -> StreamPlanner {
        StreamPlanner {
            gap,
            max_read,
            open: HashMap::new(),
            next_seq: 0,
            ops_in: 0,
            ops_out: 0,
            read_through: 0,
        }
    }

    fn close(&mut self, run: OpenRun) -> PlannedRead {
        self.ops_out += 1;
        PlannedRead {
            handle: ranged_handle(&run.proto, run.start, run.end - run.start),
            fields: run.fields,
            cks: run.cks,
        }
    }

    /// Feed the next resolved `(input position, location)`. Returns a
    /// ranged read ready to issue if this arrival sealed a run (its
    /// container's run could not absorb it), `None` if it merged or
    /// opened a new run.
    pub fn push(&mut self, pos: usize, loc: &FieldLocation) -> Option<PlannedRead> {
        self.ops_in += 1;
        let (key, off, len) = classify(pos, loc);
        let fresh = |seq: u64| OpenRun {
            proto: loc.clone(),
            start: off,
            end: off + len,
            fields: vec![(pos, 0, len)],
            cks: vec![loc.checksum()],
            seq,
        };
        let sealed = match self.open.entry(key) {
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(fresh(self.next_seq));
                self.next_seq += 1;
                None
            }
            std::collections::hash_map::Entry::Occupied(mut o) => {
                let run = o.get_mut();
                let new_end = run.end.max(off + len);
                let mergeable = off >= run.start
                    && off <= run.end.saturating_add(self.gap)
                    && (self.max_read == 0 || new_end - run.start <= self.max_read);
                if mergeable {
                    self.read_through += off.saturating_sub(run.end);
                    run.fields.push((pos, off - run.start, len));
                    run.cks.push(loc.checksum());
                    run.end = new_end;
                    None
                } else {
                    // seal the run, reopen the container at this member
                    let seq = run.seq;
                    Some(std::mem::replace(run, fresh(seq)))
                }
            }
        };
        sealed.map(|r| self.close(r))
    }

    /// Seal and return every still-open run, in container first-seen
    /// order. After this the planner is drained; [`StreamPlanner::stats`]
    /// is complete.
    pub fn finish(&mut self) -> Vec<PlannedRead> {
        let mut runs: Vec<OpenRun> = self.open.drain().map(|(_, r)| r).collect();
        runs.sort_by_key(|r| r.seq);
        runs.into_iter().map(|r| self.close(r)).collect()
    }

    /// Plan counters. The `ops_in == ops_out + ops_merged` invariant
    /// holds once [`StreamPlanner::finish`] has drained the open runs.
    pub fn stats(&self) -> PlanStats {
        PlanStats {
            ops_in: self.ops_in,
            ops_out: self.ops_out,
            ops_merged: self.ops_in - self.ops_out - self.open.len() as u64,
            bytes_read_through: self.read_through,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn posix(path: &str, off: u64, len: u64) -> FieldLocation {
        FieldLocation::PosixFile {
            path: path.into(),
            offset: off,
            length: len,
            checksum: None,
        }
    }

    fn plan(locs: Vec<FieldLocation>, gap: u64, max: u64) -> ReadPlan {
        let fields: Vec<(usize, FieldLocation)> = locs.into_iter().enumerate().collect();
        ReadPlan::build(&fields, gap, max)
    }

    #[test]
    fn adjacent_fields_merge_into_one_ranged_read() {
        let p = plan(
            vec![posix("/f", 0, 100), posix("/f", 100, 50), posix("/f", 150, 25)],
            0,
            0,
        );
        assert_eq!(p.reads.len(), 1);
        assert_eq!(
            p.reads[0].handle,
            DataHandle::Posix {
                path: "/f".into(),
                ranges: vec![(0, 175)],
            }
        );
        // slices address the merged buffer in sorted offset order
        assert_eq!(p.reads[0].fields, vec![(0, 0, 100), (1, 100, 50), (2, 150, 25)]);
        // ops_merged counts exactly what the planner claims: 3 in, 1 out
        assert_eq!(
            p.stats,
            PlanStats {
                ops_in: 3,
                ops_out: 1,
                ops_merged: 2,
                bytes_read_through: 0,
            }
        );
    }

    #[test]
    fn holes_within_gap_budget_are_read_through_and_counted() {
        // 0..100, hole 100..132, 132..164 — a 32-byte hole
        let locs = vec![posix("/f", 0, 100), posix("/f", 132, 32)];
        let tight = plan(locs.clone(), 16, 0);
        assert_eq!(tight.reads.len(), 2, "hole 32 > gap 16 must not merge");
        assert_eq!(tight.stats.bytes_read_through, 0);
        let loose = plan(locs, 64, 0);
        assert_eq!(loose.reads.len(), 1);
        assert_eq!(loose.stats.ops_merged, 1);
        assert_eq!(loose.stats.bytes_read_through, 32);
        assert_eq!(loose.reads[0].fields, vec![(0, 0, 100), (1, 132, 32)]);
    }

    #[test]
    fn coalesce_max_splits_runs() {
        let locs = vec![
            posix("/f", 0, 100),
            posix("/f", 100, 100),
            posix("/f", 200, 100),
        ];
        let p = plan(locs, 0, 150);
        // each merge would exceed 150 bytes: three singleton reads
        assert_eq!(p.reads.len(), 3);
        assert_eq!(p.stats.ops_merged, 0);
        // an oversized single field still reads whole
        let p = plan(vec![posix("/f", 0, 4096)], 0, 150);
        assert_eq!(p.reads.len(), 1);
        assert_eq!(p.reads[0].handle.total_len(), 4096);
    }

    #[test]
    fn out_of_order_and_cross_file_fields() {
        let p = plan(
            vec![
                posix("/b", 0, 10),
                posix("/a", 10, 10),
                posix("/a", 0, 10),
            ],
            0,
            0,
        );
        // containers keep first-seen order; /a's ranges sort by offset
        assert_eq!(p.reads.len(), 2);
        assert_eq!(p.reads[0].fields, vec![(0, 0, 10)]);
        assert_eq!(p.reads[1].fields, vec![(2, 0, 10), (1, 10, 10)]);
        assert_eq!(p.stats.ops_merged, 1);
    }

    #[test]
    fn unmergeable_backends_pass_through() {
        let daos = |lo: u64| FieldLocation::DaosArray {
            pool: "p".into(),
            cont: "c".into(),
            oid: crate::daos::Oid::new(1, lo),
            length: 64,
            checksum: None,
        };
        let p = plan(vec![daos(1), daos(2), FieldLocation::Null { length: 9 }], 1 << 20, 0);
        assert_eq!(p.reads.len(), 3, "distinct arrays and Null never merge");
        assert_eq!(p.stats.ops_merged, 0);
        // a duplicate identifier resolves to the SAME array: one read,
        // two slices
        let p = plan(vec![daos(1), daos(1)], 1 << 20, 0);
        assert_eq!(p.reads.len(), 1);
        assert_eq!(p.reads[0].fields, vec![(0, 0, 64), (1, 0, 64)]);
        assert_eq!(p.stats.ops_merged, 1);
    }

    #[test]
    fn overlapping_ranges_merge_without_double_counting() {
        // duplicate posix locations (same field retrieved twice)
        let p = plan(vec![posix("/f", 0, 100), posix("/f", 0, 100)], 0, 0);
        assert_eq!(p.reads.len(), 1);
        assert_eq!(p.reads[0].handle.total_len(), 100);
        assert_eq!(p.stats.bytes_read_through, 0);
    }

    /// Run a location list through the streaming planner, collecting
    /// every emitted range (push-time and finish-time).
    fn stream(locs: &[FieldLocation], gap: u64, max: u64) -> (Vec<PlannedRead>, PlanStats) {
        let mut sp = StreamPlanner::new(gap, max);
        let mut out = Vec::new();
        for (pos, loc) in locs.iter().enumerate() {
            out.extend(sp.push(pos, loc));
        }
        out.extend(sp.finish());
        (out, sp.stats())
    }

    #[test]
    fn stream_matches_batch_plan_on_ascending_arrivals() {
        // interleaved containers, each offset-ascending — exactly what a
        // depth-N writer's round-robin layout hands the resolve phase.
        // The streaming plan must equal the batch plan range for range.
        let locs = vec![
            posix("/a", 0, 100),
            posix("/b", 0, 100),
            posix("/a", 100, 50),
            posix("/b", 132, 32), // 32-byte hole on /b
            posix("/a", 150, 25),
        ];
        let fields: Vec<(usize, FieldLocation)> = locs.iter().cloned().enumerate().collect();
        let batch = ReadPlan::build(&fields, 64, 0);
        let (reads, stats) = stream(&locs, 64, 0);
        assert_eq!(reads.len(), batch.reads.len());
        for (s, b) in reads.iter().zip(&batch.reads) {
            assert_eq!(s.handle, b.handle);
            assert_eq!(s.fields, b.fields);
        }
        assert_eq!(stats, batch.stats);
        assert_eq!(stats.ops_in, stats.ops_out + stats.ops_merged);
        assert_eq!(stats.bytes_read_through, 32);
    }

    #[test]
    fn stream_emits_runs_early_on_gap_and_cap_breaks() {
        // gap break mid-stream: the sealed run surfaces from push(), not
        // finish() — that early emission is what execution overlaps with
        let mut sp = StreamPlanner::new(16, 0);
        assert!(sp.push(0, &posix("/f", 0, 100)).is_none());
        let sealed = sp.push(1, &posix("/f", 200, 10)).expect("hole 100 > gap 16 seals");
        assert_eq!(sealed.fields, vec![(0, 0, 100)]);
        // cap break: run would exceed max_read
        let mut sp = StreamPlanner::new(0, 150);
        assert!(sp.push(0, &posix("/f", 0, 100)).is_none());
        let sealed = sp.push(1, &posix("/f", 100, 100)).expect("cap 150 seals");
        assert_eq!(sealed.handle.total_len(), 100);
        let rest = sp.finish();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].fields, vec![(1, 0, 100)]);
        assert_eq!(sp.stats().ops_out, 2);
        assert_eq!(sp.stats().ops_merged, 0);
    }

    #[test]
    fn stream_out_of_order_arrival_costs_ops_not_bytes() {
        // off < run.start reopens the run: more ops than the batch plan,
        // but every field still covered exactly once
        let locs = vec![posix("/f", 100, 50), posix("/f", 0, 50)];
        let (reads, stats) = stream(&locs, 1 << 20, 0);
        assert_eq!(reads.len(), 2);
        assert_eq!(reads[0].fields, vec![(0, 0, 50)]);
        assert_eq!(reads[1].fields, vec![(1, 0, 50)]);
        assert_eq!(stats.ops_in, stats.ops_out + stats.ops_merged);
        let covered: u64 = reads.iter().map(|r| r.handle.total_len()).sum();
        assert_eq!(covered, 100);
    }

    #[test]
    fn checksums_ride_merged_reads_aligned_with_fields() {
        let with_ck = |path: &str, off: u64, len: u64, ck: u64| FieldLocation::PosixFile {
            path: path.into(),
            offset: off,
            length: len,
            checksum: Some(ck),
        };
        // checksummed + legacy members merge into one read; the check
        // set covers exactly the checksummed slices at merged-buffer
        // offsets
        let fields: Vec<(usize, FieldLocation)> = vec![
            with_ck("/f", 100, 50, 0xAA),
            posix("/f", 150, 25), // legacy, unverified
            with_ck("/f", 175, 10, 0xBB),
        ]
        .into_iter()
        .enumerate()
        .collect();
        let p = ReadPlan::build(&fields, 0, 0);
        assert_eq!(p.reads.len(), 1);
        let r = &p.reads[0];
        assert_eq!(r.cks, vec![Some(0xAA), None, Some(0xBB)]);
        let checks = r.checks();
        assert_eq!(checks.len(), 2);
        assert_eq!((checks[0].rel, checks[0].len, checks[0].ck), (0, 50, 0xAA));
        assert_eq!((checks[1].rel, checks[1].len, checks[1].ck), (75, 10, 0xBB));
        // the streaming planner carries the same alignment
        let locs: Vec<FieldLocation> = fields.into_iter().map(|(_, l)| l).collect();
        let (reads, _) = stream(&locs, 0, 0);
        assert_eq!(reads.len(), 1);
        assert_eq!(reads[0].cks, vec![Some(0xAA), None, Some(0xBB)]);
        assert_eq!(reads[0].checks(), checks);
    }

    #[test]
    fn stream_finish_drains_in_container_first_seen_order() {
        let locs = vec![
            posix("/c", 0, 10),
            posix("/a", 0, 10),
            posix("/b", 0, 10),
        ];
        let (reads, stats) = stream(&locs, 0, 0);
        assert_eq!(reads.len(), 3);
        assert_eq!(reads[0].fields, vec![(0, 0, 10)]);
        assert_eq!(reads[1].fields, vec![(1, 0, 10)]);
        assert_eq!(reads[2].fields, vec![(2, 0, 10)]);
        assert_eq!(stats.ops_merged, 0);
    }
}
