//! Integrity primitives for the scrub/repair subsystem (`fdbctl fsck`):
//! the per-range checksum expectations verified reads carry, the
//! per-field outcome a store scrub reports, and the dataset-level
//! [`FsckReport`] returned by [`crate::fdb::Fdb::fsck`].
//!
//! The checksum is the streamed FNV-1a of the field payload
//! ([`crate::util::content::Bytes::content_checksum`]), computed once at
//! archive time and carried in [`crate::fdb::FieldLocation`] / the
//! catalogue entry. Entries without one are legacy fields: readable,
//! scrubbed for existence and length only, never an error.

use crate::fdb::FdbError;
use crate::util::content::Bytes;

/// One field's expected bytes inside a (possibly coalesced) read: the
/// slice `[rel, rel+len)` of the returned buffer must checksum to `ck`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RangeCheck {
    /// offset of the field's first byte relative to the read buffer
    pub rel: u64,
    /// field length in bytes
    pub len: u64,
    /// expected FNV-1a content checksum
    pub ck: u64,
}

impl RangeCheck {
    /// A whole-buffer check (single-field read).
    pub fn whole(len: u64, ck: u64) -> RangeCheck {
        RangeCheck { rel: 0, len, ck }
    }
}

/// Verify a read buffer against its expected per-range checksums.
/// Returns the typed [`FdbError::Corrupt`] naming the first mismatching
/// range. An empty `checks` slice verifies nothing (legacy entries).
pub fn verify_ranges(buf: &Bytes, checks: &[RangeCheck]) -> Result<(), FdbError> {
    for c in checks {
        let got = buf.slice(c.rel, c.len);
        if got.len() != c.len {
            return Err(FdbError::Corrupt {
                what: "field",
                detail: format!(
                    "short read: {} of {} bytes at +{}",
                    got.len(),
                    c.len,
                    c.rel
                ),
            });
        }
        let actual = got.content_checksum();
        if actual != c.ck {
            return Err(FdbError::Corrupt {
                what: "field",
                detail: format!(
                    "checksum mismatch at +{} len {}: stored {:#018x}, read {:#018x}",
                    c.rel, c.len, c.ck, actual
                ),
            });
        }
    }
    Ok(())
}

/// What a store-level scrub of one field found, summed over however many
/// physical copies the store keeps (1 for plain backends, N under
/// replication).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScrubOutcome {
    /// physical copies examined
    pub copies: u64,
    /// copies that could not be read at all (missing object / short file)
    pub missing: u64,
    /// copies whose bytes fail the length or checksum cross-check
    pub corrupt: u64,
    /// damaged copies rewritten from a verified source this scrub
    pub repaired: u64,
}

impl ScrubOutcome {
    /// Whether every copy of the field is (now) healthy.
    pub fn healthy(&self) -> bool {
        self.missing == 0 && self.corrupt == self.repaired
    }
}

/// The catalogue↔store cross-check result for one dataset.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FsckReport {
    /// catalogue entries examined
    pub entries: u64,
    /// entries whose checksum was cross-checked (legacy entries without
    /// one are existence/length-checked only)
    pub verified: u64,
    /// catalogue entries whose data is gone from the store
    pub ghosts: u64,
    /// store objects no catalogue entry references
    pub orphans: u64,
    /// fields with at least one corrupt copy
    pub corrupt: u64,
    /// damaged copies rewritten from a verified replica (repair mode)
    pub repaired: u64,
    /// ghost entries dropped from the catalogue (repair mode)
    pub ghosts_dropped: u64,
    /// orphaned objects quarantined out of the data path (repair mode)
    pub orphans_quarantined: u64,
}

impl FsckReport {
    /// A clean pass: nothing missing, nothing rotten, nothing dangling.
    pub fn clean(&self) -> bool {
        self.ghosts == 0 && self.orphans == 0 && self.corrupt == 0
    }

    /// Whether a `--repair` pass converged: every problem found was
    /// repaired in-pass (the next fsck will report clean).
    pub fn converged(&self) -> bool {
        self.ghosts == self.ghosts_dropped
            && self.orphans == self.orphans_quarantined
            && self.corrupt == self.repaired
    }

    /// Fold one field's scrub outcome into the dataset tallies.
    pub fn absorb(&mut self, field: &ScrubOutcome) {
        // a field with NO readable copy at all is a ghost (the entry
        // points at nothing); partial damage is corruption
        if field.copies > 0 && field.missing == field.copies {
            self.ghosts += 1;
        } else if field.missing > 0 || field.corrupt > 0 {
            self.corrupt += 1;
        }
        self.repaired += field.repaired;
    }
}

impl std::fmt::Display for FsckReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} entries ({} verified): {} ghosts, {} orphans, {} corrupt; \
             repaired {} copies, dropped {} ghosts, quarantined {} orphans",
            self.entries,
            self.verified,
            self.ghosts,
            self.orphans,
            self.corrupt,
            self.repaired,
            self.ghosts_dropped,
            self.orphans_quarantined
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verify_ranges_passes_and_fails() {
        let a = Bytes::virt(100, 5);
        let b = Bytes::virt(60, 9);
        let mut buf = a.clone();
        buf.append(b.clone());
        let checks = [
            RangeCheck {
                rel: 0,
                len: 100,
                ck: a.content_checksum(),
            },
            RangeCheck {
                rel: 100,
                len: 60,
                ck: b.content_checksum(),
            },
        ];
        verify_ranges(&buf, &checks).unwrap();
        // no checks = legacy entry = no verification
        verify_ranges(&buf, &[]).unwrap();
        // a flipped byte in the second field trips only via its range
        let mut raw = buf.to_vec();
        raw[120] ^= 0xFF;
        let rotten = Bytes::real(raw);
        verify_ranges(&rotten, &checks[..1]).unwrap();
        let err = verify_ranges(&rotten, &checks).unwrap_err();
        assert!(matches!(err, FdbError::Corrupt { .. }), "got {err}");
    }

    #[test]
    fn verify_ranges_rejects_short_buffer() {
        let a = Bytes::virt(100, 5);
        let short = a.slice(0, 50);
        let err = verify_ranges(
            &short,
            &[RangeCheck::whole(100, a.content_checksum())],
        )
        .unwrap_err();
        assert!(matches!(err, FdbError::Corrupt { .. }));
    }

    #[test]
    fn report_classifies_ghost_vs_corrupt() {
        let mut rep = FsckReport::default();
        rep.absorb(&ScrubOutcome {
            copies: 2,
            missing: 2,
            ..Default::default()
        });
        rep.absorb(&ScrubOutcome {
            copies: 2,
            missing: 0,
            corrupt: 1,
            repaired: 1,
            ..Default::default()
        });
        rep.absorb(&ScrubOutcome {
            copies: 1,
            ..Default::default()
        });
        assert_eq!((rep.ghosts, rep.corrupt, rep.repaired), (1, 1, 1));
        assert!(!rep.clean());
    }

    #[test]
    fn convergence_requires_full_repair() {
        let rep = FsckReport {
            entries: 4,
            ghosts: 1,
            ghosts_dropped: 1,
            corrupt: 2,
            repaired: 2,
            orphans: 1,
            orphans_quarantined: 1,
            ..Default::default()
        };
        assert!(rep.converged());
        let partial = FsckReport {
            corrupt: 2,
            repaired: 1,
            ..Default::default()
        };
        assert!(!partial.converged());
    }
}
