//! The FDB Ceph/RADOS Store (thesis §3.2): all the design options the
//! thesis evaluated in Fig 3.5 are implemented and switchable:
//!
//! * encapsulation: namespace-per-dataset (default) or pool-per-dataset
//! * layout: RADOS object per archive() call (default), multiple
//!   spanned objects per (process, collocation), or one large object
//! * persistence: blocking writes (default) or aio + persist-on-flush
//!
//! Object names are MD5/SHA1-style digests of a unique string so related
//! names don't pile onto one OSD (§3.2.1).

use std::collections::HashMap;
use std::rc::Rc;

use crate::ceph::{Ceph, CephPool, RadosClient, Redundancy};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::util::content::Bytes;

/// Data layout options (Fig 3.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RadosLayout {
    /// a RADOS object per archive() call — the chosen default
    ObjPerField,
    /// objects per (process, collocation), spanned at `max_object_size`
    SpannedPerProcess,
    /// one large object per (process, collocation) — needs a raised
    /// `osd_max_object_size`
    SingleLargePerProcess,
}

#[derive(Clone, Debug)]
pub struct RadosStoreConfig {
    pub layout: RadosLayout,
    /// pool-per-dataset instead of namespace-per-dataset
    pub pool_per_dataset: bool,
    /// aio writes + persistence ensured on flush()
    pub async_io: bool,
    pub pg_per_pool: usize,
    pub redundancy: Redundancy,
}

impl Default for RadosStoreConfig {
    fn default() -> Self {
        RadosStoreConfig {
            layout: RadosLayout::ObjPerField,
            pool_per_dataset: false,
            async_io: false,
            pg_per_pool: 512,
            redundancy: Redundancy::None,
        }
    }
}

struct SpanState {
    /// current object name and its fill level
    obj: String,
    fill: u64,
    span_no: u32,
}

pub struct RadosStore {
    pub(crate) client: RadosClient,
    sys: Rc<Ceph>,
    pub config: RadosStoreConfig,
    base_pool: Rc<CephPool>,
    ds_pools: HashMap<String, Rc<CephPool>>,
    spans: HashMap<(String, String), SpanState>,
    counter: u64,
}

impl RadosStore {
    pub fn new(sys: &Rc<Ceph>, client: RadosClient, base_pool: &Rc<CephPool>) -> RadosStore {
        RadosStore {
            client,
            sys: sys.clone(),
            config: RadosStoreConfig::default(),
            base_pool: base_pool.clone(),
            ds_pools: HashMap::new(),
            spans: HashMap::new(),
            counter: 0,
        }
    }

    pub fn with_config(mut self, config: RadosStoreConfig) -> RadosStore {
        if let Some(bug) = match config.async_io {
            true => Some(true),
            false => None,
        } {
            // the thesis observed the aio path failing its visibility
            // guarantee (Fig 3.5 cfg 6) with the obj-per-field layout
            self.client.aio_visibility_bug =
                bug && config.layout == RadosLayout::ObjPerField;
        }
        self.config = config;
        self
    }

    /// (pool, namespace) a dataset's data lives in. Pool-per-dataset
    /// creation is reuse-if-present against the cluster's pool map, so
    /// concurrent client sessions of one store agree on the dataset pool
    /// instead of each minting a same-named twin.
    pub(crate) fn placement(&mut self, ds: &Key) -> (Rc<CephPool>, String) {
        let label = ds.canonical();
        if self.config.pool_per_dataset {
            let cached = self.ds_pools.get(&label).cloned();
            let pool = match cached {
                Some(p) => p,
                None => {
                    let name = format!("fdb-{label}");
                    let existing = self.sys.pools.borrow().get(&name).cloned();
                    let pool = existing.unwrap_or_else(|| {
                        self.sys.create_pool(
                            &name,
                            self.config.pg_per_pool,
                            self.config.redundancy,
                        )
                    });
                    self.ds_pools.insert(label, pool.clone());
                    pool
                }
            };
            (pool, String::new())
        } else {
            (self.base_pool.clone(), label)
        }
    }

    /// A collision-free object name: digest of (client, counter).
    fn unique_name(&mut self, tag: &str) -> String {
        self.counter += 1;
        let raw = format!("{tag}\u{1}{}\u{1}{}", self.counter, self.client_id());
        format!("{:016x}", crate::ceph::hash_name(&raw))
    }

    fn client_id(&self) -> u64 {
        self.client.client_id()
    }

    /// Store archive().
    pub async fn archive(&mut self, ds: &Key, colloc: &Key, data: Bytes) -> FieldLocation {
        let (pool, ns) = self.placement(ds);
        match self.config.layout {
            RadosLayout::ObjPerField => {
                let name = self.unique_name("f");
                let length = data.len();
                if self.config.async_io {
                    self.client
                        .aio_write_full(&pool, &ns, &name, data)
                        .await
                        .expect("aio write");
                } else {
                    self.client
                        .write_full_data(&pool, &ns, &name, data)
                        .await
                        .expect("write");
                }
                FieldLocation::RadosObj {
                    pool: pool.name.clone(),
                    ns,
                    name,
                    offset: 0,
                    length,
                    checksum: None,
                }
            }
            RadosLayout::SpannedPerProcess | RadosLayout::SingleLargePerProcess => {
                let limit = if self.config.layout == RadosLayout::SingleLargePerProcess {
                    u64::MAX
                } else {
                    self.sys.config.max_object_size
                };
                let key = (ds.canonical(), colloc.canonical());
                let dlen = data.len();
                let needs_new = match self.spans.get(&key) {
                    None => true,
                    Some(s) => s.fill + dlen > limit,
                };
                if needs_new {
                    let span_no = self.spans.get(&key).map(|s| s.span_no + 1).unwrap_or(0);
                    let name = self.unique_name(&format!("s{span_no}"));
                    self.spans.insert(
                        key.clone(),
                        SpanState {
                            obj: name,
                            fill: 0,
                            span_no,
                        },
                    );
                }
                let (name, offset) = {
                    let s = self.spans.get_mut(&key).unwrap();
                    let off = s.fill;
                    s.fill += dlen;
                    (s.obj.clone(), off)
                };
                if self.config.async_io {
                    // spanned-aio appends must serialize per object; model
                    // as aio of the piece then offset bookkeeping
                    self.client
                        .aio_write_full(&pool, &ns, &format!("{name}:{offset}"), data)
                        .await
                        .expect("aio write");
                    // content also mirrored into the span object at flush
                } else {
                    self.client
                        .write_at(&pool, &ns, &name, offset, data)
                        .await
                        .expect("write");
                }
                FieldLocation::RadosObj {
                    pool: pool.name.clone(),
                    ns,
                    name: if self.config.async_io {
                        format!("{name}:{offset}")
                    } else {
                        name
                    },
                    offset: if self.config.async_io { 0 } else { offset },
                    length: dlen,
                    checksum: None,
                }
            }
        }
    }

    /// Store flush(): drain aio queue if configured; otherwise no-op.
    pub async fn flush(&mut self) {
        if self.config.async_io {
            self.client.flush_pending().await;
        }
    }

    /// Remove every object of the dataset's namespace (or drop the
    /// dataset's dedicated pool). Returns objects removed.
    pub async fn wipe_dataset(&mut self, ds: &Key) -> usize {
        let (pool, ns) = self.placement(ds);
        if self.config.pool_per_dataset {
            let name = pool.name.clone();
            self.ds_pools.remove(&ds.canonical());
            return usize::from(self.sys.delete_pool(&name));
        }
        let names = self.client.list_objects(&pool, &ns).await;
        let n = names.len();
        for name in names {
            self.client.remove(&pool, &ns, &name).await;
        }
        self.spans.retain(|(d, _), _| d != &ds.canonical());
        n
    }

    /// The pool handle (ioctx) a handle's pool name resolves to: the
    /// base pool, this client's dataset-pool cache, then the cluster's
    /// pool map (a pure reader in pool-per-dataset mode never ran
    /// placement, so its cache is cold).
    fn resolve_pool(&self, pool_name: &str) -> Rc<CephPool> {
        if pool_name == self.base_pool.name {
            return self.base_pool.clone();
        }
        self.ds_pools
            .values()
            .find(|p| p.name == pool_name)
            .cloned()
            .or_else(|| self.sys.pools.borrow().get(pool_name).cloned())
            .unwrap_or_else(|| self.base_pool.clone())
    }

    /// Read the parts of a RADOS handle.
    pub async fn read_parts(
        &mut self,
        pool_name: &str,
        ns: &str,
        parts: &[(String, u64, u64)],
    ) -> Bytes {
        let pool = self.resolve_pool(pool_name);
        let mut out = Bytes::new();
        for (name, off, len) in parts {
            if let Ok(Some(bytes)) = self.client.read(&pool, ns, name, *off, *len).await {
                out.append(bytes);
            }
        }
        out
    }
}

impl RadosClient {
    /// Process-unique client id (object-naming identity).
    pub fn client_id(&self) -> u64 {
        self.id
    }
}

impl crate::fdb::backend::Store for RadosStore {
    fn name(&self) -> &'static str {
        "rados"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        _id: &'a Key,
        data: Bytes,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<FieldLocation, crate::fdb::FdbError>>
    {
        Box::pin(async move { Ok(RadosStore::archive(self, ds, colloc, data).await) })
    }

    fn flush<'a>(
        &'a mut self,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), crate::fdb::FdbError>> {
        Box::pin(async move {
            RadosStore::flush(self).await;
            Ok(())
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a crate::fdb::DataHandle,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<Bytes, crate::fdb::FdbError>> {
        Box::pin(async move {
            match handle {
                crate::fdb::DataHandle::Rados { pool, ns, parts } => {
                    Ok(self.read_parts(pool, ns, parts).await)
                }
                other => Err(crate::fdb::FdbError::BackendMismatch {
                    store: "rados",
                    handle: other.backend_name(),
                }),
            }
        })
    }

    /// The vectored read path: each distinct pool resolves to its ioctx
    /// once for the whole batch; merged spans within one object read as
    /// single ranged ops (the planner's coalesced RADOS ranges). Unlike
    /// the legacy per-field `read` (which tolerates a missing object as
    /// one empty field), a failed or absent part here is a typed error:
    /// a short merged buffer would silently misalign every field sliced
    /// from it.
    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [crate::fdb::DataHandle],
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<Vec<Bytes>, crate::fdb::FdbError>> {
        Box::pin(async move {
            let mut ioctx: HashMap<&str, Rc<CephPool>> = HashMap::new();
            let mut out = Vec::with_capacity(handles.len());
            for handle in handles {
                let crate::fdb::DataHandle::Rados { pool, ns, parts } = handle else {
                    return Err(crate::fdb::FdbError::BackendMismatch {
                        store: "rados",
                        handle: handle.backend_name(),
                    });
                };
                let pool = match ioctx.get(pool.as_str()) {
                    Some(p) => p.clone(),
                    None => {
                        let p = self.resolve_pool(pool);
                        ioctx.insert(pool.as_str(), p.clone());
                        p
                    }
                };
                let mut bytes = Bytes::new();
                for (name, off, len) in parts {
                    match self.client.read(&pool, ns, name, *off, *len).await {
                        Ok(Some(b)) => bytes.append(b),
                        Ok(None) => {
                            return Err(crate::fdb::FdbError::Backend {
                                backend: "rados",
                                detail: format!(
                                    "read {}/{ns}/{name}: object missing",
                                    pool.name
                                ),
                            })
                        }
                        Err(e) => {
                            return Err(crate::fdb::FdbError::Backend {
                                backend: "rados",
                                detail: format!("read {}/{ns}/{name}: {e:?}", pool.name),
                            })
                        }
                    }
                }
                out.push(bytes);
            }
            Ok(out)
        })
    }

    fn supports_wipe(&self) -> bool {
        true
    }

    fn wipe_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, bool> {
        Box::pin(async move { RadosStore::wipe_dataset(self, ds).await > 0 })
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::StoreSession>> {
        // own client instance id (collision-free object names, own aio
        // queue); span state is per session, like per process
        Some(Box::new(
            RadosStore::new(&self.sys, self.client.fork(), &self.base_pool)
                .with_config(self.config.clone()),
        ))
    }
}
