//! The FDB Ceph/RADOS Catalogue (thesis §3.2.1): the DAOS catalogue
//! design with Omaps in place of KVs. Namespaces encapsulate datasets;
//! `omap_get_all` fetches whole indexes in one RPC, making `list()`
//! more efficient than on DAOS.

use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::ceph::{CephPool, RadosClient};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::request::Request;
use crate::fdb::schema::Schema;

fn index_obj(colloc: &str) -> String {
    format!("fdb.index.{:016x}", crate::ceph::hash_name(colloc))
}

fn axis_obj(colloc: &str, dim: &str) -> String {
    format!(
        "fdb.axis.{:016x}",
        crate::ceph::hash_name(&format!("{colloc}\u{1}{dim}"))
    )
}

const ROOT_NS: &str = "fdb-root";
const ROOT_OBJ: &str = "fdb.root";
const CAT_OBJ: &str = "fdb.catalogue";

pub struct RadosCatalogue {
    pub(crate) client: RadosClient,
    pool: Rc<CephPool>,
    schema: Schema,
    known_datasets: HashSet<String>,
    known_collocs: HashSet<(String, String)>,
    axis_history: HashSet<(String, String, String)>,
    axes_cache: HashMap<(String, String), HashMap<String, Vec<String>>>,
}

impl RadosCatalogue {
    pub fn new(client: RadosClient, pool: &Rc<CephPool>, schema: Schema) -> RadosCatalogue {
        RadosCatalogue {
            client,
            pool: pool.clone(),
            schema,
            known_datasets: HashSet::new(),
            known_collocs: HashSet::new(),
            axis_history: HashSet::new(),
            axes_cache: HashMap::new(),
        }
    }

    /// Dataset namespace = canonical dataset key (cheap: no creation RPC,
    /// namespaces are implicit in RADOS — §3.2.1 "more lightweight").
    fn ns_of(ds: &Key) -> String {
        ds.canonical()
    }

    async fn ensure_dataset(&mut self, ds: &Key, create: bool) -> Option<String> {
        let label = ds.canonical();
        let ns = Self::ns_of(ds);
        if self.known_datasets.contains(&label) {
            return Some(ns);
        }
        let found = self
            .client
            .omap_get(&self.pool, ROOT_NS, ROOT_OBJ, &[label.as_str()])
            .await
            .ok()?;
        if found.is_empty() {
            if !create {
                return None;
            }
            // catalogue omap: dataset key + schema copy
            self.client
                .omap_set(
                    &self.pool,
                    &ns,
                    CAT_OBJ,
                    &[
                        ("key", label.as_bytes()),
                        ("schema", self.schema.to_text().as_bytes()),
                    ],
                )
                .await
                .ok()?;
            let uri = format!("radosomap://{}/{}", self.pool.name, ns);
            self.client
                .omap_set(&self.pool, ROOT_NS, ROOT_OBJ, &[(&label, uri.as_bytes())])
                .await
                .ok()?;
        }
        self.known_datasets.insert(label);
        Some(ns)
    }

    /// Catalogue archive(): immediate, persistent omap insertions.
    pub async fn archive(&mut self, ds: &Key, colloc: &Key, elem: &Key, loc: &FieldLocation) {
        let ns = self
            .ensure_dataset(ds, true)
            .await
            .expect("writer creates dataset");
        let cc = colloc.canonical();
        let pair = (ds.canonical(), cc.clone());
        let idx = index_obj(&cc);
        if !self.known_collocs.contains(&pair) {
            let found = self
                .client
                .omap_get(&self.pool, &ns, CAT_OBJ, &[&format!("colloc:{cc}")])
                .await
                .unwrap_or_default();
            if found.is_empty() {
                let dims: Vec<String> = elem.dims().map(String::from).collect();
                self.client
                    .omap_set(
                        &self.pool,
                        &ns,
                        &idx,
                        &[("key", cc.as_bytes()), ("axes", dims.join(",").as_bytes())],
                    )
                    .await
                    .expect("omap set");
                let uri = format!("radosomap://{}/{}/{}", self.pool.name, ns, idx);
                self.client
                    .omap_set(
                        &self.pool,
                        &ns,
                        CAT_OBJ,
                        &[(&format!("colloc:{cc}"), uri.as_bytes())],
                    )
                    .await
                    .expect("omap set");
            }
            self.known_collocs.insert(pair);
        }
        self.client
            .omap_set(
                &self.pool,
                &ns,
                &idx,
                &[(&elem.canonical(), loc.to_uri().as_bytes())],
            )
            .await
            .expect("omap set");
        for (dim, val) in &elem.0 {
            let hk = (cc.clone(), dim.clone(), val.clone());
            if self.axis_history.contains(&hk) {
                continue;
            }
            self.client
                .omap_set(&self.pool, &ns, &axis_obj(&cc, dim), &[(val, &[1u8])])
                .await
                .expect("omap set");
            self.axis_history.insert(hk);
        }
    }

    pub async fn flush(&mut self) {}
    pub async fn close(&mut self) {}

    /// Remove the dataset's root-omap registration after a wipe.
    pub async fn deregister_dataset(&mut self, ds: &Key) {
        let label = ds.canonical();
        let _ = self
            .client
            .omap_rm(&self.pool, ROOT_NS, ROOT_OBJ, &[label.as_str()])
            .await;
        self.known_datasets.remove(&label);
        self.known_collocs.retain(|(d, _)| d != &label);
        self.axes_cache.retain(|(d, _), _| d != &label);
    }

    async fn ensure_axes(&mut self, ds: &Key, colloc: &Key) -> Option<()> {
        let key = (ds.canonical(), colloc.canonical());
        if self.axes_cache.contains_key(&key) {
            return Some(());
        }
        let ns = self.ensure_dataset(ds, false).await?;
        let cc = colloc.canonical();
        let idx = index_obj(&cc);
        let meta = self
            .client
            .omap_get(&self.pool, &ns, &idx, &["axes"])
            .await
            .ok()?;
        let dims = String::from_utf8(meta.get("axes")?.clone()).ok()?;
        let mut axes = HashMap::new();
        for dim in dims.split(',').filter(|d| !d.is_empty()) {
            // one RPC per axis: keys are the values
            let mut vals = self
                .client
                .omap_keys(&self.pool, &ns, &axis_obj(&cc, dim))
                .await
                .unwrap_or_default();
            vals.sort();
            axes.insert(dim.to_string(), vals);
        }
        self.axes_cache.insert(key, axes);
        Some(())
    }

    pub fn invalidate_preload(&mut self, ds: &Key) {
        let dsc = ds.canonical();
        self.axes_cache.retain(|(d, _), _| d != &dsc);
    }

    pub async fn axis(&mut self, ds: &Key, colloc: &Key, dim: &str) -> Vec<String> {
        if self.ensure_axes(ds, colloc).await.is_none() {
            return Vec::new();
        }
        self.axes_cache[&(ds.canonical(), colloc.canonical())]
            .get(dim)
            .cloned()
            .unwrap_or_default()
    }

    pub async fn retrieve(
        &mut self,
        ds: &Key,
        colloc: &Key,
        elem: &Key,
    ) -> Option<FieldLocation> {
        self.ensure_axes(ds, colloc).await?;
        {
            let axes = &self.axes_cache[&(ds.canonical(), colloc.canonical())];
            for (dim, val) in &elem.0 {
                if !axes.get(dim)?.contains(val) {
                    return None;
                }
            }
        }
        let ns = Self::ns_of(ds);
        let cc = colloc.canonical();
        let got = self
            .client
            .omap_get(&self.pool, &ns, &index_obj(&cc), &[&elem.canonical()])
            .await
            .ok()?;
        let raw = got.get(&elem.canonical())?;
        FieldLocation::parse_uri(&String::from_utf8(raw.clone()).ok()?)
    }

    /// list(): whole indexes fetched with single `omap_get_all` RPCs.
    pub async fn list(&mut self, ds: &Key, request: &Request) -> Vec<(Key, FieldLocation)> {
        let Some(ns) = self.ensure_dataset(ds, false).await else {
            return Vec::new();
        };
        let cat = self
            .client
            .omap_get_all(&self.pool, &ns, CAT_OBJ)
            .await
            .unwrap_or_default();
        let fixed = request.fixed_key();
        let mut out = Vec::new();
        for (k, _) in cat {
            let Some(cc) = k.strip_prefix("colloc:") else {
                continue;
            };
            let ck = Key::parse(cc).unwrap_or_default();
            let conflict = ck
                .0
                .iter()
                .any(|(d, v)| fixed.get(d).map(|fv| fv != v).unwrap_or(false));
            if conflict {
                continue;
            }
            let entries = self
                .client
                .omap_get_all(&self.pool, &ns, &index_obj(cc))
                .await
                .unwrap_or_default();
            for (elem_key, raw) in entries {
                if elem_key == "key" || elem_key == "axes" {
                    continue;
                }
                let ek = Key::parse(&elem_key).unwrap_or_default();
                let full = ds.merged(&ck).merged(&ek);
                if !request.matches(&full) {
                    continue;
                }
                if let Some(loc) =
                    FieldLocation::parse_uri(&String::from_utf8(raw).unwrap_or_default())
                {
                    out.push((full, loc));
                }
            }
        }
        out
    }
}

impl crate::fdb::backend::Catalogue for RadosCatalogue {
    fn name(&self) -> &'static str {
        "rados"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        _id: &'a Key,
        loc: &'a FieldLocation,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), crate::fdb::FdbError>> {
        // omap insertions into always-creatable objects — no fallible
        // surface on this path
        Box::pin(async move {
            RadosCatalogue::archive(self, ds, colloc, elem, loc).await;
            Ok(())
        })
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::CatalogueSession>> {
        // index omaps live in the shared pool and inserts are immediately
        // visible; a forked client over the same `Rc<CephPool>` is
        // read-equivalent (its axis caches start cold, which only costs
        // time, never answers)
        Some(Box::new(RadosCatalogue::new(
            self.client.fork(),
            &self.pool,
            self.schema.clone(),
        )))
    }

    fn retrieve<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        _id: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(RadosCatalogue::retrieve(self, ds, colloc, elem))
    }

    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Vec<String>> {
        Box::pin(RadosCatalogue::axis(self, ds, colloc, dim))
    }

    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Vec<(Key, FieldLocation)>> {
        Box::pin(RadosCatalogue::list(self, ds, request))
    }

    fn invalidate_preload(&mut self, ds: &Key) {
        RadosCatalogue::invalidate_preload(self, ds);
    }

    fn deregister_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, ()> {
        Box::pin(RadosCatalogue::deregister_dataset(self, ds))
    }
}
