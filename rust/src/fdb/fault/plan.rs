//! Fault plans: which operations fail, when, and how — parsed from the
//! `--fault` spec string, executed deterministically from a seeded RNG.

use std::cell::RefCell;
use std::rc::Rc;

use crate::fdb::FdbError;
use crate::sim::exec::Sim;
use crate::sim::time::SimTime;
use crate::util::rng::Rng;

/// The operation classes faults can target. Store-side classes map to
/// [`crate::fdb::backend::Store`] methods, catalogue-side ones to
/// [`crate::fdb::backend::Catalogue`] methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultClass {
    /// store archive (data write)
    Write,
    /// store read / read_ranges (per handle)
    Read,
    /// store flush
    Flush,
    /// catalogue archive (index mutation)
    Index,
    /// catalogue flush/close (index persistence)
    IndexFlush,
}

impl FaultClass {
    fn parse(s: &str) -> Option<FaultClass> {
        Some(match s {
            "write" => FaultClass::Write,
            "read" => FaultClass::Read,
            "flush" => FaultClass::Flush,
            "index" => FaultClass::Index,
            "index-flush" => FaultClass::IndexFlush,
            _ => return None,
        })
    }

    fn idx(self) -> usize {
        match self {
            FaultClass::Write => 0,
            FaultClass::Read => 1,
            FaultClass::Flush => 2,
            FaultClass::Index => 3,
            FaultClass::IndexFlush => 4,
        }
    }
}

const NCLASSES: usize = 5;

/// One fault rule of a plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// After `after` operations of the class, the whole instance is dead:
    /// every subsequent operation (of ANY class) fails — a crashed node.
    FailStop { after: u64 },
    /// The `nth` write (0-based) persists only a prefix of its bytes and
    /// then reports failure — a torn write.
    Torn { nth: u64 },
    /// Each operation of the class fails with probability `prob`.
    /// `transient` marks the injected error retryable (spec suffix
    /// `:transient`, e.g. `err:read:p0.3:transient`): the detail string
    /// carries the marker so [`crate::fdb::telemetry::is_transient`]
    /// classifies it and retry policies re-attempt the op. Without the
    /// marker the error models a permanent fault (bad sector, corrupt
    /// object) that retrying cannot fix.
    Err { prob: f64, transient: bool },
    /// Each operation of the class is delayed by `micros` of sim time —
    /// a slow replica/device.
    Slow { micros: u64 },
    /// Bit rot: with probability `prob` the operation's payload has one
    /// byte flipped — written rotten (`corrupt:write:p<f>`) or rotting
    /// on the way back (`corrupt:read:p<f>`). The operation itself
    /// *succeeds*; only checksum verification can tell. Write/read
    /// classes only.
    Corrupt { prob: f64 },
}

/// A parsed, cloneable fault plan. Cloning shares the build counter, so
/// every Store/Catalogue built from clones of one plan gets its own
/// deterministic RNG stream (replica 0 and replica 1 of a replicated
/// store see *different* fault sequences — dead-replica rotation is
/// exercisable end-to-end).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<(FaultClass, FaultAction)>,
    /// `only=<n>` clause: rules apply only to the n-th built instance
    /// (0-based build order); every other instance gets a transparent
    /// wrapper. This is how a spec targets ONE replica of a replicated
    /// store — e.g. `slow:read:2000,only=1` slows replica 1 and leaves
    /// replica 0 healthy.
    pub only_instance: Option<u64>,
    /// distinct stream per built instance, shared across config clones
    builds: Rc<std::cell::Cell<u64>>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rules: Vec::new(),
            only_instance: None,
            builds: Rc::new(std::cell::Cell::new(0)),
        }
    }

    pub fn with_rule(mut self, class: FaultClass, action: FaultAction) -> FaultPlan {
        self.rules.push((class, action));
        self
    }

    /// Scope every rule to the n-th built instance (see `only_instance`).
    pub fn with_only_instance(mut self, n: u64) -> FaultPlan {
        self.only_instance = Some(n);
        self
    }

    /// Parse the `--fault` spec grammar (see the module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan, FdbError> {
        let invalid =
            |msg: String| FdbError::InvalidConfig(format!("fault spec `{spec}`: {msg}"));
        let mut plan = FaultPlan::new(0);
        for clause in spec.split(',').filter(|c| !c.is_empty()) {
            if let Some(seed) = clause.strip_prefix("seed=") {
                plan.seed = seed
                    .parse()
                    .map_err(|_| invalid(format!("bad seed `{seed}`")))?;
                continue;
            }
            if let Some(n) = clause.strip_prefix("only=") {
                plan.only_instance = Some(
                    n.parse()
                        .map_err(|_| invalid(format!("bad instance `{n}`")))?,
                );
                continue;
            }
            let parts: Vec<&str> = clause.split(':').collect();
            let (action, class, arg, modifier) = match parts[..] {
                [action, class, arg] => (action, class, arg, None),
                [action, class, arg, modifier] => (action, class, arg, Some(modifier)),
                _ => {
                    return Err(invalid(format!(
                        "clause `{clause}` is not action:class:arg[:modifier]"
                    )))
                }
            };
            if let Some(m) = modifier {
                if action != "err" || m != "transient" {
                    return Err(invalid(format!(
                        "modifier `{m}` only valid as err:<class>:p<f>:transient"
                    )));
                }
            }
            let class = FaultClass::parse(class)
                .ok_or_else(|| invalid(format!("unknown op class `{class}`")))?;
            let action = match action {
                "failstop" => FaultAction::FailStop {
                    after: arg
                        .parse()
                        .map_err(|_| invalid(format!("bad count `{arg}`")))?,
                },
                "torn" => {
                    if class != FaultClass::Write {
                        return Err(invalid("torn faults only apply to write".into()));
                    }
                    FaultAction::Torn {
                        nth: arg
                            .parse()
                            .map_err(|_| invalid(format!("bad count `{arg}`")))?,
                    }
                }
                "err" => {
                    let p = arg
                        .strip_prefix('p')
                        .and_then(|p| p.parse::<f64>().ok())
                        .ok_or_else(|| invalid(format!("bad probability `{arg}` (want pN.N)")))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(invalid(format!("probability {p} outside [0,1]")));
                    }
                    FaultAction::Err {
                        prob: p,
                        transient: modifier.is_some(),
                    }
                }
                "slow" => FaultAction::Slow {
                    micros: arg
                        .parse()
                        .map_err(|_| invalid(format!("bad delay `{arg}`")))?,
                },
                "corrupt" => {
                    if class != FaultClass::Write && class != FaultClass::Read {
                        return Err(invalid(
                            "corrupt faults only apply to write/read".into(),
                        ));
                    }
                    let p = arg
                        .strip_prefix('p')
                        .and_then(|p| p.parse::<f64>().ok())
                        .ok_or_else(|| invalid(format!("bad probability `{arg}` (want pN.N)")))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(invalid(format!("probability {p} outside [0,1]")));
                    }
                    FaultAction::Corrupt { prob: p }
                }
                other => return Err(invalid(format!("unknown action `{other}`"))),
            };
            plan.rules.push((class, action));
        }
        Ok(plan)
    }

    /// Human-readable shape for `BackendConfig::describe()`.
    pub fn describe(&self) -> String {
        if self.rules.is_empty() {
            return "none".to_string();
        }
        let parts: Vec<String> = self
            .rules
            .iter()
            .map(|(c, a)| {
                let class = match c {
                    FaultClass::Write => "write",
                    FaultClass::Read => "read",
                    FaultClass::Flush => "flush",
                    FaultClass::Index => "index",
                    FaultClass::IndexFlush => "index-flush",
                };
                match a {
                    FaultAction::FailStop { after } => format!("failstop:{class}:{after}"),
                    FaultAction::Torn { nth } => format!("torn:{class}:{nth}"),
                    FaultAction::Err { prob, transient } => {
                        if *transient {
                            format!("err:{class}:p{prob}:transient")
                        } else {
                            format!("err:{class}:p{prob}")
                        }
                    }
                    FaultAction::Slow { micros } => format!("slow:{class}:{micros}"),
                    FaultAction::Corrupt { prob } => format!("corrupt:{class}:p{prob}"),
                }
            })
            .collect();
        let mut out = parts.join(",");
        if let Some(n) = self.only_instance {
            out.push_str(&format!(",only={n}"));
        }
        out
    }

    /// Mint the shared mutable state for one built wrapper instance.
    /// Each call advances the build counter so successive instances
    /// (e.g. the replicas of a replicated store) draw independent
    /// deterministic RNG streams.
    pub fn build_state(&self, sim: Option<&Sim>) -> Rc<RefCell<FaultState>> {
        let instance = self.builds.get();
        self.builds.set(instance + 1);
        Rc::new(RefCell::new(FaultState::new(self, instance, sim)))
    }
}

/// Shared mutable fault state: per-class op counters plus the seeded RNG.
/// One `Rc<RefCell<_>>` is shared by a wrapper and every session it
/// mints, so fail-stop counts total instance operations — a dead node
/// takes its sessions down with it.
pub struct FaultState {
    rules: Vec<(FaultClass, FaultAction)>,
    counts: [u64; NCLASSES],
    rng: Rng,
    dead: bool,
    sim: Option<Sim>,
    /// payload corruptions injected so far (bit-rot observability: the
    /// harness can assert scrub found everything that was planted)
    corruptions: u64,
}

/// What the wrapper must do for one operation.
pub enum FaultDecision {
    /// run the inner op (after `delay`, if any); with `corrupt` drawn,
    /// flip the payload byte at `draw % len` — silent bit rot the op
    /// itself never reports
    Proceed {
        delay: Option<SimTime>,
        corrupt: Option<u64>,
    },
    /// fail with the given injected error
    Fail(FdbError),
    /// write class only: persist `keep` of the payload's bytes through
    /// the inner store, then fail
    TornWrite { keep: u64 },
}

fn injected(detail: String) -> FdbError {
    FdbError::Backend {
        backend: "fault",
        detail,
    }
}

impl FaultState {
    fn new(plan: &FaultPlan, instance: u64, sim: Option<&Sim>) -> FaultState {
        let mut root = Rng::new(plan.seed);
        // an `only=` clause scoped to a different instance builds a
        // transparent wrapper: no rules, nothing ever fires
        let scoped_out = plan.only_instance.is_some_and(|k| k != instance);
        FaultState {
            rules: if scoped_out {
                Vec::new()
            } else {
                plan.rules.clone()
            },
            counts: [0; NCLASSES],
            rng: root.fork(instance),
            dead: false,
            sim: sim.cloned(),
            corruptions: 0,
        }
    }

    /// Account one operation of `class` and decide its fate. `len` is
    /// the payload size for write ops (torn-write prefix computation).
    pub fn on_op(&mut self, class: FaultClass, len: u64) -> FaultDecision {
        if self.dead {
            return FaultDecision::Fail(injected("instance is fail-stopped".into()));
        }
        let n = self.counts[class.idx()];
        self.counts[class.idx()] += 1;
        let mut delay: Option<SimTime> = None;
        let mut corrupt: Option<u64> = None;
        for (c, action) in &self.rules {
            if *c != class {
                continue;
            }
            match action {
                FaultAction::FailStop { after } => {
                    if n >= *after {
                        self.dead = true;
                        return FaultDecision::Fail(injected(format!(
                            "fail-stop after {after} {class:?} ops"
                        )));
                    }
                }
                FaultAction::Torn { nth } => {
                    if n == *nth {
                        return FaultDecision::TornWrite { keep: len / 2 };
                    }
                }
                FaultAction::Err { prob, transient } => {
                    if self.rng.f64() < *prob {
                        return FaultDecision::Fail(injected(if *transient {
                            format!("injected transient {class:?} error (op {n})")
                        } else {
                            format!("injected {class:?} error (op {n})")
                        }));
                    }
                }
                FaultAction::Slow { micros } => {
                    delay = Some(SimTime::micros(*micros));
                }
                FaultAction::Corrupt { prob } => {
                    if self.rng.f64() < *prob {
                        corrupt = Some(self.rng.next_u64());
                    }
                }
            }
        }
        FaultDecision::Proceed { delay, corrupt }
    }

    /// Count one byte-flip actually applied (the wrapper calls this —
    /// an empty payload has nothing to flip, so the draw alone doesn't
    /// count).
    pub fn note_corruption(&mut self) {
        self.corruptions += 1;
    }

    /// Payload corruptions injected so far by this instance.
    pub fn corruptions(&self) -> u64 {
        self.corruptions
    }

    pub fn sim(&self) -> Option<Sim> {
        self.sim.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let plan =
            FaultPlan::parse("seed=7,failstop:write:5,torn:write:3,err:read:p0.25,slow:flush:100")
                .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.rules.len(), 4);
        assert_eq!(
            plan.rules[0],
            (FaultClass::Write, FaultAction::FailStop { after: 5 })
        );
        assert_eq!(plan.rules[1], (FaultClass::Write, FaultAction::Torn { nth: 3 }));
        assert_eq!(
            plan.rules[2],
            (FaultClass::Read, FaultAction::Err { prob: 0.25, transient: false })
        );
        assert_eq!(
            plan.rules[3],
            (FaultClass::Flush, FaultAction::Slow { micros: 100 })
        );
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "flip:write:1",
            "failstop:disk:1",
            "err:read:0.5",
            "err:read:p1.5",
            "torn:read:1",
            "seed=x",
            "failstop:write",
            "err:read:p0.5:forever",
            "slow:read:100:transient",
            "err:read:p0.5:transient:x",
            "corrupt:flush:p0.5",
            "corrupt:index:p0.5",
            "corrupt:read:0.5",
            "corrupt:read:p2.0",
            "corrupt:read:p0.5:transient",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn corrupt_clause_parses_draws_and_round_trips() {
        let plan = FaultPlan::parse("seed=5,corrupt:read:p0.5,corrupt:write:p1").unwrap();
        assert_eq!(plan.rules[0], (FaultClass::Read, FaultAction::Corrupt { prob: 0.5 }));
        assert_eq!(plan.rules[1], (FaultClass::Write, FaultAction::Corrupt { prob: 1.0 }));
        assert_eq!(plan.describe(), "corrupt:read:p0.5,corrupt:write:p1");
        // p1.0: every op of the class draws a flip position; the op
        // still Proceeds — bit rot is silent
        let state = plan.build_state(None);
        let mut s = state.borrow_mut();
        for _ in 0..8 {
            assert!(matches!(
                s.on_op(FaultClass::Write, 64),
                FaultDecision::Proceed { corrupt: Some(_), .. }
            ));
        }
        // flush is untouched by corrupt rules
        assert!(matches!(
            s.on_op(FaultClass::Flush, 0),
            FaultDecision::Proceed { corrupt: None, .. }
        ));
        // the draw only counts once the wrapper actually flips a byte
        assert_eq!(s.corruptions(), 0);
        s.note_corruption();
        assert_eq!(s.corruptions(), 1);
    }

    #[test]
    fn empty_spec_is_no_faults() {
        let plan = FaultPlan::parse("").unwrap();
        assert!(plan.rules.is_empty());
        assert_eq!(plan.describe(), "none");
    }

    #[test]
    fn failstop_kills_every_class() {
        let plan =
            FaultPlan::new(1).with_rule(FaultClass::Write, FaultAction::FailStop { after: 2 });
        let state = plan.build_state(None);
        let mut s = state.borrow_mut();
        assert!(matches!(s.on_op(FaultClass::Write, 10), FaultDecision::Proceed { .. }));
        assert!(matches!(s.on_op(FaultClass::Write, 10), FaultDecision::Proceed { .. }));
        assert!(matches!(s.on_op(FaultClass::Write, 10), FaultDecision::Fail(_)));
        // dead: reads fail too
        assert!(matches!(s.on_op(FaultClass::Read, 0), FaultDecision::Fail(_)));
    }

    #[test]
    fn torn_write_hits_exactly_the_nth() {
        let plan = FaultPlan::new(1).with_rule(FaultClass::Write, FaultAction::Torn { nth: 1 });
        let state = plan.build_state(None);
        let mut s = state.borrow_mut();
        assert!(matches!(s.on_op(FaultClass::Write, 100), FaultDecision::Proceed { .. }));
        assert!(
            matches!(s.on_op(FaultClass::Write, 100), FaultDecision::TornWrite { keep: 50 })
        );
        assert!(matches!(s.on_op(FaultClass::Write, 100), FaultDecision::Proceed { .. }));
    }

    #[test]
    fn transient_marker_parses_and_classifies() {
        // parse: the 4-part err clause round-trips through describe()
        let plan = FaultPlan::parse("seed=3,err:read:p0.3:transient").unwrap();
        assert_eq!(
            plan.rules[0],
            (FaultClass::Read, FaultAction::Err { prob: 0.3, transient: true })
        );
        assert_eq!(plan.describe(), "err:read:p0.3:transient");
        // classification: transient-marked injected errors are the ONLY
        // injected err-rule failures a retry policy may re-attempt
        let fire = |transient: bool| -> FdbError {
            let plan = FaultPlan::new(1)
                .with_rule(FaultClass::Read, FaultAction::Err { prob: 1.0, transient });
            let state = plan.build_state(None);
            let mut s = state.borrow_mut();
            match s.on_op(FaultClass::Read, 0) {
                FaultDecision::Fail(e) => e,
                _ => panic!("p1.0 must fire"),
            }
        };
        assert!(crate::fdb::telemetry::is_transient(&fire(true)));
        assert!(!crate::fdb::telemetry::is_transient(&fire(false)));
        // a fail-stopped instance is permanently dead — never retryable
        let plan =
            FaultPlan::new(1).with_rule(FaultClass::Read, FaultAction::FailStop { after: 0 });
        let state = plan.build_state(None);
        let FaultDecision::Fail(e) = state.borrow_mut().on_op(FaultClass::Read, 0) else {
            panic!("fail-stop must fire");
        };
        assert!(!crate::fdb::telemetry::is_transient(&e));
    }

    #[test]
    fn err_probability_is_deterministic_per_seed() {
        let run = |seed| {
            let plan = FaultPlan::new(seed)
                .with_rule(FaultClass::Read, FaultAction::Err { prob: 0.5, transient: false });
            let state = plan.build_state(None);
            let mut s = state.borrow_mut();
            (0..64)
                .map(|_| matches!(s.on_op(FaultClass::Read, 0), FaultDecision::Fail(_)))
                .collect::<Vec<bool>>()
        };
        assert_eq!(run(42), run(42), "same seed, same fault sequence");
        assert_ne!(run(42), run(43), "different seed, different sequence");
    }

    #[test]
    fn only_clause_scopes_rules_to_one_instance() {
        let plan = FaultPlan::parse("slow:read:2000,only=1").unwrap();
        assert_eq!(plan.only_instance, Some(1));
        assert!(plan.describe().ends_with(",only=1"));
        // instance 0: transparent; instance 1: the slow rule fires
        let healthy = plan.build_state(None);
        let slow = plan.build_state(None);
        assert!(matches!(
            healthy.borrow_mut().on_op(FaultClass::Read, 0),
            FaultDecision::Proceed { delay: None, .. }
        ));
        assert!(matches!(
            slow.borrow_mut().on_op(FaultClass::Read, 0),
            FaultDecision::Proceed { delay: Some(d), .. } if d == SimTime::micros(2000)
        ));
        // bad instance number rejected
        assert!(FaultPlan::parse("slow:read:10,only=x").is_err());
    }

    #[test]
    fn instances_draw_independent_streams() {
        let plan = FaultPlan::new(9)
            .with_rule(FaultClass::Read, FaultAction::Err { prob: 0.5, transient: false });
        let a = plan.build_state(None);
        let b = plan.build_state(None); // e.g. replica 1 of the same config
        let seq = |state: &Rc<RefCell<FaultState>>| {
            let mut s = state.borrow_mut();
            (0..64)
                .map(|_| matches!(s.on_op(FaultClass::Read, 0), FaultDecision::Fail(_)))
                .collect::<Vec<bool>>()
        };
        assert_ne!(seq(&a), seq(&b), "replicas must not fail in lockstep");
    }
}
