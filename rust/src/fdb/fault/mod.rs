//! The durability subsystem: deterministic fault injection + the
//! WAL-backed crash-recovery machinery.
//!
//! Three cooperating layers:
//!
//! * [`wal`] — the write-ahead log format the POSIX catalogue appends in
//!   durable mode (`IoProfile::durable`), with checksummed records,
//!   commit watermarks, torn-tail truncation, and idempotent replay.
//! * [`FaultStore`] / [`FaultCatalogue`] — wrappers in the style of
//!   [`crate::fdb::wrappers`] that inject *seeded, deterministic* faults
//!   into any inner backend: fail-stop after N operations, torn writes
//!   that persist a prefix, probabilistic read errors, slow replicas via
//!   the sim clock. Composable through [`crate::fdb::BackendConfig::Fault`]
//!   and surfaced as `fdbctl hammer --fault <spec>`, so the replicated/
//!   tiered/sharded failure paths (`AllReplicasFailed`, `ReadPolicy`
//!   dead-replica rotation) finally get end-to-end coverage.
//! * The crash-recovery scenario (`crate::bench::crash`) kills a durable
//!   writer at seeded fault points mid-archive, reopens, replays the
//!   WAL, and verifies index/data agreement (`abl_recovery`).
//!
//! Fault spec grammar (comma-separated clauses):
//!
//! ```text
//! seed=<u64>                 RNG seed (default 0)
//! failstop:<class>:<n>      after n ops of <class>, EVERY op fails
//! torn:write:<n>            the n-th write persists a prefix, then errors
//! err:<class>:p<prob>       each op of <class> fails with probability p
//! err:<class>:p<prob>:transient   as above, but the injected error is
//!                           marked RETRYABLE — retry policies
//!                           ([`crate::fdb::ResilienceProfile`]) re-attempt
//!                           it; unmarked err faults model permanent damage
//! slow:<class>:<micros>     delay each op of <class> by <micros> µs
//! only=<n>                  scope ALL rules to the n-th built instance
//! ```
//!
//! `<class>` is one of `write`, `read`, `flush` (store side), `index`,
//! `index-flush` (catalogue side). Example:
//! `seed=7,err:read:p0.2,slow:write:250`. Instances are numbered in
//! build order (replica 0 before replica 1, stores before catalogues),
//! so `slow:read:2000,only=1` degrades exactly one replica of a
//! `replicated:2` store — the telemetry ablation (`abl_observe`) uses
//! this to show per-layer histograms isolating a slow replica.

pub mod catalogue;
pub mod plan;
pub mod store;
pub mod wal;

pub use catalogue::FaultCatalogue;
pub use plan::{FaultAction, FaultClass, FaultPlan, FaultState};
pub use store::FaultStore;
pub use wal::{RecoveryStats, WalRecord};
