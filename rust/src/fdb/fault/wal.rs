//! Write-ahead log for the POSIX catalogue (the durability subsystem's
//! persistence layer).
//!
//! The POSIX catalogue's `archive()` is an in-memory mutation: the index
//! entry only reaches storage at `flush()`/`close()`. A writer that dies
//! between archive and flush silently loses every unflushed entry — the
//! data bytes sit in the store's data files with nothing pointing at
//! them. In durable mode the catalogue appends an *intent* record here
//! (fdatasync'd) before mutating its in-memory index, so a recovering
//! process can re-apply exactly the lost tail.
//!
//! Record framing (little-endian, one record per append):
//!
//! ```text
//! [len u32][crc u64][payload]
//! payload = [tag u8][seq u64][tag-specific fields]
//! tag 0 = Intent { colloc str, elem str, uri str, offset u64, length u64 }
//! tag 1 = Commit {}          (seq is the commit watermark)
//! tag 2 = Intent + content checksum (tag-0 fields then ck u64)
//! ```
//!
//! Tag 2 exists because [`Dec`] treats truncation as `None` — a trailing
//! optional field on tag 0 would be indistinguishable from a short
//! record, so checksummed intents get their own tag. Logs written by
//! older code (tag-0 only) parse unchanged; the recovered entries are
//! simply unverified.
//!
//! `crc` is FNV-1a over the payload. [`parse_stream`] accepts the
//! longest valid prefix and reports how many torn/corrupt tail bytes it
//! dropped — the logical truncation the recovery path relies on (the
//! simulated filesystem has no truncate(2); recovery unlinks the whole
//! WAL once its records are re-persisted).
//!
//! Replay is idempotent by construction: intents are keyed by element,
//! so applying a record twice overwrites the entry with itself, and a
//! `Commit { seq }` watermark excludes every intent with `seq < commit`
//! (those already reached a persisted partial index).

use crate::fdb::wire::{Dec, Enc};

/// FNV-1a 64-bit checksum (offset basis / prime per the spec).
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

/// One WAL record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// Archive intent, appended (and fdatasync'd) *before* the in-memory
    /// index mutation. Carries everything needed to re-run the indexing:
    /// the collocation + element canonical keys and the field location
    /// split the way the catalogue's URI store splits it.
    Intent {
        seq: u64,
        colloc: String,
        elem: String,
        uri: String,
        offset: u64,
        length: u64,
        /// content checksum of the field payload (tag-2 records); `None`
        /// for legacy tag-0 intents — recovery then gates on data-file
        /// size alone
        ck: Option<u64>,
    },
    /// Commit watermark, appended after a successful catalogue flush:
    /// every intent with `seq < seq` has reached a persisted partial
    /// index and must not be replayed.
    Commit { seq: u64 },
}

impl WalRecord {
    pub fn seq(&self) -> u64 {
        match self {
            WalRecord::Intent { seq, .. } | WalRecord::Commit { seq } => *seq,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            WalRecord::Intent {
                seq,
                colloc,
                elem,
                uri,
                offset,
                length,
                ck,
            } => {
                let tag = if ck.is_some() { 2 } else { 0 };
                e.u8(tag).u64(*seq).str(colloc).str(elem).str(uri).u64(*offset).u64(*length);
                if let Some(ck) = ck {
                    e.u64(*ck);
                }
            }
            WalRecord::Commit { seq } => {
                e.u8(1).u64(*seq);
            }
        }
        let payload = e.finish();
        let mut out = Vec::with_capacity(payload.len() + 12);
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&checksum(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    fn decode(payload: &[u8]) -> Option<WalRecord> {
        let mut d = Dec::new(payload);
        match d.u8()? {
            tag @ (0 | 2) => Some(WalRecord::Intent {
                seq: d.u64()?,
                colloc: d.str()?,
                elem: d.str()?,
                uri: d.str()?,
                offset: d.u64()?,
                length: d.u64()?,
                ck: if tag == 2 { Some(d.u64()?) } else { None },
            }),
            1 => Some(WalRecord::Commit { seq: d.u64()? }),
            _ => None,
        }
    }
}

/// Parse the longest valid record prefix of a WAL file. Returns the
/// records plus the number of tail bytes dropped (torn final append or
/// checksum-corrupt record — everything after the first bad frame).
pub fn parse_stream(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos + 12 <= bytes.len() {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let Some(crc_bytes) = bytes.get(pos + 4..pos + 12) else {
            break;
        };
        let crc = u64::from_le_bytes(crc_bytes.try_into().unwrap());
        let Some(payload) = bytes.get(pos + 12..pos + 12 + len) else {
            break; // torn tail
        };
        if checksum(payload) != crc {
            break; // corrupt record: stop at the last good prefix
        }
        let Some(rec) = WalRecord::decode(payload) else {
            break;
        };
        out.push(rec);
        pos += 12 + len;
    }
    (out, bytes.len() - pos)
}

/// The replay set of a parsed WAL: intents past the last commit
/// watermark, in sequence order. Everything before the watermark already
/// reached a persisted partial index.
pub fn uncommitted(records: &[WalRecord]) -> Vec<&WalRecord> {
    let watermark = records
        .iter()
        .filter_map(|r| match r {
            WalRecord::Commit { seq } => Some(*seq),
            _ => None,
        })
        .max()
        .unwrap_or(0);
    records
        .iter()
        .filter(|r| matches!(r, WalRecord::Intent { seq, .. } if *seq >= watermark))
        .collect()
}

/// What a recovery pass did — summed across WAL files (and catalogue
/// shards, for wrapped catalogues).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// uncommitted intents re-applied to the live index
    pub replayed: usize,
    /// intents below the commit watermark (already persisted, skipped)
    pub committed: usize,
    /// intents whose data bytes were not durable (location past the data
    /// file's persisted size) — skipped, the field is lost as it would
    /// be on a real machine
    pub data_missing: usize,
    /// intents whose persisted data bytes fail the logged content
    /// checksum (bit rot between the WAL append and recovery) — skipped,
    /// a corrupt replay target must never be indexed
    pub data_corrupt: usize,
    /// WAL files processed
    pub wal_files: usize,
    /// torn/corrupt tail bytes dropped across those files
    pub torn_bytes: usize,
}

impl RecoveryStats {
    pub fn merge(&mut self, other: &RecoveryStats) {
        self.replayed += other.replayed;
        self.committed += other.committed;
        self.data_missing += other.data_missing;
        self.data_corrupt += other.data_corrupt;
        self.wal_files += other.wal_files;
        self.torn_bytes += other.torn_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn intent(seq: u64) -> WalRecord {
        WalRecord::Intent {
            seq,
            colloc: "levtype=sfc".into(),
            elem: format!("step={seq}"),
            uri: "posix:///fdb/ds/x.data".into(),
            offset: seq * 128,
            length: 128,
            ck: None,
        }
    }

    #[test]
    fn checksummed_intent_roundtrips_as_tag2() {
        let rec = WalRecord::Intent {
            seq: 7,
            colloc: "levtype=sfc".into(),
            elem: "step=7".into(),
            uri: "posix:///fdb/ds/x.data".into(),
            offset: 896,
            length: 128,
            ck: Some(0xfeed_f00d_dead_beef),
        };
        let bytes = rec.encode();
        // tag byte sits right after the 12-byte frame header
        assert_eq!(bytes[12], 2);
        let (parsed, torn) = parse_stream(&bytes);
        assert_eq!(parsed, vec![rec]);
        assert_eq!(torn, 0);
        // legacy tag-0 intents still carry tag 0 on the wire
        assert_eq!(intent(0).encode()[12], 0);
    }

    #[test]
    fn stream_roundtrip() {
        let records = vec![intent(0), intent(1), WalRecord::Commit { seq: 2 }, intent(2)];
        let mut bytes = Vec::new();
        for r in &records {
            bytes.extend(r.encode());
        }
        let (parsed, torn) = parse_stream(&bytes);
        assert_eq!(parsed, records);
        assert_eq!(torn, 0);
    }

    #[test]
    fn torn_tail_dropped_and_counted() {
        let mut bytes = intent(0).encode();
        let full = intent(1).encode();
        let cut = full.len() - 3;
        bytes.extend_from_slice(&full[..cut]);
        let (parsed, torn) = parse_stream(&bytes);
        assert_eq!(parsed, vec![intent(0)]);
        assert_eq!(torn, cut);
    }

    #[test]
    fn corrupt_record_stops_the_stream() {
        let mut bytes = intent(0).encode();
        let mut bad = intent(1).encode();
        let n = bad.len();
        bad[n - 1] ^= 0xFF; // flip a payload byte: crc mismatch
        bytes.extend_from_slice(&bad);
        bytes.extend(intent(2).encode()); // unreachable past the corruption
        let (parsed, torn) = parse_stream(&bytes);
        assert_eq!(parsed, vec![intent(0)]);
        assert_eq!(torn, bytes.len() - intent(0).encode().len());
    }

    #[test]
    fn commit_watermark_excludes_persisted_intents() {
        let records = vec![
            intent(0),
            intent(1),
            WalRecord::Commit { seq: 2 },
            intent(2),
            intent(3),
        ];
        let replay = uncommitted(&records);
        let seqs: Vec<u64> = replay.iter().map(|r| r.seq()).collect();
        assert_eq!(seqs, vec![2, 3]);
    }

    #[test]
    fn no_commit_replays_everything() {
        let records = vec![intent(0), intent(1)];
        assert_eq!(uncommitted(&records).len(), 2);
    }

    #[test]
    fn replay_set_is_idempotent() {
        // applying the replay set twice produces the same map as once —
        // intents are keyed by element, so re-insertion is a no-op
        let records = vec![intent(0), intent(1), intent(2)];
        let apply = |times: usize| {
            let mut map = std::collections::BTreeMap::new();
            for _ in 0..times {
                for r in uncommitted(&records) {
                    if let WalRecord::Intent {
                        elem, offset, length, ..
                    } = r
                    {
                        map.insert(elem.clone(), (*offset, *length));
                    }
                }
            }
            map
        };
        assert_eq!(apply(1), apply(2));
    }

    #[test]
    fn checksum_is_stable_and_sensitive() {
        assert_eq!(checksum(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(checksum(b"a"), checksum(b"b"));
    }
}
