//! [`FaultStore`]: a [`Store`] wrapper that injects seeded, deterministic
//! faults into its inner store — the data-plane half of the fault
//! harness (see the [`super`] module docs for the spec grammar).

use std::cell::RefCell;
use std::rc::Rc;

use crate::fdb::backend::{LocalBoxFuture, Store, StoreSession};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::FdbError;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

use super::plan::{FaultClass, FaultDecision, FaultState};

/// One gated operation's fate when it is allowed to run.
enum Gated {
    Clean,
    /// write class only: persist `keep` bytes, then report failure
    Torn { keep: u64 },
    /// silent bit rot: flip the payload byte at `draw % len`
    Corrupt { draw: u64 },
}

/// A fault-injecting Store wrapper. All state (op counters, RNG, the
/// fail-stop flag) lives in the shared [`FaultState`], so sessions
/// minted from this store inherit their parent's fate: a fail-stopped
/// instance takes every session down with it, like a crashed node.
pub struct FaultStore {
    inner: Box<dyn Store>,
    state: Rc<RefCell<FaultState>>,
}

impl FaultStore {
    pub fn new(inner: Box<dyn Store>, state: Rc<RefCell<FaultState>>) -> FaultStore {
        FaultStore { inner, state }
    }

    async fn gate(&self, class: FaultClass, len: u64) -> Result<Gated, FdbError> {
        let decision = self.state.borrow_mut().on_op(class, len);
        match decision {
            FaultDecision::Proceed { delay, corrupt } => {
                if let (Some(d), Some(sim)) = (delay, self.state.borrow().sim()) {
                    sim.sleep(d).await;
                }
                Ok(match corrupt {
                    Some(draw) => Gated::Corrupt { draw },
                    None => Gated::Clean,
                })
            }
            FaultDecision::Fail(e) => Err(e),
            FaultDecision::TornWrite { keep } => Ok(Gated::Torn { keep }),
        }
    }

    /// Flip one byte of `data` at `draw % len` — the planted bit rot.
    /// Empty payloads pass through (nothing to flip, nothing counted).
    fn flip_byte(&self, data: Bytes, draw: u64) -> Bytes {
        let len = data.len();
        if len == 0 {
            return data;
        }
        let idx = draw % len;
        let rotten = data.slice(idx, 1).to_vec()[0] ^ 0xFF;
        let mut out = data.slice(0, idx);
        out.append(Bytes::real(vec![rotten]));
        out.append(data.slice(idx + 1, len - idx - 1));
        self.state.borrow_mut().note_corruption();
        out
    }
}

impl Store for FaultStore {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        Box::pin(async move {
            match self.gate(FaultClass::Write, data.len()).await? {
                Gated::Clean => self.inner.archive(ds, colloc, id, data).await,
                Gated::Corrupt { draw } => {
                    // bit rot on the write path: the rotten payload
                    // persists and the op reports success — only the
                    // archive-time checksum carried in the catalogue
                    // can expose it later
                    let rotten = self.flip_byte(data, draw);
                    self.inner.archive(ds, colloc, id, rotten).await
                }
                Gated::Torn { keep } => {
                    // torn write: a prefix of the payload reaches the
                    // inner store, then the operation reports failure —
                    // the caller must treat the field as not archived
                    let prefix = data.slice(0, keep);
                    let _ = self.inner.archive(ds, colloc, id, prefix).await;
                    Err(FdbError::Backend {
                        backend: "fault",
                        detail: format!("torn write: {keep}/{} bytes persisted", data.len()),
                    })
                }
            }
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            self.gate(FaultClass::Flush, 0).await?;
            self.inner.flush().await
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(async move {
            match self.gate(FaultClass::Read, 0).await? {
                Gated::Corrupt { draw } => {
                    let buf = self.inner.read(handle).await?;
                    Ok(self.flip_byte(buf, draw))
                }
                _ => self.inner.read(handle).await,
            }
        })
    }

    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            // one fault-accounted op per handle, matching the inner
            // store's default loop — a mid-batch fault surfaces exactly
            // at the affected range
            let mut out = Vec::with_capacity(handles.len());
            for handle in handles {
                let gated = self.gate(FaultClass::Read, 0).await?;
                let buf = self.inner.read(handle).await?;
                out.push(match gated {
                    Gated::Corrupt { draw } => self.flip_byte(buf, draw),
                    _ => buf,
                });
            }
            Ok(out)
        })
    }

    // Verified reads stay on the trait defaults on purpose: they route
    // through the gated read/read_ranges above, so verification sits
    // ABOVE the injected bit rot and catches it. The scrub/repair
    // plumbing below forwards to the inner store — repair is the
    // harness's convergence path and must actually reach the bytes.

    fn repair<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        self.inner.repair(handle, data)
    }

    /// Scrub probes the bytes *on disk* (the inner store), not the
    /// gated read path: `corrupt:read` rot is transient wire damage —
    /// it must trip verified reads, not show up as disk damage — while
    /// `corrupt:write` rot persisted through archive and the inner
    /// probe finds it.
    fn scrub_field<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        expect_len: u64,
        ck: Option<u64>,
        do_repair: bool,
    ) -> LocalBoxFuture<'a, Result<crate::fdb::scrub::ScrubOutcome, FdbError>> {
        self.inner.scrub_field(handle, expect_len, ck, do_repair)
    }

    fn scrub_inventory<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> LocalBoxFuture<'a, Option<Vec<(String, u64)>>> {
        self.inner.scrub_inventory(ds)
    }

    fn quarantine_object<'a>(
        &'a mut self,
        ds: &'a Key,
        container: &'a str,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        self.inner.quarantine_object(ds, container)
    }

    fn direct_retrieve_enabled(&self) -> bool {
        self.inner.direct_retrieve_enabled()
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        self.inner.retrieve_direct(ds, id)
    }

    fn supports_wipe(&self) -> bool {
        self.inner.supports_wipe()
    }

    fn wipe_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        self.inner.wipe_dataset(ds)
    }

    fn take_lock_time(&self) -> SimTime {
        self.inner.take_lock_time()
    }

    fn session(&mut self) -> Option<Box<dyn StoreSession>> {
        let inner = self.inner.session()?;
        Some(Box::new(FaultStore {
            inner: inner.into_store(),
            state: self.state.clone(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullStore};
    use crate::fdb::fault::plan::{FaultAction, FaultPlan};

    fn fault_store(plan: FaultPlan) -> FaultStore {
        let state = plan.build_state(None);
        FaultStore::new(Box::new(NullStore), state)
    }

    fn archive_one(s: &mut FaultStore, n: u64) -> Result<FieldLocation, FdbError> {
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        block_on(s.archive(&ds, &ds, &id, Bytes::virt(n, 1)))
    }

    #[test]
    fn failstop_after_n_writes_then_everything_fails() {
        let plan =
            FaultPlan::new(3).with_rule(FaultClass::Write, FaultAction::FailStop { after: 2 });
        let mut s = fault_store(plan);
        assert!(archive_one(&mut s, 8).is_ok());
        assert!(archive_one(&mut s, 8).is_ok());
        let err = archive_one(&mut s, 8).unwrap_err();
        assert!(matches!(err, FdbError::Backend { backend: "fault", .. }));
        // dead instance: reads fail too
        let h = DataHandle::Null { length: 8 };
        assert!(block_on(s.read(&h)).is_err());
    }

    #[test]
    fn torn_write_persists_prefix_and_errors() {
        let plan = FaultPlan::new(3).with_rule(FaultClass::Write, FaultAction::Torn { nth: 0 });
        let mut s = fault_store(plan);
        let err = archive_one(&mut s, 100).unwrap_err();
        let FdbError::Backend { detail, .. } = err else {
            panic!("expected backend error")
        };
        assert!(detail.contains("torn write: 50/100"), "{detail}");
        // the next write is clean
        assert!(archive_one(&mut s, 100).is_ok());
    }

    #[test]
    fn sessions_share_the_fate_of_their_parent() {
        let plan =
            FaultPlan::new(3).with_rule(FaultClass::Write, FaultAction::FailStop { after: 1 });
        let mut s = fault_store(plan);
        let mut session = s.session().expect("null store has sessions");
        assert!(archive_one(&mut s, 8).is_ok());
        // the parent's counter tripped the fail-stop: the session dies too
        let ds = Key::new();
        let id = Key::of(&[("step", "2")]);
        assert!(archive_one(&mut s, 8).is_err());
        assert!(block_on(session.archive(&ds, &ds, &id, Bytes::virt(8, 1))).is_err());
    }

    #[test]
    fn read_faults_surface_per_ranged_handle() {
        let plan =
            FaultPlan::new(3).with_rule(FaultClass::Read, FaultAction::FailStop { after: 1 });
        let mut s = fault_store(plan);
        let handles = vec![
            DataHandle::Null { length: 4 },
            DataHandle::Null { length: 4 },
        ];
        // first handle reads, second hits the fail-stop → typed error for
        // the whole batch, never a short result
        let err = block_on(s.read_ranges(&handles)).unwrap_err();
        assert!(matches!(err, FdbError::Backend { backend: "fault", .. }));
    }

    #[test]
    fn read_corruption_flips_one_byte_and_counts() {
        let plan =
            FaultPlan::new(11).with_rule(FaultClass::Read, FaultAction::Corrupt { prob: 1.0 });
        let state = plan.build_state(None);
        let mut s = FaultStore::new(Box::new(NullStore), state.clone());
        let h = DataHandle::Null { length: 64 };
        let clean = block_on(NullStore.read(&h)).unwrap();
        let rotten = block_on(s.read(&h)).unwrap();
        // same length, exactly one differing byte, checksum broken
        assert_eq!(rotten.len(), 64);
        let (a, b) = (clean.to_vec(), rotten.to_vec());
        assert_eq!(a.iter().zip(&b).filter(|(x, y)| x != y).count(), 1);
        assert_ne!(clean.content_checksum(), rotten.content_checksum());
        assert_eq!(state.borrow().corruptions(), 1);
        // the verified read path catches what the plain read cannot
        let checks = [crate::fdb::scrub::RangeCheck::whole(64, clean.content_checksum())];
        let err = block_on(s.read_verified(&h, &checks)).unwrap_err();
        assert!(matches!(err, FdbError::Corrupt { .. }), "got {err}");
    }

    #[test]
    fn write_corruption_is_silent_and_scrub_probes_beneath_read_rot() {
        // corrupt:write:p1 — the archive succeeds (silent rot)
        let plan =
            FaultPlan::new(11).with_rule(FaultClass::Write, FaultAction::Corrupt { prob: 1.0 });
        let state = plan.build_state(None);
        let mut s = FaultStore::new(Box::new(NullStore), state.clone());
        assert!(archive_one(&mut s, 32).is_ok());
        assert_eq!(state.borrow().corruptions(), 1);
        // corrupt:read rot is wire damage: scrub_field forwards to the
        // inner store and must see the on-disk bytes as healthy
        let plan =
            FaultPlan::new(11).with_rule(FaultClass::Read, FaultAction::Corrupt { prob: 1.0 });
        let mut s = FaultStore::new(Box::new(NullStore), plan.build_state(None));
        let h = DataHandle::Null { length: 64 };
        let disk = block_on(NullStore.read(&h)).unwrap();
        let outcome =
            block_on(s.scrub_field(&h, 64, Some(disk.content_checksum()), false)).unwrap();
        assert!(outcome.healthy(), "scrub saw wire rot as disk damage: {outcome:?}");
    }

    #[test]
    fn no_rules_is_a_transparent_wrapper() {
        let mut s = fault_store(FaultPlan::new(0));
        for _ in 0..32 {
            assert!(archive_one(&mut s, 16).is_ok());
        }
        let h = DataHandle::Null { length: 16 };
        assert_eq!(block_on(s.read(&h)).unwrap().len(), 16);
    }
}
