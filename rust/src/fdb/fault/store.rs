//! [`FaultStore`]: a [`Store`] wrapper that injects seeded, deterministic
//! faults into its inner store — the data-plane half of the fault
//! harness (see the [`super`] module docs for the spec grammar).

use std::cell::RefCell;
use std::rc::Rc;

use crate::fdb::backend::{LocalBoxFuture, Store, StoreSession};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::FdbError;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

use super::plan::{FaultClass, FaultDecision, FaultState};

/// A fault-injecting Store wrapper. All state (op counters, RNG, the
/// fail-stop flag) lives in the shared [`FaultState`], so sessions
/// minted from this store inherit their parent's fate: a fail-stopped
/// instance takes every session down with it, like a crashed node.
pub struct FaultStore {
    inner: Box<dyn Store>,
    state: Rc<RefCell<FaultState>>,
}

impl FaultStore {
    pub fn new(inner: Box<dyn Store>, state: Rc<RefCell<FaultState>>) -> FaultStore {
        FaultStore { inner, state }
    }

    async fn gate(&self, class: FaultClass, len: u64) -> Result<Option<u64>, FdbError> {
        let decision = self.state.borrow_mut().on_op(class, len);
        match decision {
            FaultDecision::Proceed { delay } => {
                if let (Some(d), Some(sim)) = (delay, self.state.borrow().sim()) {
                    sim.sleep(d).await;
                }
                Ok(None)
            }
            FaultDecision::Fail(e) => Err(e),
            FaultDecision::TornWrite { keep } => Ok(Some(keep)),
        }
    }
}

impl Store for FaultStore {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        Box::pin(async move {
            match self.gate(FaultClass::Write, data.len()).await? {
                None => self.inner.archive(ds, colloc, id, data).await,
                Some(keep) => {
                    // torn write: a prefix of the payload reaches the
                    // inner store, then the operation reports failure —
                    // the caller must treat the field as not archived
                    let prefix = data.slice(0, keep);
                    let _ = self.inner.archive(ds, colloc, id, prefix).await;
                    Err(FdbError::Backend {
                        backend: "fault",
                        detail: format!("torn write: {keep}/{} bytes persisted", data.len()),
                    })
                }
            }
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            self.gate(FaultClass::Flush, 0).await?;
            self.inner.flush().await
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(async move {
            self.gate(FaultClass::Read, 0).await?;
            self.inner.read(handle).await
        })
    }

    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            // one fault-accounted op per handle, matching the inner
            // store's default loop — a mid-batch fault surfaces exactly
            // at the affected range
            let mut out = Vec::with_capacity(handles.len());
            for handle in handles {
                self.gate(FaultClass::Read, 0).await?;
                out.push(self.inner.read(handle).await?);
            }
            Ok(out)
        })
    }

    fn direct_retrieve_enabled(&self) -> bool {
        self.inner.direct_retrieve_enabled()
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        self.inner.retrieve_direct(ds, id)
    }

    fn supports_wipe(&self) -> bool {
        self.inner.supports_wipe()
    }

    fn wipe_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        self.inner.wipe_dataset(ds)
    }

    fn take_lock_time(&self) -> SimTime {
        self.inner.take_lock_time()
    }

    fn session(&mut self) -> Option<Box<dyn StoreSession>> {
        let inner = self.inner.session()?;
        Some(Box::new(FaultStore {
            inner: inner.into_store(),
            state: self.state.clone(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullStore};
    use crate::fdb::fault::plan::{FaultAction, FaultPlan};

    fn fault_store(plan: FaultPlan) -> FaultStore {
        let state = plan.build_state(None);
        FaultStore::new(Box::new(NullStore), state)
    }

    fn archive_one(s: &mut FaultStore, n: u64) -> Result<FieldLocation, FdbError> {
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        block_on(s.archive(&ds, &ds, &id, Bytes::virt(n, 1)))
    }

    #[test]
    fn failstop_after_n_writes_then_everything_fails() {
        let plan =
            FaultPlan::new(3).with_rule(FaultClass::Write, FaultAction::FailStop { after: 2 });
        let mut s = fault_store(plan);
        assert!(archive_one(&mut s, 8).is_ok());
        assert!(archive_one(&mut s, 8).is_ok());
        let err = archive_one(&mut s, 8).unwrap_err();
        assert!(matches!(err, FdbError::Backend { backend: "fault", .. }));
        // dead instance: reads fail too
        let h = DataHandle::Null { length: 8 };
        assert!(block_on(s.read(&h)).is_err());
    }

    #[test]
    fn torn_write_persists_prefix_and_errors() {
        let plan = FaultPlan::new(3).with_rule(FaultClass::Write, FaultAction::Torn { nth: 0 });
        let mut s = fault_store(plan);
        let err = archive_one(&mut s, 100).unwrap_err();
        let FdbError::Backend { detail, .. } = err else {
            panic!("expected backend error")
        };
        assert!(detail.contains("torn write: 50/100"), "{detail}");
        // the next write is clean
        assert!(archive_one(&mut s, 100).is_ok());
    }

    #[test]
    fn sessions_share_the_fate_of_their_parent() {
        let plan =
            FaultPlan::new(3).with_rule(FaultClass::Write, FaultAction::FailStop { after: 1 });
        let mut s = fault_store(plan);
        let mut session = s.session().expect("null store has sessions");
        assert!(archive_one(&mut s, 8).is_ok());
        // the parent's counter tripped the fail-stop: the session dies too
        let ds = Key::new();
        let id = Key::of(&[("step", "2")]);
        assert!(archive_one(&mut s, 8).is_err());
        assert!(block_on(session.archive(&ds, &ds, &id, Bytes::virt(8, 1))).is_err());
    }

    #[test]
    fn read_faults_surface_per_ranged_handle() {
        let plan =
            FaultPlan::new(3).with_rule(FaultClass::Read, FaultAction::FailStop { after: 1 });
        let mut s = fault_store(plan);
        let handles = vec![
            DataHandle::Null { length: 4 },
            DataHandle::Null { length: 4 },
        ];
        // first handle reads, second hits the fail-stop → typed error for
        // the whole batch, never a short result
        let err = block_on(s.read_ranges(&handles)).unwrap_err();
        assert!(matches!(err, FdbError::Backend { backend: "fault", .. }));
    }

    #[test]
    fn no_rules_is_a_transparent_wrapper() {
        let mut s = fault_store(FaultPlan::new(0));
        for _ in 0..32 {
            assert!(archive_one(&mut s, 16).is_ok());
        }
        let h = DataHandle::Null { length: 16 };
        assert_eq!(block_on(s.read(&h)).unwrap().len(), 16);
    }
}
