//! [`FaultCatalogue`]: the metadata-plane half of the fault harness —
//! injects seeded faults into an inner [`Catalogue`]'s archive (`index`
//! class) and flush/close (`index-flush` class) paths. The interesting
//! kill window for crash recovery sits exactly here: a store-side write
//! that succeeded whose index mutation or index flush then dies.

use std::cell::RefCell;
use std::rc::Rc;

use crate::fdb::backend::{Catalogue, LocalBoxFuture};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::request::Request;
use crate::fdb::FdbError;
use crate::sim::time::SimTime;

use super::plan::{FaultClass, FaultDecision, FaultState};
use super::wal::RecoveryStats;

pub struct FaultCatalogue {
    inner: Box<dyn Catalogue>,
    state: Rc<RefCell<FaultState>>,
}

impl FaultCatalogue {
    pub fn new(inner: Box<dyn Catalogue>, state: Rc<RefCell<FaultState>>) -> FaultCatalogue {
        FaultCatalogue { inner, state }
    }

    async fn gate(&self, class: FaultClass) -> Result<(), FdbError> {
        let decision = self.state.borrow_mut().on_op(class, 0);
        match decision {
            FaultDecision::Proceed { delay, .. } => {
                if let (Some(d), Some(sim)) = (delay, self.state.borrow().sim()) {
                    sim.sleep(d).await;
                }
                Ok(())
            }
            FaultDecision::Fail(e) => Err(e),
            // torn writes are a data-plane concept; treat as plain failure
            FaultDecision::TornWrite { .. } => Err(FdbError::Backend {
                backend: "fault",
                detail: "torn fault on a catalogue op".into(),
            }),
        }
    }
}

impl Catalogue for FaultCatalogue {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
        loc: &'a FieldLocation,
    ) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            self.gate(FaultClass::Index).await?;
            self.inner.archive(ds, colloc, elem, id, loc).await
        })
    }

    fn forget<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        Box::pin(async move {
            // an index mutation like archive: fsck ghost-drops contend
            // with the same injected index faults
            self.gate(FaultClass::Index).await?;
            self.inner.forget(ds, colloc, elem, id).await
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            self.gate(FaultClass::IndexFlush).await?;
            self.inner.flush().await
        })
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::CatalogueSession>> {
        // fate-sharing: the session wraps the inner's session with the
        // SAME shared fault state — a fail-stopped instance stays dead
        // through every client it minted (reads are ungated today, but a
        // session must never outlive its parent's fault schedule)
        let inner = self.inner.session()?.into_catalogue();
        Some(Box::new(FaultCatalogue::new(inner, self.state.clone())))
    }

    fn begin_archive_group(&mut self) {
        // group hooks pass through ungated: the gate sits on the archive
        // ops themselves, and adding a hidden gated op here would shift
        // every seeded fault schedule by one op per batch
        self.inner.begin_archive_group();
    }

    fn end_archive_group<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        self.inner.end_archive_group()
    }

    fn close<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            self.gate(FaultClass::IndexFlush).await?;
            self.inner.close().await
        })
    }

    fn recover_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> LocalBoxFuture<'a, Result<RecoveryStats, FdbError>> {
        // recovery itself is not fault-gated: the recovering process is
        // a fresh one, not the crashed instance this plan modelled
        self.inner.recover_dataset(ds)
    }

    fn retrieve<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        self.inner.retrieve(ds, colloc, elem, id)
    }

    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> LocalBoxFuture<'a, Vec<String>> {
        self.inner.axis(ds, colloc, dim)
    }

    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> LocalBoxFuture<'a, Vec<(Key, FieldLocation)>> {
        self.inner.list(ds, request)
    }

    fn invalidate_preload(&mut self, ds: &Key) {
        self.inner.invalidate_preload(ds);
    }

    fn deregister_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, ()> {
        self.inner.deregister_dataset(ds)
    }

    fn take_lock_time(&self) -> SimTime {
        self.inner.take_lock_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullCatalogue};
    use crate::fdb::fault::plan::{FaultAction, FaultPlan};

    fn fault_cat(plan: FaultPlan) -> FaultCatalogue {
        FaultCatalogue::new(Box::new(NullCatalogue::new()), plan.build_state(None))
    }

    fn loc() -> FieldLocation {
        FieldLocation::Null { length: 8 }
    }

    #[test]
    fn index_failstop_makes_archive_a_typed_error() {
        let plan =
            FaultPlan::new(5).with_rule(FaultClass::Index, FaultAction::FailStop { after: 2 });
        let mut cat = fault_cat(plan);
        let ds = Key::new();
        for step in 1..=2u32 {
            let id = Key::of(&[("step", &step.to_string())]);
            block_on(cat.archive(&ds, &ds, &id, &id, &loc())).unwrap();
        }
        let id = Key::of(&[("step", "3")]);
        let err = block_on(cat.archive(&ds, &ds, &id, &id, &loc())).unwrap_err();
        assert!(matches!(err, FdbError::Backend { backend: "fault", .. }));
        // fail-stop is global: the index flush dies too
        assert!(block_on(cat.flush()).is_err());
    }

    #[test]
    fn index_flush_fault_leaves_archive_alive() {
        // the crash-recovery kill window: archives succeed, flush dies
        let plan =
            FaultPlan::new(5).with_rule(FaultClass::IndexFlush, FaultAction::FailStop { after: 0 });
        let mut cat = fault_cat(plan);
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        block_on(cat.archive(&ds, &ds, &id, &id, &loc())).unwrap();
        assert!(block_on(cat.flush()).is_err());
    }

    #[test]
    fn reads_pass_through_untouched() {
        let plan =
            FaultPlan::new(5).with_rule(FaultClass::Index, FaultAction::FailStop { after: 0 });
        let mut cat = fault_cat(plan);
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        // archive dies, but lookups against the (empty) inner work
        assert!(block_on(cat.archive(&ds, &ds, &id, &id, &loc())).is_err());
        assert!(block_on(cat.retrieve(&ds, &ds, &id, &id)).is_none());
        assert!(block_on(cat.list(&ds, &Request::parse("").unwrap())).is_empty());
    }
}
