//! The FDB backend abstraction (thesis §2.7): two object-safe traits —
//! [`Store`] for field data, [`Catalogue`] for the index network — that
//! every backend pair (POSIX/Lustre, DAOS, Ceph/RADOS, S3, Null)
//! implements. `Fdb` dispatches through `Box<dyn Store>` /
//! `Box<dyn Catalogue>`, so adding a backend (tiered cache, sharded
//! catalogue, replicated store) is one new trait impl instead of a
//! cross-cutting edit of every FDB method.
//!
//! On top of the single-client surface, [`Store::session`] mints
//! independent per-request **client sessions** ([`StoreSession`]): each
//! session owns its own backend client handle (a fresh Lustre mount
//! identity, DAOS event-queue equivalent, RADOS/S3 client instance), so
//! the I/O-depth engine in [`crate::fdb::Fdb`] can keep N reads/writes
//! in flight instead of serializing on the one `&mut` Store — the
//! client-side asynchrony the DAOS papers identify as the real source
//! of object-store throughput (arXiv:2311.18714, arXiv:2409.18682).
//!
//! The simulator is single-threaded, so the async methods return
//! [`LocalBoxFuture`]s with no `Send` bound.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;

use super::datahandle::DataHandle;
use super::fault::wal::RecoveryStats;
use super::key::Key;
use super::location::FieldLocation;
use super::request::Request;
use super::scrub::{verify_ranges, RangeCheck, ScrubOutcome};
use super::FdbError;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

/// A non-`Send` boxed future (the DES executor is single-threaded).
pub type LocalBoxFuture<'a, T> = Pin<Box<dyn Future<Output = T> + 'a>>;

/// Box an immediately-ready value (default trait-method bodies).
pub fn ready<'a, T: 'a>(value: T) -> LocalBoxFuture<'a, T> {
    Box::pin(std::future::ready(value))
}

/// Drive a boxed future to completion on a no-op waker — shared test
/// helper for backends whose futures never actually suspend (the Null
/// pair and wrappers over it).
#[cfg(test)]
pub(crate) fn block_on_ready<T>(mut fut: LocalBoxFuture<'_, T>) -> T {
    use std::task::{Context, Poll, RawWaker, RawWakerVTable, Waker};
    fn clone(_: *const ()) -> RawWaker {
        noop_raw()
    }
    fn noop(_: *const ()) {}
    static VTABLE: RawWakerVTable = RawWakerVTable::new(clone, noop, noop, noop);
    fn noop_raw() -> RawWaker {
        RawWaker::new(std::ptr::null(), &VTABLE)
    }
    let waker = unsafe { Waker::from_raw(noop_raw()) };
    let mut cx = Context::from_waker(&waker);
    match fut.as_mut().poll(&mut cx) {
        Poll::Ready(v) => v,
        Poll::Pending => panic!("never-suspending backend future suspended"),
    }
}

/// The data plane: where field bytes live (thesis §2.7.1 "Store").
pub trait Store {
    /// Short backend tag used in errors and diagnostics.
    fn name(&self) -> &'static str;

    /// Write one field; returns its location descriptor. `id` is the
    /// full identifier (backends with identifier-derived placement, like
    /// hash-OID DAOS, use it; others key placement off `ds`/`colloc`).
    /// Backend failures (mkdir on a non-directory, a stale multipart
    /// upload, ...) surface as [`FdbError::Backend`], never a panic.
    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>>;

    /// Make prior archives durable (no-op for immediately-durable
    /// backends). Fallible: a tiered store spills its absorbed writes to
    /// the backing tier here, and that spill can fail like any archive.
    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        ready(Ok(()))
    }

    /// Read the bytes a (possibly merged) handle refers to. Handles from
    /// another backend yield [`FdbError::BackendMismatch`].
    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>>;

    /// Vectored read: a batch of (possibly merged) ranged handles in one
    /// backend call, returning one `Bytes` per handle in input order —
    /// how the read planner ([`crate::fdb::plan`]) issues its coalesced
    /// ranges. The default is a loop of [`Store::read`], so backends
    /// without a vectored path (Null, S3, third-party impls) keep
    /// working; POSIX/Lustre and RADOS override it to resolve each
    /// container (file descriptor, pool handle) once per batch.
    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            let mut out = Vec::with_capacity(handles.len());
            for handle in handles {
                out.push(self.read(handle).await?);
            }
            Ok(out)
        })
    }

    /// [`Store::read`] plus end-to-end integrity: each [`RangeCheck`]
    /// names a slice of the returned buffer and its expected content
    /// checksum; a mismatch surfaces as [`FdbError::Corrupt`]. An empty
    /// `checks` slice verifies nothing (legacy entries), so callers can
    /// route every read through this method. The default reads then
    /// verifies; [`crate::fdb::wrappers::ReplicatedStore`] overrides it
    /// to verify *per replica* and fail over to the next copy.
    fn read_verified<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        checks: &'a [RangeCheck],
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(async move {
            let buf = self.read(handle).await?;
            verify_ranges(&buf, checks)?;
            Ok(buf)
        })
    }

    /// [`Store::read_ranges`] with per-handle integrity checks —
    /// `checks[i]` verifies slices of buffer `i` (coalesced reads carry
    /// one [`RangeCheck`] per checksummed member field). `checks` may be
    /// shorter than `handles`; unmatched buffers go unverified.
    fn read_ranges_verified<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
        checks: &'a [Vec<RangeCheck>],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            let bufs = self.read_ranges(handles).await?;
            for (buf, cks) in bufs.iter().zip(checks) {
                verify_ranges(buf, cks)?;
            }
            Ok(bufs)
        })
    }

    /// Rewrite the bytes a handle refers to from verified data (scrub
    /// repair of a rotten copy). Returns whether the store performed the
    /// rewrite; the default cannot (sink and immutable backends).
    fn repair<'a>(
        &'a mut self,
        _handle: &'a DataHandle,
        _data: Bytes,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        ready(Ok(false))
    }

    /// Scrub one field: probe every physical copy the store keeps for
    /// existence, length, and (when `ck` is carried) content checksum;
    /// with `do_repair`, rewrite damaged copies from a verified one.
    /// The default probes the single copy a plain backend keeps.
    fn scrub_field<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        expect_len: u64,
        ck: Option<u64>,
        _do_repair: bool,
    ) -> LocalBoxFuture<'a, Result<ScrubOutcome, FdbError>> {
        Box::pin(async move {
            let mut out = ScrubOutcome {
                copies: 1,
                ..Default::default()
            };
            match self.read(handle).await {
                Err(_) => out.missing = 1,
                Ok(buf) => {
                    let bad_len = buf.len() != expect_len;
                    let bad_ck = ck.is_some_and(|ck| buf.content_checksum() != ck);
                    if bad_len || bad_ck {
                        out.corrupt = 1;
                    }
                }
            }
            Ok(out)
        })
    }

    /// Enumerate a dataset's physical containers as `(container URI,
    /// length)` pairs — the store side of orphan detection (objects no
    /// catalogue entry references). `None` (the default) means this
    /// store cannot enumerate and orphan scanning is skipped for it.
    fn scrub_inventory<'a>(
        &'a mut self,
        _ds: &'a Key,
    ) -> LocalBoxFuture<'a, Option<Vec<(String, u64)>>> {
        ready(None)
    }

    /// Move an unreferenced object out of the data path (fsck orphan
    /// repair) — e.g. POSIX renames the data file aside. Returns whether
    /// anything was quarantined; the default cannot.
    fn quarantine_object<'a>(
        &'a mut self,
        _ds: &'a Key,
        _container: &'a str,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        ready(Ok(false))
    }

    /// Whether this Store can resolve fully-specified identifiers
    /// without the Catalogue (the DAOS hash-OID fast path, §3.1.2).
    fn direct_retrieve_enabled(&self) -> bool {
        false
    }

    /// Catalogue-bypassing lookup for a fully-specified identifier.
    /// Only called when [`Store::direct_retrieve_enabled`] is true.
    fn retrieve_direct<'a>(
        &'a mut self,
        _ds: &'a Key,
        _id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        ready(None)
    }

    /// Whether this Store implements dataset wipe. When false,
    /// `Fdb::wipe` is a strict no-op (the Catalogue keeps its entries —
    /// deregistering an index whose data survives would orphan it).
    fn supports_wipe(&self) -> bool {
        false
    }

    /// Remove every object of a dataset (fdb-wipe). Returns whether
    /// anything was removed. Only called when [`Store::supports_wipe`]
    /// is true.
    fn wipe_dataset<'a>(&'a mut self, _ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        ready(false)
    }

    /// Drain distributed-lock time accumulated by this Store's client
    /// (Lustre DLM accounting; zero elsewhere).
    fn take_lock_time(&self) -> SimTime {
        SimTime::ZERO
    }

    /// Mint an independent per-request client session: a Store instance
    /// over the *same* deployed backend but with its own client handle,
    /// so its operations can be in flight concurrently with the parent's
    /// and with other sessions'. `None` means the backend has no session
    /// support and callers must stay on the serial path (the default).
    fn session(&mut self) -> Option<Box<dyn StoreSession>> {
        None
    }
}

/// A per-request client session minted by [`Store::session`]. Sessions
/// are full [`Store`]s (they carry `archive`/`read`/`flush` and the
/// DAOS direct-retrieve fast path), plus [`StoreSession::into_store`]
/// so wrapper backends can assemble sessions of their inner stores into
/// a wrapper-of-sessions. The blanket impl makes every `'static` Store
/// a session; backends only decide *how to construct* one (usually: a
/// fresh instance over a forked client).
pub trait StoreSession: Store {
    /// Recover the plain `Store` view (wrappers hold inner sessions as
    /// `Box<dyn Store>` fields).
    fn into_store(self: Box<Self>) -> Box<dyn Store>;
}

impl<S: Store + 'static> StoreSession for S {
    fn into_store(self: Box<Self>) -> Box<dyn Store> {
        self
    }
}

/// The metadata plane: the index network mapping identifiers to
/// locations (thesis §2.7.1 "Catalogue").
pub trait Catalogue {
    /// Short backend tag used in errors and diagnostics.
    fn name(&self) -> &'static str;

    /// Index one archived field. `elem` is the schema's element sub-key;
    /// `id` the full identifier (kept whole for catalogues that index by
    /// complete keys, like the in-memory Null catalogue). Backend
    /// failures (mkdir on a non-directory during dataset init, index
    /// file creation, ...) surface as [`FdbError::Backend`], never a
    /// panic — the store-side twin of this guarantee landed first, this
    /// is the catalogue-side ripple.
    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
        loc: &'a FieldLocation,
    ) -> LocalBoxFuture<'a, Result<(), FdbError>>;

    /// Persist partial indexes (POSIX); no-op on immediately-persistent
    /// backends. Fallible: the POSIX index/sub-TOC appends hit the
    /// filesystem and surface as [`FdbError::Backend`] — an index flush
    /// that silently swallowed a write failure would publish entries
    /// that never became durable.
    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        ready(Ok(()))
    }

    /// End-of-producer-lifetime persistence (POSIX full indexes +
    /// masking); no-op elsewhere. Fallible like [`Catalogue::flush`].
    fn close<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        ready(Ok(()))
    }

    /// Crash recovery: replay any write-ahead log a died producer left
    /// for the dataset, re-applying its lost (unflushed) index entries
    /// to this catalogue's live state. The caller flushes afterwards to
    /// persist them. Default: nothing to recover (backends whose archive
    /// is immediately persistent have no WAL).
    fn recover_dataset<'a>(
        &'a mut self,
        _ds: &'a Key,
    ) -> LocalBoxFuture<'a, Result<RecoveryStats, FdbError>> {
        ready(Ok(RecoveryStats::default()))
    }

    /// Look up one fully-specified identifier.
    fn retrieve<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>>;

    /// Indexed values of one element dimension.
    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> LocalBoxFuture<'a, Vec<String>>;

    /// All indexed (identifier, location) pairs matching a request.
    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> LocalBoxFuture<'a, Vec<(Key, FieldLocation)>>;

    /// Remove one index entry (fsck ghost repair: the entry points at
    /// data that no longer exists). Returns whether the entry was
    /// removed or masked; the default catalogue cannot forget
    /// (append-only formats mask via tombstones instead — see the POSIX
    /// impl). Callers must treat `Ok(false)` as "ghost left in place".
    fn forget<'a>(
        &'a mut self,
        _ds: &'a Key,
        _colloc: &'a Key,
        _elem: &'a Key,
        _id: &'a Key,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        ready(Ok(false))
    }

    /// Drop reader-side caches so later flushes become visible.
    fn invalidate_preload(&mut self, _ds: &Key) {}

    /// Remove a dataset's catalogue registration after a Store wipe.
    fn deregister_dataset<'a>(&'a mut self, _ds: &'a Key) -> LocalBoxFuture<'a, ()> {
        ready(())
    }

    /// Drain distributed-lock time accumulated by this Catalogue's
    /// client (Lustre DLM accounting; zero elsewhere).
    fn take_lock_time(&self) -> SimTime {
        SimTime::ZERO
    }

    /// Mint an independent per-request client session — the catalogue
    /// twin of [`Store::session`]. A session is a read-side view over
    /// the *same* deployed index (same published TOCs / KV namespace /
    /// shared map) with its own client handle, so batched lookups can
    /// run at I/O depth instead of serializing on the one `&mut`
    /// Catalogue. `None` (the default) keeps callers on the serial
    /// lookup path. Sessions only need the read surface (`retrieve`);
    /// mutations stay on the parent.
    fn session(&mut self) -> Option<Box<dyn CatalogueSession>> {
        None
    }

    /// Begin a write group: until [`Catalogue::end_archive_group`],
    /// per-archive durability barriers (WAL fdatasyncs) may be deferred
    /// and batched — group commit. Archives inside a group are NOT
    /// individually durable; callers must `end_archive_group` before
    /// reporting the batch archived. Default: no-op (backends without a
    /// WAL have nothing to defer).
    fn begin_archive_group(&mut self) {}

    /// End a write group: flush every durability barrier deferred since
    /// [`Catalogue::begin_archive_group`] (one fdatasync per dirty WAL
    /// instead of one per intent). Must be awaited on every exit path
    /// of the batch, including error returns.
    fn end_archive_group<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        ready(Ok(()))
    }
}

/// A per-request client session minted by [`Catalogue::session`].
/// Sessions are full [`Catalogue`]s (the engine only calls the read
/// surface), plus [`CatalogueSession::into_catalogue`] so wrapper
/// backends can assemble sessions of their inner catalogues into a
/// wrapper-of-sessions. The blanket impl makes every `'static`
/// Catalogue a session; backends only decide *how to construct* one.
pub trait CatalogueSession: Catalogue {
    /// Recover the plain `Catalogue` view (wrappers hold inner sessions
    /// as `Box<dyn Catalogue>` fields).
    fn into_catalogue(self: Box<Self>) -> Box<dyn Catalogue>;
}

impl<C: Catalogue + 'static> CatalogueSession for C {
    fn into_catalogue(self: Box<Self>) -> Box<dyn Catalogue> {
        self
    }
}

/// Zero-cost data sink — client-overhead experiments (Fig 4.30).
#[derive(Default)]
pub struct NullStore;

impl Store for NullStore {
    fn name(&self) -> &'static str {
        "null"
    }

    fn archive<'a>(
        &'a mut self,
        _ds: &'a Key,
        _colloc: &'a Key,
        _id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        ready(Ok(FieldLocation::Null { length: data.len() }))
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        ready(match handle {
            DataHandle::Null { length } => Ok(Bytes::virt(*length, 0)),
            other => Err(FdbError::BackendMismatch {
                store: "null",
                handle: other.backend_name(),
            }),
        })
    }

    fn session(&mut self) -> Option<Box<dyn StoreSession>> {
        // the zero-cost sink is stateless: a fresh instance is a session
        Some(Box::new(NullStore))
    }
}

/// In-memory catalogue (no persistence, process-local visibility) —
/// pairs with the S3 and Null stores. Keys are stored as [`Key`] values,
/// not canonical strings, so `list()` cannot lose entries to lossy
/// canonical→parse round-trips. The map sits behind an `Rc<RefCell<…>>`
/// so [`Catalogue::session`] clones share the live index (a session
/// over a private copy would answer lookups from an empty map); safe on
/// the single-threaded DES executor because no borrow spans an await.
#[derive(Clone, Default)]
pub struct NullCatalogue {
    map: Rc<RefCell<BTreeMap<Key, FieldLocation>>>,
}

impl NullCatalogue {
    pub fn new() -> NullCatalogue {
        NullCatalogue::default()
    }

    pub fn len(&self) -> usize {
        self.map.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.borrow().is_empty()
    }

    // Synchronous core ops, shared by the `Catalogue` impls of both
    // `NullCatalogue` and `SharedNullCatalogue` (the latter must not
    // hold its interior borrow across an await).

    fn insert(&mut self, id: &Key, loc: &FieldLocation) {
        self.map.borrow_mut().insert(id.clone(), loc.clone());
    }

    fn lookup(&self, id: &Key) -> Option<FieldLocation> {
        self.map.borrow().get(id).cloned()
    }

    fn axis_values(&self, ds: &Key, colloc: &Key, dim: &str) -> Vec<String> {
        let vals: std::collections::BTreeSet<String> = self
            .map
            .borrow()
            .keys()
            .filter(|k| ds.matches(k) && colloc.matches(k))
            .filter_map(|k| k.get(dim).map(String::from))
            .collect();
        vals.into_iter().collect()
    }

    fn entries(&self, ds: &Key, request: &Request) -> Vec<(Key, FieldLocation)> {
        self.map
            .borrow()
            .iter()
            .filter(|(k, _)| ds.matches(k) && request.matches(k))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    fn remove_dataset(&mut self, ds: &Key) {
        self.map.borrow_mut().retain(|k, _| !ds.matches(k));
    }

    fn remove(&mut self, id: &Key) -> bool {
        self.map.borrow_mut().remove(id).is_some()
    }
}

impl Catalogue for NullCatalogue {
    fn name(&self) -> &'static str {
        "null"
    }

    fn archive<'a>(
        &'a mut self,
        _ds: &'a Key,
        _colloc: &'a Key,
        _elem: &'a Key,
        id: &'a Key,
        loc: &'a FieldLocation,
    ) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        self.insert(id, loc);
        ready(Ok(()))
    }

    fn retrieve<'a>(
        &'a mut self,
        _ds: &'a Key,
        _colloc: &'a Key,
        _elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        ready(self.lookup(id))
    }

    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> LocalBoxFuture<'a, Vec<String>> {
        ready(self.axis_values(ds, colloc, dim))
    }

    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> LocalBoxFuture<'a, Vec<(Key, FieldLocation)>> {
        ready(self.entries(ds, request))
    }

    fn deregister_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, ()> {
        self.remove_dataset(ds);
        ready(())
    }

    fn forget<'a>(
        &'a mut self,
        _ds: &'a Key,
        _colloc: &'a Key,
        _elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        let removed = self.remove(id);
        ready(Ok(removed))
    }

    fn session(&mut self) -> Option<Box<dyn CatalogueSession>> {
        // clones share the live map: session lookups see every insert
        Some(Box::new(self.clone()))
    }
}

/// A [`NullCatalogue`] shared by every FDB instance cloned from the same
/// handle — cross-process index visibility for Null deployments (the
/// bare catalogue is process-local, so a reader process would see an
/// empty index). Safe to share on the single-threaded DES executor: all
/// ops delegate synchronously to the inner map, so the interior borrow
/// never spans an await point.
#[derive(Clone, Default)]
pub struct SharedNullCatalogue {
    inner: std::rc::Rc<std::cell::RefCell<NullCatalogue>>,
}

impl SharedNullCatalogue {
    pub fn new() -> SharedNullCatalogue {
        SharedNullCatalogue::default()
    }

    pub fn len(&self) -> usize {
        self.inner.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.borrow().is_empty()
    }
}

impl Catalogue for SharedNullCatalogue {
    fn name(&self) -> &'static str {
        "null"
    }

    fn archive<'a>(
        &'a mut self,
        _ds: &'a Key,
        _colloc: &'a Key,
        _elem: &'a Key,
        id: &'a Key,
        loc: &'a FieldLocation,
    ) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        self.inner.borrow_mut().insert(id, loc);
        ready(Ok(()))
    }

    fn retrieve<'a>(
        &'a mut self,
        _ds: &'a Key,
        _colloc: &'a Key,
        _elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        ready(self.inner.borrow().lookup(id))
    }

    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> LocalBoxFuture<'a, Vec<String>> {
        ready(self.inner.borrow().axis_values(ds, colloc, dim))
    }

    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> LocalBoxFuture<'a, Vec<(Key, FieldLocation)>> {
        ready(self.inner.borrow().entries(ds, request))
    }

    fn deregister_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, ()> {
        self.inner.borrow_mut().remove_dataset(ds);
        ready(())
    }

    fn forget<'a>(
        &'a mut self,
        _ds: &'a Key,
        _colloc: &'a Key,
        _elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        let removed = self.inner.borrow_mut().remove(id);
        ready(Ok(removed))
    }

    fn session(&mut self) -> Option<Box<dyn CatalogueSession>> {
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::block_on_ready as block_on;

    fn loc(n: u64) -> FieldLocation {
        FieldLocation::Null { length: n }
    }

    #[test]
    fn null_catalogue_stores_keys_not_strings() {
        // a value containing '=' and ',' breaks canonical→parse
        // round-trips; Key-typed storage must survive it anyway
        let mut cat = NullCatalogue::new();
        let id = Key::new().with("expr", "a=b,c").with("step", "1");
        let ds = Key::new();
        let colloc = Key::new();
        block_on(cat.archive(&ds, &colloc, &id, &id, &loc(7))).unwrap();
        assert_eq!(cat.len(), 1);
        let listed = block_on(cat.list(&ds, &Request::parse("").unwrap()));
        assert_eq!(listed.len(), 1, "lossy round-trip must not drop keys");
        assert_eq!(listed[0].0, id);
        let got = block_on(cat.retrieve(&ds, &colloc, &id, &id));
        assert_eq!(got, Some(loc(7)));
    }

    #[test]
    fn null_catalogue_axis_and_filters() {
        let mut cat = NullCatalogue::new();
        let ds = Key::of(&[("class", "od")]);
        let colloc = Key::new();
        for step in ["1", "2", "2"] {
            let id = Key::of(&[("class", "od"), ("step", step)]).with("n", step);
            block_on(cat.archive(&ds, &colloc, &id, &id, &loc(1))).unwrap();
        }
        let axis = block_on(cat.axis(&ds, &colloc, "step"));
        assert_eq!(axis, vec!["1".to_string(), "2".to_string()]);
        // a request filter applies
        let req = Request::parse("step=1").unwrap();
        assert_eq!(block_on(cat.list(&ds, &req)).len(), 1);
        // deregister drops the dataset's keys
        block_on(cat.deregister_dataset(&ds));
        assert!(cat.is_empty());
    }

    #[test]
    fn null_store_mismatched_handle_is_typed_error() {
        let mut store = NullStore;
        let handle = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        let err = block_on(store.read(&handle)).unwrap_err();
        assert_eq!(
            err,
            FdbError::BackendMismatch {
                store: "null",
                handle: "posix",
            }
        );
    }

    #[test]
    fn null_roundtrip_through_traits() {
        let mut store = NullStore;
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        let l = block_on(store.archive(&ds, &ds, &id, Bytes::virt(64, 1))).unwrap();
        assert_eq!(l.length(), 64);
        let h = DataHandle::from_location(&l);
        let bytes = block_on(store.read(&h)).unwrap();
        assert_eq!(bytes.len(), 64);
    }

    #[test]
    fn default_read_verified_catches_mismatch_and_passes_clean() {
        let mut store = NullStore;
        let h = DataHandle::Null { length: 64 };
        // Null reads regenerate virt(len, 0): its checksum passes
        let good = Bytes::virt(64, 0).content_checksum();
        let checks = [super::RangeCheck::whole(64, good)];
        assert_eq!(block_on(store.read_verified(&h, &checks)).unwrap().len(), 64);
        // empty checks = legacy entry = no verification
        assert!(block_on(store.read_verified(&h, &[])).is_ok());
        // a wrong expected checksum is typed corruption
        let bad = [super::RangeCheck::whole(64, good ^ 1)];
        let err = block_on(store.read_verified(&h, &bad)).unwrap_err();
        assert!(matches!(err, FdbError::Corrupt { .. }), "got {err}");
    }

    #[test]
    fn default_scrub_field_classifies_copies() {
        let mut store = NullStore;
        let h = DataHandle::Null { length: 64 };
        let good = Bytes::virt(64, 0).content_checksum();
        let out = block_on(store.scrub_field(&h, 64, Some(good), false)).unwrap();
        assert!(out.healthy(), "{out:?}");
        // wrong checksum → corrupt copy
        let out = block_on(store.scrub_field(&h, 64, Some(good ^ 1), false)).unwrap();
        assert_eq!((out.copies, out.corrupt), (1, 1));
        // wrong length → corrupt copy even without a checksum
        let out = block_on(store.scrub_field(&h, 65, None, false)).unwrap();
        assert_eq!(out.corrupt, 1);
        // unreadable handle → missing copy
        let foreign = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        let out = block_on(store.scrub_field(&foreign, 4, None, false)).unwrap();
        assert_eq!(out.missing, 1);
    }

    #[test]
    fn null_catalogue_forget_removes_one_entry() {
        let mut cat = NullCatalogue::new();
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        block_on(cat.archive(&ds, &ds, &id, &id, &loc(7))).unwrap();
        assert!(block_on(cat.forget(&ds, &ds, &id, &id)).unwrap());
        assert!(block_on(cat.retrieve(&ds, &ds, &id, &id)).is_none());
        // forgetting a missing entry reports false, not an error
        assert!(!block_on(cat.forget(&ds, &ds, &id, &id)).unwrap());
    }

    #[test]
    fn null_catalogue_session_shares_the_live_index() {
        // a session minted BEFORE an insert must still see it: sessions
        // are views over the same map, not snapshots
        let mut cat = NullCatalogue::new();
        let mut session = cat.session().expect("null catalogue sessions");
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        block_on(cat.archive(&ds, &ds, &id, &id, &loc(9))).unwrap();
        let got = block_on(session.retrieve(&ds, &ds, &id, &id));
        assert_eq!(got, Some(loc(9)));
        // group hooks default to no-ops on WAL-less catalogues
        cat.begin_archive_group();
        block_on(cat.end_archive_group()).unwrap();
    }

    #[test]
    fn shared_null_catalogue_visible_across_clones() {
        // two "processes" (clones of the shared handle) see one index
        let shared = SharedNullCatalogue::new();
        let mut writer_view = shared.clone();
        let mut reader_view = shared.clone();
        let id = Key::of(&[("class", "od"), ("step", "1")]);
        let ds = Key::new();
        block_on(writer_view.archive(&ds, &ds, &id, &id, &loc(3))).unwrap();
        assert_eq!(shared.len(), 1);
        let got = block_on(reader_view.retrieve(&ds, &ds, &id, &id));
        assert_eq!(got, Some(loc(3)));
        block_on(reader_view.deregister_dataset(&ds));
        assert!(shared.is_empty());
    }
}
