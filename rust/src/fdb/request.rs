//! Retrieval requests: identifiers with multi-value expressions
//! (`step=1/2/3`, or `step=*` to be expanded from the axes) — thesis
//! §2.7.1 `axis()`.

use std::collections::BTreeMap;

use super::key::Key;

/// A (possibly multi-valued, possibly partial) request.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Request {
    /// dim → candidate values; a `*` wildcard is an empty vec
    pub dims: BTreeMap<String, Vec<String>>,
}

impl Request {
    /// Parse `a=1,b=2/3,c=*`.
    pub fn parse(s: &str) -> Result<Request, String> {
        let mut dims = BTreeMap::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("bad request component `{part}`"))?;
            let vals: Vec<String> = if v.trim() == "*" {
                Vec::new()
            } else {
                v.split('/').map(|x| x.trim().to_string()).collect()
            };
            dims.insert(k.trim().to_string(), vals);
        }
        Ok(Request { dims })
    }

    pub fn from_key(key: &Key) -> Request {
        Request {
            dims: key
                .0
                .iter()
                .map(|(k, v)| (k.clone(), vec![v.clone()]))
                .collect(),
        }
    }

    /// Wildcard dims that need axis expansion.
    pub fn wildcards(&self) -> Vec<String> {
        self.dims
            .iter()
            .filter(|(_, v)| v.is_empty())
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Substitute axis values for a wildcard dim.
    pub fn bind(&mut self, dim: &str, values: Vec<String>) {
        self.dims.insert(dim.to_string(), values);
    }

    /// Cartesian expansion into fully-specified identifiers.
    /// Wildcards must have been bound first.
    pub fn expand(&self) -> Vec<Key> {
        let mut out = vec![Key::new()];
        for (dim, vals) in &self.dims {
            assert!(
                !vals.is_empty(),
                "unbound wildcard dim `{dim}` — call bind() with axis values first"
            );
            let mut next = Vec::with_capacity(out.len() * vals.len());
            for k in &out {
                for v in vals {
                    next.push(k.clone().with(dim, v.clone()));
                }
            }
            out = next;
        }
        out
    }

    /// The partial key of single-valued dims (used for list() matching).
    pub fn fixed_key(&self) -> Key {
        let mut k = Key::new();
        for (dim, vals) in &self.dims {
            if vals.len() == 1 {
                k.set(dim, vals[0].clone());
            }
        }
        k
    }

    /// Does a full key satisfy this request?
    pub fn matches(&self, key: &Key) -> bool {
        self.dims.iter().all(|(dim, vals)| match key.get(dim) {
            None => false,
            Some(v) => vals.is_empty() || vals.iter().any(|x| x == v),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_multi_and_wildcard() {
        let r = Request::parse("step=1/2,param=v,levelist=*").unwrap();
        assert_eq!(r.dims["step"], vec!["1", "2"]);
        assert_eq!(r.dims["param"], vec!["v"]);
        assert!(r.dims["levelist"].is_empty());
        assert_eq!(r.wildcards(), vec!["levelist"]);
    }

    #[test]
    fn expand_cartesian() {
        let r = Request::parse("a=1/2,b=x/y").unwrap();
        let keys = r.expand();
        assert_eq!(keys.len(), 4);
        let canon: Vec<String> = keys.iter().map(|k| k.canonical()).collect();
        assert!(canon.contains(&"a=1,b=x".to_string()));
        assert!(canon.contains(&"a=2,b=y".to_string()));
    }

    #[test]
    #[should_panic(expected = "unbound wildcard")]
    fn expand_panics_on_unbound_wildcard() {
        Request::parse("a=*").unwrap().expand();
    }

    #[test]
    fn bind_then_expand() {
        let mut r = Request::parse("step=*").unwrap();
        r.bind("step", vec!["1".into(), "2".into(), "3".into()]);
        assert_eq!(r.expand().len(), 3);
    }

    #[test]
    fn matching() {
        let r = Request::parse("step=1/2,param=*").unwrap();
        assert!(r.matches(&Key::of(&[("step", "1"), ("param", "v")])));
        assert!(r.matches(&Key::of(&[("step", "2"), ("param", "t")])));
        assert!(!r.matches(&Key::of(&[("step", "3"), ("param", "v")])));
        assert!(!r.matches(&Key::of(&[("param", "v")])));
    }

    #[test]
    fn from_key_roundtrip() {
        let k = Key::of(&[("a", "1"), ("b", "2")]);
        let r = Request::from_key(&k);
        assert_eq!(r.expand(), vec![k]);
    }
}
