//! The FDB S3 Store (thesis §3.3): bucket per dataset, object per field,
//! blocking PutObject on archive() (durable + visible on return), no-op
//! flush(). No S3 Catalogue exists — S3 lacks atomic append and
//! key-values (the thesis discarded it); pair this Store with a
//! Catalogue from another backend.

use std::collections::HashSet;
use std::rc::Rc;

use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::FdbError;
use crate::s3::{MemS3, S3Api};
use crate::util::content::Bytes;

/// Typed backend error for a failed S3 call (replaces the former
/// `expect`/`unwrap` sites on the archive path).
fn s3_err(op: &str, detail: impl std::fmt::Display) -> FdbError {
    FdbError::Backend {
        backend: "s3",
        detail: format!("{op}: {detail}"),
    }
}

pub struct S3Store {
    pub(crate) s3: Rc<MemS3>,
    known_buckets: HashSet<String>,
    counter: u64,
    client_tag: String,
    /// multipart mode: fields for a (dataset, collocation) accumulate as
    /// parts of one S3 object, assembled on flush() (thesis §3.3 —
    /// fewer S3 objects, visibility deferred to flush)
    pub multipart: bool,
    uploads: std::collections::HashMap<(String, String), (String, u64, u32, u64)>,
    /// sessions minted off this store (suffixes their client tags so
    /// object keys stay collision-free)
    session_counter: u64,
}

impl S3Store {
    pub fn new(s3: &Rc<MemS3>, client_tag: &str) -> S3Store {
        S3Store {
            s3: s3.clone(),
            known_buckets: HashSet::new(),
            counter: 0,
            client_tag: client_tag.to_string(),
            multipart: false,
            uploads: std::collections::HashMap::new(),
            session_counter: 0,
        }
    }

    fn bucket_of(ds: &Key) -> String {
        // bucket names: lowercase alnum + dashes
        let mut b = String::from("fdb-");
        for c in ds.canonical().chars() {
            b.push(match c {
                'a'..='z' | '0'..='9' => c,
                'A'..='Z' => c.to_ascii_lowercase(),
                _ => '-',
            });
        }
        b
    }

    /// Store archive(): unique key from (time proxy, host, pid) — here the
    /// client tag + a counter; a blocking PutObject (or an UploadPart in
    /// multipart mode).
    pub async fn archive(
        &mut self,
        ds: &Key,
        colloc: &Key,
        data: Bytes,
    ) -> Result<FieldLocation, FdbError> {
        let bucket = Self::bucket_of(ds);
        if !self.known_buckets.contains(&bucket) {
            self.s3.create_bucket(&bucket).await;
            self.known_buckets.insert(bucket.clone());
        }
        if self.multipart {
            return self.archive_part(ds, colloc, &bucket, data).await;
        }
        self.counter += 1;
        let key = format!("{}-{}", self.client_tag, self.counter);
        let length = data.len();
        self.s3
            .put_object(&bucket, &key, data)
            .await
            .map_err(|e| s3_err("put_object", format!("{bucket}/{key}: {e:?}")))?;
        Ok(FieldLocation::S3Obj {
            bucket,
            key,
            length,
            checksum: None,
        })
    }

    /// One part of the per-(dataset, collocation) multipart object.
    /// Missing upload state and an UploadPart rejected by the server
    /// (e.g. the upload was completed out of order by another actor)
    /// are typed [`FdbError::Backend`]s, not crashes.
    async fn archive_part(
        &mut self,
        ds: &Key,
        colloc: &Key,
        bucket: &str,
        data: Bytes,
    ) -> Result<FieldLocation, FdbError> {
        let key = (ds.canonical(), colloc.canonical());
        if !self.uploads.contains_key(&key) {
            self.counter += 1;
            let obj_key = format!("{}-{}-mp", self.client_tag, self.counter);
            let upload = self
                .s3
                .create_multipart(bucket, &obj_key)
                .await
                .map_err(|e| s3_err("create_multipart", format!("{bucket}/{obj_key}: {e:?}")))?;
            self.uploads.insert(key.clone(), (obj_key, upload, 0, 0));
        }
        let (obj_key, upload, part_no, offset) = {
            let u = self.uploads.get_mut(&key).ok_or_else(|| {
                s3_err(
                    "upload_part",
                    format!("no open multipart upload for ({}, {})", key.0, key.1),
                )
            })?;
            u.2 += 1;
            let off = u.3;
            u.3 += data.len();
            (u.0.clone(), u.1, u.2, off)
        };
        let length = data.len();
        self.s3
            .upload_part(bucket, upload, part_no, data)
            .await
            .map_err(|e| {
                s3_err(
                    "upload_part",
                    format!("{bucket}/{obj_key} part {part_no} (upload {upload}): {e:?}"),
                )
            })?;
        // NOTE: the object is NOT visible until flush() completes the
        // multipart upload — like the POSIX backends' deferred visibility
        Ok(FieldLocation::S3Obj {
            bucket: bucket.to_string(),
            key: format!("{obj_key}?part-offset={offset}&len={length}"),
            length,
            checksum: None,
        })
    }

    /// flush(): no-op for PutObject mode; completes multipart uploads.
    pub async fn flush(&mut self) {
        if !self.multipart {
            return;
        }
        let uploads: Vec<((String, String), (String, u64, u32, u64))> =
            self.uploads.drain().collect();
        for ((ds, _), (obj_key, upload, _, _)) in uploads {
            let bucket = Self::bucket_of(&Key::parse(&ds).unwrap_or_default());
            let _ = self.s3.complete_multipart(&bucket, &obj_key, upload).await;
        }
    }

    pub async fn read_parts(&mut self, bucket: &str, parts: &[(String, u64)]) -> Bytes {
        let mut out = Bytes::new();
        for (key, len) in parts {
            // multipart keys carry a range: `obj?part-offset=N&len=L`
            let (key, range) = match key.split_once("?part-offset=") {
                Some((k, rest)) => {
                    let off: u64 = rest
                        .split('&')
                        .next()
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(0);
                    (k, Some((off, *len)))
                }
                None => (key.as_str(), Some((0, *len))),
            };
            if let Ok(Some(bytes)) = self.s3.get_object(bucket, key, range).await {
                out.append(bytes);
            }
        }
        out
    }
}

impl crate::fdb::backend::Store for S3Store {
    fn name(&self) -> &'static str {
        "s3"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        _id: &'a Key,
        data: Bytes,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<FieldLocation, crate::fdb::FdbError>>
    {
        Box::pin(S3Store::archive(self, ds, colloc, data))
    }

    fn flush<'a>(
        &'a mut self,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<(), crate::fdb::FdbError>> {
        Box::pin(async move {
            S3Store::flush(self).await;
            Ok(())
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a crate::fdb::DataHandle,
    ) -> crate::fdb::backend::LocalBoxFuture<'a, Result<Bytes, crate::fdb::FdbError>> {
        Box::pin(async move {
            match handle {
                crate::fdb::DataHandle::S3 { bucket, parts } => {
                    Ok(self.read_parts(bucket, parts).await)
                }
                other => Err(crate::fdb::FdbError::BackendMismatch {
                    store: "s3",
                    handle: other.backend_name(),
                }),
            }
        })
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::StoreSession>> {
        // an independent HTTP client: a derived tag keeps its object
        // keys (`{tag}-{counter}`) disjoint from the parent's and from
        // other sessions'
        self.session_counter += 1;
        let mut s = S3Store::new(
            &self.s3,
            &format!("{}~s{}", self.client_tag, self.session_counter),
        );
        s.multipart = self.multipart;
        Some(Box::new(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::profiles::{build_cluster, Testbed};
    use crate::sim::exec::Sim;

    #[test]
    fn stale_multipart_upload_is_typed_error_not_panic() {
        // regression for the `uploads.get_mut(&key).unwrap()` /
        // `.expect("upload part")` sites: an upload completed out of
        // order (by another actor) must surface as FdbError::Backend
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::Gcp, 1, 1, false, true));
        let server = cluster.storage_nodes().next().unwrap().clone();
        let cnode = cluster.client_nodes().next().unwrap().clone();
        let s3 = Rc::new(MemS3::new(&sim, &server, &cnode));
        let s3_2 = s3.clone();
        sim.spawn(async move {
            let mut store = S3Store::new(&s3_2, "p0");
            store.multipart = true;
            let ds = Key::of(&[("class", "od"), ("date", "20231201")]);
            let colloc = Key::of(&[("step", "1")]);
            store
                .archive(&ds, &colloc, Bytes::virt(1024, 1))
                .await
                .unwrap();
            // another actor completes the open upload behind our back
            let (obj_key, upload) = {
                let (_, (k, u, _, _)) = store.uploads.iter().next().unwrap();
                (k.clone(), *u)
            };
            let bucket = S3Store::bucket_of(&ds);
            s3_2.complete_multipart(&bucket, &obj_key, upload)
                .await
                .unwrap();
            // the next part for the same collocation targets the stale
            // upload id: a typed error, not a simulator crash
            let err = store
                .archive(&ds, &colloc, Bytes::virt(1024, 2))
                .await
                .unwrap_err();
            match err {
                crate::fdb::FdbError::Backend { backend, detail } => {
                    assert_eq!(backend, "s3");
                    assert!(detail.contains("upload_part"), "{detail}");
                }
                other => panic!("expected typed backend error, got {other}"),
            }
        });
        sim.run();
    }
}
