//! The top-level FDB API (thesis §2.7): `archive() / flush() /
//! retrieve() / list()` plus `axes()` and `close()`, dispatching to a
//! Store and a Catalogue backend, with per-op-class trace accounting
//! that feeds the profiling figures.

use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::request::Request;
use crate::fdb::schema::Schema;
use crate::sim::exec::Sim;
use crate::sim::trace::{OpClass, Trace};

use super::daos::catalogue::DaosCatalogue;
use super::daos::store::DaosStore;
use super::posix::catalogue::PosixCatalogue;
use super::posix::store::PosixStore;
use super::rados::catalogue::RadosCatalogue;
use super::rados::store::RadosStore;
use super::s3::store::S3Store;

/// Store backend dispatch.
pub enum StoreBackend {
    Posix(PosixStore),
    Daos(DaosStore),
    Rados(RadosStore),
    S3(S3Store),
    /// data sink with zero cost — client-overhead experiments (Fig 4.30)
    Null,
}

/// Catalogue backend dispatch.
pub enum CatalogueBackend {
    Posix(PosixCatalogue),
    Daos(DaosCatalogue),
    Rados(RadosCatalogue),
    /// in-memory catalogue (no persistence) — used with Null stores
    Null(std::collections::HashMap<String, FieldLocation>),
}

/// One FDB instance per simulated process (like linking libfdb).
pub struct Fdb {
    pub schema: Schema,
    pub store: StoreBackend,
    pub catalogue: CatalogueBackend,
    pub trace: Trace,
    sim: Sim,
}

impl Fdb {
    pub fn new(
        sim: &Sim,
        schema: Schema,
        store: StoreBackend,
        catalogue: CatalogueBackend,
    ) -> Fdb {
        Fdb {
            schema,
            store,
            catalogue,
            trace: Trace::new(),
            sim: sim.clone(),
        }
    }

    /// Attach a shared trace collector (benchmark profiling).
    pub fn with_trace(mut self, trace: Trace) -> Fdb {
        self.trace = trace;
        self
    }

    /// FDB archive(): Store archive then Catalogue archive (§2.7.1).
    pub async fn archive(
        &mut self,
        id: &Key,
        data: impl Into<crate::util::content::Bytes>,
    ) -> Result<(), super::FdbError> {
        let data: crate::util::content::Bytes = data.into();
        let (ds, colloc, elem) = self.schema.split(id)?;
        let t0 = self.sim.now();
        let dlen = data.len();
        let loc = match &mut self.store {
            StoreBackend::Posix(s) => s.archive(&ds, &colloc, data).await,
            StoreBackend::Daos(s) if s.hash_oids => s.archive_hashed(&ds, id, data).await,
            StoreBackend::Daos(s) => s.archive(&ds, &colloc, data).await,
            StoreBackend::Rados(s) => s.archive(&ds, &colloc, data).await,
            StoreBackend::S3(s) => s.archive(&ds, &colloc, data).await,
            StoreBackend::Null => FieldLocation::Null { length: dlen },
        };
        let lock1 = self.take_lock_time();
        self.trace
            .record(OpClass::DataWrite, self.sim.now() - t0 - lock1);
        let t1 = self.sim.now();
        match &mut self.catalogue {
            CatalogueBackend::Posix(c) => c.archive(&ds, &colloc, &elem, &loc).await,
            CatalogueBackend::Daos(c) => c.archive(&ds, &colloc, &elem, &loc).await,
            CatalogueBackend::Rados(c) => c.archive(&ds, &colloc, &elem, &loc).await,
            CatalogueBackend::Null(map) => {
                map.insert(id.canonical(), loc.clone());
            }
        }
        let lock2 = self.take_lock_time();
        self.trace
            .record(OpClass::IndexWrite, self.sim.now() - t1 - lock2);
        if lock1 + lock2 > crate::sim::time::SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock1 + lock2);
        }
        Ok(())
    }

    /// FDB flush(): Store flush then Catalogue flush (§2.7.1).
    pub async fn flush(&mut self) {
        let t0 = self.sim.now();
        match &mut self.store {
            StoreBackend::Posix(s) => s.flush().await,
            StoreBackend::Daos(s) => s.flush().await,
            StoreBackend::Rados(s) => s.flush().await,
            StoreBackend::S3(s) => s.flush().await,
            StoreBackend::Null => {}
        }
        match &mut self.catalogue {
            CatalogueBackend::Posix(c) => c.flush().await,
            CatalogueBackend::Daos(c) => c.flush().await,
            CatalogueBackend::Rados(c) => c.flush().await,
            CatalogueBackend::Null(_) => {}
        }
        let lock = self.take_lock_time();
        self.trace
            .record(OpClass::Flush, self.sim.now() - t0 - lock);
        if lock > crate::sim::time::SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
    }

    /// Catalogue close() at end of producer lifetime (§2.7.2).
    pub async fn close(&mut self) {
        let t0 = self.sim.now();
        match &mut self.catalogue {
            CatalogueBackend::Posix(c) => c.close().await,
            CatalogueBackend::Daos(c) => c.close().await,
            CatalogueBackend::Rados(c) => c.close().await,
            CatalogueBackend::Null(_) => {}
        }
        let lock = self.take_lock_time();
        self.trace
            .record(OpClass::Flush, self.sim.now() - t0 - lock);
        if lock > crate::sim::time::SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
    }

    /// FDB retrieve() for one fully-specified identifier.
    pub async fn retrieve(&mut self, id: &Key) -> Result<Option<DataHandle>, super::FdbError> {
        let (ds, colloc, elem) = self.schema.split(id)?;
        let t0 = self.sim.now();
        // hash-OID fast path (thesis §3.1.2 optimisation): bypass the
        // Catalogue entirely for fully-specified identifiers
        if let StoreBackend::Daos(s) = &mut self.store {
            if s.hash_oids {
                let loc = s.retrieve_hashed(&ds, id).await;
                self.trace
                    .record(OpClass::IndexRead, self.sim.now() - t0);
                return Ok(loc.map(|l| DataHandle::from_location(&l)));
            }
        }
        let loc = match &mut self.catalogue {
            CatalogueBackend::Posix(c) => c.retrieve(&ds, &colloc, &elem).await,
            CatalogueBackend::Daos(c) => c.retrieve(&ds, &colloc, &elem).await,
            CatalogueBackend::Rados(c) => c.retrieve(&ds, &colloc, &elem).await,
            CatalogueBackend::Null(map) => map.get(&id.canonical()).cloned(),
        };
        let lock = self.take_lock_time();
        self.trace
            .record(OpClass::IndexRead, self.sim.now() - t0 - lock);
        if lock > crate::sim::time::SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        // not finding a field is NOT an error (cache use-case, §2.7.1)
        Ok(loc.map(|l| DataHandle::from_location(&l)))
    }

    /// FDB retrieve() for a (possibly multi-valued) request: expands via
    /// axis(), retrieves every identifier, merges the handles.
    pub async fn retrieve_request(
        &mut self,
        request: &Request,
    ) -> Result<Vec<DataHandle>, super::FdbError> {
        let mut request = request.clone();
        // expand wildcards from the axes
        let wildcards = request.wildcards();
        if !wildcards.is_empty() {
            // need dataset+colloc keys from the fixed part
            let fixed = request.fixed_key();
            let ds = fixed
                .project(&self.schema.dataset)
                .ok_or(super::FdbError::UnderspecifiedRequest)?;
            let colloc = fixed
                .project(&self.schema.collocation)
                .ok_or(super::FdbError::UnderspecifiedRequest)?;
            for dim in wildcards {
                let vals = self.axes(&ds, &colloc, &dim).await;
                request.bind(&dim, vals);
            }
        }
        let mut handles = Vec::new();
        for id in request.expand() {
            if let Some(h) = self.retrieve(&id).await? {
                handles.push(h);
            }
        }
        Ok(DataHandle::merge_all(handles))
    }

    /// Catalogue axis() values for one element dimension.
    pub async fn axes(&mut self, ds: &Key, colloc: &Key, dim: &str) -> Vec<String> {
        let t0 = self.sim.now();
        let out = match &mut self.catalogue {
            CatalogueBackend::Posix(c) => c.axis(ds, colloc, dim).await,
            CatalogueBackend::Daos(c) => c.axis(ds, colloc, dim).await,
            CatalogueBackend::Rados(c) => c.axis(ds, colloc, dim).await,
            CatalogueBackend::Null(_) => Vec::new(),
        };
        self.trace.record(OpClass::IndexRead, self.sim.now() - t0);
        out
    }

    /// FDB list(): all indexed identifiers matching a partial request.
    pub async fn list(&mut self, ds: &Key, request: &Request) -> Vec<(Key, FieldLocation)> {
        let t0 = self.sim.now();
        let out = match &mut self.catalogue {
            CatalogueBackend::Posix(c) => c.list(ds, request).await,
            CatalogueBackend::Daos(c) => c.list(ds, request).await,
            CatalogueBackend::Rados(c) => c.list(ds, request).await,
            CatalogueBackend::Null(map) => map
                .iter()
                .filter_map(|(k, v)| {
                    let key = Key::parse(k).ok()?;
                    request.matches(&key).then(|| (key, v.clone()))
                })
                .collect(),
        };
        let lock = self.take_lock_time();
        self.trace
            .record(OpClass::IndexRead, self.sim.now() - t0 - lock);
        if lock > crate::sim::time::SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        out
    }

    /// Drop reader-side caches so later flushes become visible.
    pub fn invalidate_preload(&mut self, ds: &Key) {
        match &mut self.catalogue {
            CatalogueBackend::Posix(c) => c.invalidate_preload(ds),
            CatalogueBackend::Daos(c) => c.invalidate_preload(ds),
            CatalogueBackend::Rados(c) => c.invalidate_preload(ds),
            CatalogueBackend::Null(_) => {}
        }
    }

    /// Read a handle's bytes through the Store.
    pub async fn read(&mut self, handle: &DataHandle) -> crate::util::content::Bytes {
        let t0 = self.sim.now();
        let out = match (&mut self.store, handle) {
            (StoreBackend::Posix(s), DataHandle::Posix { path, ranges }) => {
                s.read_ranges(path, ranges).await
            }
            (StoreBackend::Daos(s), DataHandle::Daos { cont, parts, .. }) => {
                s.read_parts(cont, parts).await
            }
            (StoreBackend::Rados(s), DataHandle::Rados { pool, ns, parts }) => {
                s.read_parts(pool, ns, parts).await
            }
            (StoreBackend::S3(s), DataHandle::S3 { bucket, parts }) => {
                s.read_parts(bucket, parts).await
            }
            (StoreBackend::Null, DataHandle::Null { length }) => {
                crate::util::content::Bytes::virt(*length, 0)
            }
            _ => panic!("DataHandle backend mismatch"),
        };
        let lock = self.take_lock_time();
        self.trace
            .record(OpClass::DataRead, self.sim.now() - t0 - lock);
        if lock > crate::sim::time::SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        out
    }

    fn take_lock_time(&self) -> crate::sim::time::SimTime {
        match &self.store {
            StoreBackend::Posix(s) => {
                let mut t = s.take_lock_time();
                if let CatalogueBackend::Posix(c) = &self.catalogue {
                    t += c.client.take_lock_time();
                }
                t
            }
            _ => {
                if let CatalogueBackend::Posix(c) = &self.catalogue {
                    c.client.take_lock_time()
                } else {
                    crate::sim::time::SimTime::ZERO
                }
            }
        }
    }
}
