//! The top-level FDB API (thesis §2.7): `archive() / flush() /
//! retrieve() / list()` plus `axes()` and `close()`, and the batched
//! `archive_many()` / `retrieve_many()` paths the DAOS follow-up papers
//! identify as the key to scalable small-object I/O.
//!
//! All backend dispatch is virtual: one `Box<dyn Store>` and one
//! `Box<dyn Catalogue>` (see [`crate::fdb::backend`]), with per-op-class
//! trace and distributed-lock accounting factored into a single shared
//! wrapper ([`Fdb::account`]). Construction goes through
//! [`crate::fdb::builder::FdbBuilder`].
//!
//! The **I/O-depth engine**: with [`IoProfile::depth`] > 1 the batched
//! paths stop serializing on the single Store client and instead drive
//! up to `depth` concurrent operations over per-request
//! [`StoreSession`]s, admitted by a sim-native semaphore (a FIFO
//! [`Resource`] with `depth` servers). Results are re-ordered to input
//! order and per-op-class trace/lock accounting is preserved, so any
//! `depth >= 1` is byte- and order-identical to `depth = 1` — only the
//! virtual time changes. This is the queue-depth client asynchrony of
//! the DAOS interface papers (event queues with N outstanding ops).

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::task::Waker;

use crate::fdb::backend::{Catalogue, Store, StoreSession};
use crate::fdb::builder::IoProfile;
use crate::fdb::datahandle::DataHandle;
use crate::fdb::plan::{PlanStats, ReadPlan};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::request::Request;
use crate::fdb::schema::Schema;
use crate::sim::exec::Sim;
use crate::sim::futures::{boxed, join_all};
use crate::sim::resource::Resource;
use crate::sim::time::SimTime;
use crate::sim::trace::{OpClass, Trace};
use crate::util::content::Bytes;

/// One store-pass result awaiting its catalogue insert:
/// `(identifier, dataset, collocation, element, location)`.
type Indexed = (Key, Key, Key, Key, FieldLocation);

/// One FDB instance per simulated process (like linking libfdb).
pub struct Fdb {
    pub schema: Schema,
    store: Box<dyn Store>,
    catalogue: Box<dyn Catalogue>,
    pub trace: Trace,
    sim: Sim,
    /// queue-depth configuration (depth 1 = the serial legacy paths)
    io: IoProfile,
    /// lazily-minted client sessions, one per admitted in-flight op;
    /// reused across batches so session client state (open files, page
    /// caches) persists like a real client's
    sessions: Vec<Box<dyn StoreSession>>,
    io_inflight: Cell<usize>,
    io_inflight_peak: Cell<usize>,
    /// cumulative read-plan counters (zero until a coalesced retrieve
    /// runs; see [`IoProfile::coalesce_gap`])
    plan_stats: Cell<PlanStats>,
}

impl Fdb {
    /// Wire a Store/Catalogue pair directly. Prefer
    /// [`crate::fdb::builder::FdbBuilder`], which validates configs and
    /// picks matching pairs.
    pub fn new(
        sim: &Sim,
        schema: Schema,
        store: Box<dyn Store>,
        catalogue: Box<dyn Catalogue>,
    ) -> Fdb {
        Fdb {
            schema,
            store,
            catalogue,
            trace: Trace::new(),
            sim: sim.clone(),
            io: IoProfile::default(),
            sessions: Vec::new(),
            io_inflight: Cell::new(0),
            io_inflight_peak: Cell::new(0),
            plan_stats: Cell::new(PlanStats::default()),
        }
    }

    /// Attach a shared trace collector (benchmark profiling).
    pub fn with_trace(mut self, trace: Trace) -> Fdb {
        self.trace = trace;
        self
    }

    /// Set the I/O-depth profile (callers go through
    /// [`crate::fdb::builder::FdbBuilder::io`], which validates it).
    pub fn with_io(mut self, io: IoProfile) -> Fdb {
        self.io = io;
        self
    }

    /// The active I/O profile.
    pub fn io_profile(&self) -> IoProfile {
        self.io
    }

    /// Client sessions minted so far (0 until a batched op runs at
    /// depth > 1).
    pub fn io_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// High-water mark of concurrently in-flight session operations —
    /// never exceeds [`IoProfile::depth`] (the engine's semaphore bound;
    /// asserted by the integration tests).
    pub fn io_inflight_peak(&self) -> usize {
        self.io_inflight_peak.get()
    }

    /// Cumulative read-plan counters across this instance's coalesced
    /// retrieves: requested vs issued ops, merges, hole bytes read
    /// through. All-zero until [`IoProfile::coalesce_gap`] > 0.
    pub fn plan_stats(&self) -> PlanStats {
        self.plan_stats.get()
    }

    /// Backend tags of the wired (store, catalogue) pair.
    pub fn backend_names(&self) -> (&'static str, &'static str) {
        (self.store.name(), self.catalogue.name())
    }

    /// Fill the session pool up to the configured depth. Returns whether
    /// the fan-out engine can run; `false` (depth 1, or a backend
    /// without session support) keeps callers on the serial paths.
    fn ensure_sessions(&mut self) -> bool {
        if self.io.depth <= 1 {
            return false;
        }
        while self.sessions.len() < self.io.depth {
            match self.store.session() {
                Some(s) => self.sessions.push(s),
                None => {
                    self.sessions.clear();
                    return false;
                }
            }
        }
        true
    }

    /// The shared trace/lock wrapper: record the span since `t0` under
    /// `class`, with any distributed-lock time drained from the backends
    /// (and any idle sessions) split out into [`OpClass::Lock`].
    fn account(&mut self, class: OpClass, t0: SimTime) {
        let mut lock = self.store.take_lock_time() + self.catalogue.take_lock_time();
        for s in &self.sessions {
            lock = lock + s.take_lock_time();
        }
        self.trace.record(class, self.sim.now() - t0 - lock);
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
    }

    /// FDB archive(): Store archive then Catalogue archive (§2.7.1).
    pub async fn archive(
        &mut self,
        id: &Key,
        data: impl Into<Bytes>,
    ) -> Result<(), super::FdbError> {
        let data: Bytes = data.into();
        let (ds, colloc, elem) = self.schema.split(id)?;
        let t0 = self.sim.now();
        let loc = self.store.archive(&ds, &colloc, id, data).await;
        self.account(OpClass::DataWrite, t0);
        let loc = loc?;
        let t1 = self.sim.now();
        let indexed = self.catalogue.archive(&ds, &colloc, &elem, id, &loc).await;
        self.account(OpClass::IndexWrite, t1);
        // on a catalogue error the written field stays un-indexed and
        // therefore invisible — same story as a crashed writer
        indexed
    }

    /// Batched archive: all Store writes first, then all Catalogue
    /// inserts — the small-object batching pattern (arXiv:2311.18714).
    /// Identifiers are validated up front; nothing is written on a
    /// validation error. A Store error in the batch stops before the
    /// Catalogue pass: the already-written fields stay un-indexed and
    /// therefore invisible, like a crashed writer's unflushed step.
    ///
    /// At [`IoProfile::depth`] > 1 the Store pass fans out over client
    /// sessions with up to `depth` writes in flight; the Catalogue pass
    /// stays in input order either way, so the index is identical.
    pub async fn archive_many(
        &mut self,
        items: Vec<(Key, Bytes)>,
    ) -> Result<(), super::FdbError> {
        let mut split = Vec::with_capacity(items.len());
        for (id, _) in &items {
            split.push(self.schema.split(id)?);
        }
        let indexed = if self.ensure_sessions() {
            self.archive_fanout(items, split).await?
        } else {
            let t0 = self.sim.now();
            let mut indexed = Vec::with_capacity(items.len());
            let mut failed = None;
            for ((id, data), (ds, colloc, elem)) in items.into_iter().zip(split) {
                match self.store.archive(&ds, &colloc, &id, data).await {
                    Ok(loc) => indexed.push((id, ds, colloc, elem, loc)),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            self.account(OpClass::DataWrite, t0);
            if let Some(e) = failed {
                return Err(e);
            }
            indexed
        };
        let t1 = self.sim.now();
        for (id, ds, colloc, elem, loc) in &indexed {
            let r = self.catalogue.archive(ds, colloc, elem, id, loc).await;
            if let Err(e) = r {
                // later fields of the batch stay un-indexed — invisible,
                // like the store-error story above
                self.account(OpClass::IndexWrite, t1);
                return Err(e);
            }
        }
        self.account(OpClass::IndexWrite, t1);
        Ok(())
    }

    /// The Store half of [`Fdb::archive_many`] at depth > 1: one task
    /// per field, admitted by a `depth`-server semaphore; each admitted
    /// task checks a client session out of the pool, writes through it,
    /// and returns it. Locations come back in input order. On errors the
    /// whole batch reports the first (by input index) error and nothing
    /// is indexed.
    async fn archive_fanout(
        &mut self,
        items: Vec<(Key, Bytes)>,
        split: Vec<(Key, Key, Key)>,
    ) -> Result<Vec<Indexed>, super::FdbError> {
        let n = items.len();
        let (ids, datas): (Vec<Key>, Vec<Bytes>) = items.into_iter().unzip();
        let sem = Resource::new("fdb/io-depth", self.sessions.len().max(1));
        let pool: RefCell<Vec<Box<dyn StoreSession>>> =
            RefCell::new(std::mem::take(&mut self.sessions));
        let locs: RefCell<Vec<Option<FieldLocation>>> =
            RefCell::new((0..n).map(|_| None).collect());
        let failed: RefCell<Option<(usize, super::FdbError)>> = RefCell::new(None);
        let sim = self.sim.clone();
        let trace = self.trace.clone();
        {
            let (pool, locs, failed) = (&pool, &locs, &failed);
            let (sem, sim, trace) = (&sem, &sim, &trace);
            let inflight = &self.io_inflight;
            let peak = &self.io_inflight_peak;
            let tasks: Vec<_> = datas
                .into_iter()
                .enumerate()
                .map(|(i, data)| {
                    let id = &ids[i];
                    let (ds, colloc, _elem) = &split[i];
                    boxed(async move {
                        sem.acquire().await;
                        let mut session =
                            pool.borrow_mut().pop().expect("session free under semaphore");
                        inflight.set(inflight.get() + 1);
                        peak.set(peak.get().max(inflight.get()));
                        let t0 = sim.now();
                        let r = session.archive(ds, colloc, id, data).await;
                        let lock = session.take_lock_time();
                        inflight.set(inflight.get() - 1);
                        pool.borrow_mut().push(session);
                        sem.release();
                        match r {
                            Ok(loc) => {
                                trace.record(OpClass::DataWrite, sim.now() - t0 - lock);
                                if lock > SimTime::ZERO {
                                    trace.record(OpClass::Lock, lock);
                                }
                                locs.borrow_mut()[i] = Some(loc);
                            }
                            Err(e) => {
                                let mut f = failed.borrow_mut();
                                if f.as_ref().map(|(j, _)| i < *j).unwrap_or(true) {
                                    *f = Some((i, e));
                                }
                            }
                        }
                    })
                })
                .collect();
            join_all(tasks).await;
        }
        self.sessions = pool.into_inner();
        if let Some((_, e)) = failed.into_inner() {
            return Err(e);
        }
        let mut indexed = Vec::with_capacity(n);
        for ((id, (ds, colloc, elem)), loc) in
            ids.into_iter().zip(split).zip(locs.into_inner())
        {
            let loc = loc.expect("no failure => every field has a location");
            indexed.push((id, ds, colloc, elem, loc));
        }
        Ok(indexed)
    }

    /// FDB flush(): Store flush (including every minted client session —
    /// their buffered writes must be durable too), then Catalogue flush
    /// (§2.7.1). Fallible since tiered stores write absorbed fields
    /// through to the backing tier here; on a Store error the Catalogue
    /// flush is skipped, so an index for non-durable data is never
    /// published.
    pub async fn flush(&mut self) -> Result<(), super::FdbError> {
        let t0 = self.sim.now();
        let mut flushed = self.store.flush().await;
        if flushed.is_ok() {
            for s in &mut self.sessions {
                flushed = s.flush().await;
                if flushed.is_err() {
                    break;
                }
            }
        }
        if flushed.is_ok() {
            flushed = self.catalogue.flush().await;
        }
        self.account(OpClass::Flush, t0);
        flushed
    }

    /// Catalogue close() at end of producer lifetime (§2.7.2). Fallible:
    /// the POSIX catalogue persists full indexes and TOC masks here.
    pub async fn close(&mut self) -> Result<(), super::FdbError> {
        let t0 = self.sim.now();
        let closed = self.catalogue.close().await;
        self.account(OpClass::Flush, t0);
        closed
    }

    /// Crash recovery (durable mode): replay write-ahead logs left in
    /// the dataset by crashed producers, re-indexing their unflushed
    /// entries. Call [`Fdb::flush`] (or [`Fdb::close`]) afterwards to
    /// publish the recovered entries to readers. No-op on catalogues
    /// without WAL support.
    pub async fn recover(
        &mut self,
        ds: &Key,
    ) -> Result<super::fault::RecoveryStats, super::FdbError> {
        let t0 = self.sim.now();
        let stats = self.catalogue.recover_dataset(ds).await;
        self.account(OpClass::IndexRead, t0);
        stats
    }

    /// FDB retrieve() for one fully-specified identifier.
    pub async fn retrieve(&mut self, id: &Key) -> Result<Option<DataHandle>, super::FdbError> {
        let (ds, colloc, elem) = self.schema.split(id)?;
        let t0 = self.sim.now();
        // hash-OID fast path (thesis §3.1.2 optimisation): a Store that
        // derives placement from identifiers bypasses the Catalogue
        let loc = if self.store.direct_retrieve_enabled() {
            self.store.retrieve_direct(&ds, id).await
        } else {
            self.catalogue.retrieve(&ds, &colloc, &elem, id).await
        };
        self.account(OpClass::IndexRead, t0);
        // not finding a field is NOT an error (cache use-case, §2.7.1)
        Ok(loc.map(|l| DataHandle::from_location(&l)))
    }

    /// Batched retrieve+read: Catalogue lookups stream into Store reads
    /// through an in-process pipe, so the lookup for `ids[i+1]` overlaps
    /// the data read for `ids[i]` in virtual time. (The pipe is
    /// unbounded: handles are tiny descriptors, so at most `ids.len()`
    /// of them queue if lookups outpace reads.) Returns the found
    /// `(identifier, bytes)` pairs in input order; absent fields are
    /// skipped (cache semantics, like [`Fdb::retrieve`]).
    ///
    /// At [`IoProfile::depth`] > 1 the Store half fans out over client
    /// sessions: up to `depth` data reads in flight behind the pipelined
    /// lookups, results re-ordered to input order — the intra-store read
    /// parallelism the serial pipe cannot express.
    ///
    /// With [`IoProfile::coalesce_gap`] > 0 the read planner takes over
    /// instead (`retrieve_coalesced`): adjacent fields merge into large
    /// ranged I/Os, byte- and order-identical output, fewer ops.
    pub async fn retrieve_many(
        &mut self,
        ids: &[Key],
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let mut split = Vec::with_capacity(ids.len());
        for id in ids {
            split.push(self.schema.split(id)?);
        }
        let fanout = self.ensure_sessions();
        if self.store.direct_retrieve_enabled() {
            if fanout {
                return self.retrieve_direct_fanout(ids, &split).await;
            }
            // direct mode: the Store serves the lookups too, so lookup
            // and read contend for the same client — run sequentially
            let mut out = Vec::new();
            for (id, (ds, _, _)) in ids.iter().zip(&split) {
                let t0 = self.sim.now();
                let loc = self.store.retrieve_direct(ds, id).await;
                self.account(OpClass::IndexRead, t0);
                if let Some(loc) = loc {
                    let h = DataHandle::from_location(&loc);
                    let t1 = self.sim.now();
                    let bytes = self.store.read(&h).await;
                    self.account(OpClass::DataRead, t1);
                    out.push((id.clone(), bytes?));
                }
            }
            return Ok(out);
        }
        if self.io.coalesce_enabled() {
            return self.retrieve_coalesced(ids, &split, fanout).await;
        }
        if fanout {
            return self.retrieve_fanout(ids, &split).await;
        }
        let pipe: Pipe<(Key, DataHandle)> = Pipe::new();
        let out: RefCell<Vec<(Key, Bytes)>> = RefCell::new(Vec::new());
        let failed: Cell<Option<super::FdbError>> = Cell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        let sim = self.sim.clone();
        let trace = self.trace.clone();
        // split borrows: the Catalogue drives lookups while the Store
        // serves reads — the two halves of the pipeline. Lock time is
        // drained per op (like `account`) so the IndexRead/DataRead
        // spans exclude it and it is recorded once under Lock.
        let store = &mut self.store;
        let catalogue = &mut self.catalogue;
        let lookups = async {
            for (id, (ds, colloc, elem)) in ids.iter().zip(&split) {
                let t0 = sim.now();
                let loc = catalogue.retrieve(ds, colloc, elem, id).await;
                let lock = catalogue.take_lock_time();
                lock_total.set(lock_total.get() + lock);
                trace.record(OpClass::IndexRead, sim.now() - t0 - lock);
                if let Some(loc) = loc {
                    pipe.push((id.clone(), DataHandle::from_location(&loc)));
                }
            }
            pipe.close();
        };
        let reads = async {
            while let Some((id, handle)) = pipe.pop().await {
                let t0 = sim.now();
                match store.read(&handle).await {
                    Ok(bytes) => {
                        let lock = store.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        trace.record(OpClass::DataRead, sim.now() - t0 - lock);
                        out.borrow_mut().push((id, bytes));
                    }
                    Err(e) => {
                        failed.set(Some(e));
                        break;
                    }
                }
            }
        };
        join_all(vec![boxed(lookups), boxed(reads)]).await;
        let lock = lock_total.get();
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        if let Some(e) = failed.take() {
            return Err(e);
        }
        Ok(out.into_inner())
    }

    /// [`Fdb::retrieve_many`] at depth > 1: the Catalogue client still
    /// runs its lookups serially (one index client, like the pipe path),
    /// but each resolved handle is handed to a per-field read task via a
    /// one-shot slot. Read tasks are admitted by a `depth`-server
    /// semaphore and check client sessions out of the pool, so up to
    /// `depth` store reads are in flight at once. Results land in an
    /// input-order table; absent fields are skipped.
    async fn retrieve_fanout(
        &mut self,
        ids: &[Key],
        split: &[(Key, Key, Key)],
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let n = ids.len();
        let sem = Resource::new("fdb/io-depth", self.sessions.len().max(1));
        let pool: RefCell<Vec<Box<dyn StoreSession>>> =
            RefCell::new(std::mem::take(&mut self.sessions));
        let slots: Vec<Slot<Option<DataHandle>>> = (0..n).map(|_| Slot::new()).collect();
        let out: RefCell<Vec<Option<(Key, Bytes)>>> =
            RefCell::new((0..n).map(|_| None).collect());
        let failed: RefCell<Option<(usize, super::FdbError)>> = RefCell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        let sim = self.sim.clone();
        let trace = self.trace.clone();
        {
            let (pool, slots, out, failed) = (&pool, &slots, &out, &failed);
            let (sem, sim, trace, lock_total) = (&sem, &sim, &trace, &lock_total);
            let inflight = &self.io_inflight;
            let peak = &self.io_inflight_peak;
            let catalogue = &mut self.catalogue;
            let lookups = boxed(async move {
                for (i, (id, (ds, colloc, elem))) in ids.iter().zip(split).enumerate() {
                    let t0 = sim.now();
                    let loc = catalogue.retrieve(ds, colloc, elem, id).await;
                    let lock = catalogue.take_lock_time();
                    lock_total.set(lock_total.get() + lock);
                    trace.record(OpClass::IndexRead, sim.now() - t0 - lock);
                    slots[i].put(loc.map(|l| DataHandle::from_location(&l)));
                }
            });
            let mut tasks = vec![lookups];
            for (i, id) in ids.iter().enumerate() {
                tasks.push(boxed(async move {
                    let Some(handle) = slots[i].take().await else {
                        return; // absent field: cache semantics
                    };
                    sem.acquire().await;
                    let mut session =
                        pool.borrow_mut().pop().expect("session free under semaphore");
                    inflight.set(inflight.get() + 1);
                    peak.set(peak.get().max(inflight.get()));
                    let t0 = sim.now();
                    let r = session.read(&handle).await;
                    let lock = session.take_lock_time();
                    lock_total.set(lock_total.get() + lock);
                    inflight.set(inflight.get() - 1);
                    pool.borrow_mut().push(session);
                    sem.release();
                    match r {
                        Ok(bytes) => {
                            trace.record(OpClass::DataRead, sim.now() - t0 - lock);
                            out.borrow_mut()[i] = Some((id.clone(), bytes));
                        }
                        Err(e) => {
                            let mut f = failed.borrow_mut();
                            if f.as_ref().map(|(j, _)| i < *j).unwrap_or(true) {
                                *f = Some((i, e));
                            }
                        }
                    }
                }));
            }
            join_all(tasks).await;
        }
        self.sessions = pool.into_inner();
        let lock = lock_total.get();
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        if let Some((_, e)) = failed.into_inner() {
            return Err(e);
        }
        Ok(out.into_inner().into_iter().flatten().collect())
    }

    /// [`Fdb::retrieve_many`] with the read planner on
    /// ([`IoProfile::coalesce_gap`] > 0): resolve every location first
    /// (the planner needs the full set — the lookup/read overlap the
    /// pipe buys is traded for op-count reduction), build a
    /// [`ReadPlan`] merging adjacent fields into ranged I/Os, execute
    /// the plan, and slice the merged buffers back into per-field bytes
    /// in input order. At depth > 1 the plan fans out over client
    /// sessions with **merged ranges as the unit of in-flight
    /// admission** (one [`Store::read_ranges`] call per range); at
    /// depth 1 the whole plan issues as a single vectored
    /// [`Store::read_ranges`] batch — a bare POSIX/RADOS store then
    /// resolves each container (file descriptor, pool handle) once for
    /// the batch, while wrappers route range by range by design (tiered
    /// per minting tier, replicated per read policy). Byte- and
    /// order-identical to the uncoalesced paths; only the op count (and
    /// so the virtual time) changes.
    async fn retrieve_coalesced(
        &mut self,
        ids: &[Key],
        split: &[(Key, Key, Key)],
        fanout: bool,
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let n = ids.len();
        // catalogue phase: serial lookups on the one index client,
        // accounted per op like the legacy paths
        let mut located: Vec<(usize, FieldLocation)> = Vec::new();
        for (i, (id, (ds, colloc, elem))) in ids.iter().zip(split).enumerate() {
            let t0 = self.sim.now();
            let loc = self.catalogue.retrieve(ds, colloc, elem, id).await;
            self.account(OpClass::IndexRead, t0);
            if let Some(loc) = loc {
                located.push((i, loc));
            }
        }
        let plan = ReadPlan::build(&located, self.io.coalesce_gap, self.io.coalesce_max);
        let mut stats = self.plan_stats.get();
        stats.absorb(plan.stats);
        self.plan_stats.set(stats);
        let out = if fanout {
            self.execute_plan_fanout(&plan, n).await?
        } else {
            // the whole plan as ONE vectored batch: a bare backend
            // resolves each container (fd, ioctx) once across every
            // merged range (wrappers route per range by design)
            let mut out: Vec<Option<Bytes>> = (0..n).map(|_| None).collect();
            if !plan.reads.is_empty() {
                let handles: Vec<DataHandle> =
                    plan.reads.iter().map(|pr| pr.handle.clone()).collect();
                let t0 = self.sim.now();
                let r = self.store.read_ranges(&handles).await;
                self.account(OpClass::DataRead, t0);
                for (pr, buf) in plan.reads.iter().zip(r?) {
                    for &(idx, rel, len) in &pr.fields {
                        out[idx] = Some(buf.slice(rel, len));
                    }
                }
            }
            out
        };
        Ok(ids
            .iter()
            .zip(out)
            .filter_map(|(id, b)| b.map(|b| (id.clone(), b)))
            .collect())
    }

    /// Execute a [`ReadPlan`] at depth > 1: one task per merged range,
    /// admitted by the `depth`-server semaphore; each admitted task
    /// checks a client session out of the pool, issues the ranged read
    /// through [`Store::read_ranges`], and slices its fields into the
    /// input-order table. Merged ranges — not raw fields — are the unit
    /// of in-flight admission, so a plan that halves the op count also
    /// halves the semaphore traffic.
    async fn execute_plan_fanout(
        &mut self,
        plan: &ReadPlan,
        n: usize,
    ) -> Result<Vec<Option<Bytes>>, super::FdbError> {
        let sem = Resource::new("fdb/io-depth", self.sessions.len().max(1));
        let pool: RefCell<Vec<Box<dyn StoreSession>>> =
            RefCell::new(std::mem::take(&mut self.sessions));
        let out: RefCell<Vec<Option<Bytes>>> =
            RefCell::new((0..n).map(|_| None).collect());
        let failed: RefCell<Option<(usize, super::FdbError)>> = RefCell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        let sim = self.sim.clone();
        let trace = self.trace.clone();
        {
            let (pool, out, failed) = (&pool, &out, &failed);
            let (sem, sim, trace, lock_total) = (&sem, &sim, &trace, &lock_total);
            let inflight = &self.io_inflight;
            let peak = &self.io_inflight_peak;
            let tasks: Vec<_> = plan
                .reads
                .iter()
                .enumerate()
                .map(|(ri, pr)| {
                    boxed(async move {
                        sem.acquire().await;
                        let mut session =
                            pool.borrow_mut().pop().expect("session free under semaphore");
                        inflight.set(inflight.get() + 1);
                        peak.set(peak.get().max(inflight.get()));
                        let t0 = sim.now();
                        let r = session.read_ranges(std::slice::from_ref(&pr.handle)).await;
                        let lock = session.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        inflight.set(inflight.get() - 1);
                        pool.borrow_mut().push(session);
                        sem.release();
                        match r {
                            Ok(mut bufs) => {
                                trace.record(OpClass::DataRead, sim.now() - t0 - lock);
                                let buf = bufs.pop().expect("one buffer per handle");
                                let mut out = out.borrow_mut();
                                for &(idx, rel, len) in &pr.fields {
                                    out[idx] = Some(buf.slice(rel, len));
                                }
                            }
                            Err(e) => {
                                let mut f = failed.borrow_mut();
                                if f.as_ref().map(|(j, _)| ri < *j).unwrap_or(true) {
                                    *f = Some((ri, e));
                                }
                            }
                        }
                    })
                })
                .collect();
            join_all(tasks).await;
        }
        self.sessions = pool.into_inner();
        let lock = lock_total.get();
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        if let Some((_, e)) = failed.into_inner() {
            return Err(e);
        }
        Ok(out.into_inner())
    }

    /// The direct-retrieve (hash-OID) variant of the fan-out: lookups
    /// would contend with reads on the single Store client, which is why
    /// the serial path runs them back-to-back — but sessions remove that
    /// contention entirely: each task resolves *and* reads through its
    /// own client, `depth` fields in flight.
    async fn retrieve_direct_fanout(
        &mut self,
        ids: &[Key],
        split: &[(Key, Key, Key)],
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let n = ids.len();
        let sem = Resource::new("fdb/io-depth", self.sessions.len().max(1));
        let pool: RefCell<Vec<Box<dyn StoreSession>>> =
            RefCell::new(std::mem::take(&mut self.sessions));
        let out: RefCell<Vec<Option<(Key, Bytes)>>> =
            RefCell::new((0..n).map(|_| None).collect());
        let failed: RefCell<Option<(usize, super::FdbError)>> = RefCell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        let sim = self.sim.clone();
        let trace = self.trace.clone();
        {
            let (pool, out, failed) = (&pool, &out, &failed);
            let (sem, sim, trace, lock_total) = (&sem, &sim, &trace, &lock_total);
            let inflight = &self.io_inflight;
            let peak = &self.io_inflight_peak;
            let tasks: Vec<_> = ids
                .iter()
                .enumerate()
                .map(|(i, id)| {
                    let (ds, _, _) = &split[i];
                    boxed(async move {
                        sem.acquire().await;
                        let mut session =
                            pool.borrow_mut().pop().expect("session free under semaphore");
                        inflight.set(inflight.get() + 1);
                        peak.set(peak.get().max(inflight.get()));
                        let t0 = sim.now();
                        let loc = session.retrieve_direct(ds, id).await;
                        let lock = session.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        trace.record(OpClass::IndexRead, sim.now() - t0 - lock);
                        let mut result = Ok(None);
                        if let Some(loc) = loc {
                            let h = DataHandle::from_location(&loc);
                            let t1 = sim.now();
                            let r = session.read(&h).await;
                            let lock = session.take_lock_time();
                            lock_total.set(lock_total.get() + lock);
                            result = r.map(Some);
                            if result.is_ok() {
                                trace.record(OpClass::DataRead, sim.now() - t1 - lock);
                            }
                        }
                        inflight.set(inflight.get() - 1);
                        pool.borrow_mut().push(session);
                        sem.release();
                        match result {
                            Ok(Some(bytes)) => {
                                out.borrow_mut()[i] = Some((id.clone(), bytes));
                            }
                            Ok(None) => {}
                            Err(e) => {
                                let mut f = failed.borrow_mut();
                                if f.as_ref().map(|(j, _)| i < *j).unwrap_or(true) {
                                    *f = Some((i, e));
                                }
                            }
                        }
                    })
                })
                .collect();
            join_all(tasks).await;
        }
        self.sessions = pool.into_inner();
        let lock = lock_total.get();
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        if let Some((_, e)) = failed.into_inner() {
            return Err(e);
        }
        Ok(out.into_inner().into_iter().flatten().collect())
    }

    /// Expand a request's wildcard dimensions from the axes.
    async fn expand_request(
        &mut self,
        request: &Request,
    ) -> Result<Vec<Key>, super::FdbError> {
        let mut request = request.clone();
        let wildcards = request.wildcards();
        if !wildcards.is_empty() {
            // need dataset+colloc keys from the fixed part
            let fixed = request.fixed_key();
            let ds = fixed
                .project(&self.schema.dataset)
                .ok_or(super::FdbError::UnderspecifiedRequest)?;
            let colloc = fixed
                .project(&self.schema.collocation)
                .ok_or(super::FdbError::UnderspecifiedRequest)?;
            for dim in wildcards {
                let vals = self.axes(&ds, &colloc, &dim).await;
                request.bind(&dim, vals);
            }
        }
        Ok(request.expand())
    }

    /// FDB retrieve() for a (possibly multi-valued) request: expands via
    /// axis(), retrieves every identifier, merges the handles.
    pub async fn retrieve_request(
        &mut self,
        request: &Request,
    ) -> Result<Vec<DataHandle>, super::FdbError> {
        let mut handles = Vec::new();
        for id in self.expand_request(request).await? {
            if let Some(h) = self.retrieve(&id).await? {
                handles.push(h);
            }
        }
        Ok(DataHandle::merge_all(handles))
    }

    /// Streaming request retrieval: wildcard expansion, then the
    /// pipelined [`Fdb::retrieve_many`] path (lookups overlap reads).
    pub async fn retrieve_request_streaming(
        &mut self,
        request: &Request,
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let ids = self.expand_request(request).await?;
        self.retrieve_many(&ids).await
    }

    /// Catalogue axis() values for one element dimension.
    pub async fn axes(&mut self, ds: &Key, colloc: &Key, dim: &str) -> Vec<String> {
        let t0 = self.sim.now();
        let out = self.catalogue.axis(ds, colloc, dim).await;
        self.account(OpClass::IndexRead, t0);
        out
    }

    /// FDB list(): all indexed identifiers matching a partial request.
    pub async fn list(
        &mut self,
        ds: &Key,
        request: &Request,
    ) -> Vec<(Key, crate::fdb::location::FieldLocation)> {
        let t0 = self.sim.now();
        let out = self.catalogue.list(ds, request).await;
        self.account(OpClass::IndexRead, t0);
        out
    }

    /// Drop reader-side caches so later flushes become visible.
    pub fn invalidate_preload(&mut self, ds: &Key) {
        self.catalogue.invalidate_preload(ds);
    }

    /// Read a handle's bytes through the Store. A handle minted by a
    /// different backend yields [`super::FdbError::BackendMismatch`].
    pub async fn read(&mut self, handle: &DataHandle) -> Result<Bytes, super::FdbError> {
        let t0 = self.sim.now();
        let out = self.store.read(handle).await;
        self.account(OpClass::DataRead, t0);
        out
    }

    /// Remove a dataset wholesale (fdb-wipe). Returns whether anything
    /// was removed. One Store wipe + one Catalogue deregistration —
    /// DAOS: a single `daos_cont_destroy` (the container-per-dataset
    /// argument); RADOS: per-object deletes in the dataset namespace;
    /// POSIX: unlink of the dataset directory's files. A strict no-op
    /// on Stores without wipe support (S3, Null): deregistering the
    /// catalogue while the data survives would orphan live objects.
    pub async fn wipe(&mut self, ds: &Key) -> bool {
        if !self.store.supports_wipe() {
            return false;
        }
        let removed = self.store.wipe_dataset(ds).await;
        // sessions wipe too: that purges their per-dataset client state
        // (open data files, absorbed-but-unspilled tiered fields) for
        // `ds` only — state for OTHER datasets must survive exactly as
        // it does at depth 1. The main store already unlinked the files,
        // so session wipes find nothing on disk.
        for s in &mut self.sessions {
            s.wipe_dataset(ds).await;
        }
        self.catalogue.deregister_dataset(ds).await;
        removed
    }
}

/// A single-producer single-consumer in-process queue connecting the
/// two halves of the retrieve pipeline. Waker-based so the consumer
/// suspends cleanly while the producer awaits backend I/O.
struct Pipe<T> {
    queue: RefCell<VecDeque<T>>,
    closed: Cell<bool>,
    waker: RefCell<Option<Waker>>,
}

impl<T> Pipe<T> {
    fn new() -> Pipe<T> {
        Pipe {
            queue: RefCell::new(VecDeque::new()),
            closed: Cell::new(false),
            waker: RefCell::new(None),
        }
    }

    fn push(&self, item: T) {
        self.queue.borrow_mut().push_back(item);
        if let Some(w) = self.waker.borrow_mut().take() {
            w.wake();
        }
    }

    fn close(&self) {
        self.closed.set(true);
        if let Some(w) = self.waker.borrow_mut().take() {
            w.wake();
        }
    }

    fn pop(&self) -> Pop<'_, T> {
        Pop { pipe: self }
    }
}

struct Pop<'a, T> {
    pipe: &'a Pipe<T>,
}

impl<'a, T> std::future::Future for Pop<'a, T> {
    type Output = Option<T>;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<Option<T>> {
        if let Some(item) = self.pipe.queue.borrow_mut().pop_front() {
            return std::task::Poll::Ready(Some(item));
        }
        if self.pipe.closed.get() {
            return std::task::Poll::Ready(None);
        }
        *self.pipe.waker.borrow_mut() = Some(cx.waker().clone());
        std::task::Poll::Pending
    }
}

/// A one-shot value slot connecting the lookup task to a per-field read
/// task in the fan-out engine: the producer `put`s exactly once, the
/// single consumer `take().await`s it. Waker-based so the consumer
/// suspends cleanly while the catalogue client is still looking up
/// earlier identifiers.
struct Slot<T> {
    value: RefCell<Option<T>>,
    waker: RefCell<Option<Waker>>,
}

impl<T> Slot<T> {
    fn new() -> Slot<T> {
        Slot {
            value: RefCell::new(None),
            waker: RefCell::new(None),
        }
    }

    fn put(&self, value: T) {
        *self.value.borrow_mut() = Some(value);
        if let Some(w) = self.waker.borrow_mut().take() {
            w.wake();
        }
    }

    fn take(&self) -> TakeSlot<'_, T> {
        TakeSlot { slot: self }
    }
}

struct TakeSlot<'a, T> {
    slot: &'a Slot<T>,
}

impl<'a, T> std::future::Future for TakeSlot<'a, T> {
    type Output = T;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<T> {
        if let Some(value) = self.slot.value.borrow_mut().take() {
            return std::task::Poll::Ready(value);
        }
        *self.slot.waker.borrow_mut() = Some(cx.waker().clone());
        std::task::Poll::Pending
    }
}
