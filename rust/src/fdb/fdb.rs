//! The top-level FDB API (thesis §2.7): `archive() / flush() /
//! retrieve() / list()` plus `axes()` and `close()`, and the batched
//! `archive_many()` / `retrieve_many()` paths the DAOS follow-up papers
//! identify as the key to scalable small-object I/O.
//!
//! All backend dispatch is virtual: one `Box<dyn Store>` and one
//! `Box<dyn Catalogue>` (see [`crate::fdb::backend`]), with per-op-class
//! trace and distributed-lock accounting factored into a single shared
//! wrapper ([`Fdb::account`]). Construction goes through
//! [`crate::fdb::builder::FdbBuilder`].

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::task::Waker;

use crate::fdb::backend::{Catalogue, Store};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::request::Request;
use crate::fdb::schema::Schema;
use crate::sim::exec::Sim;
use crate::sim::futures::{boxed, join_all};
use crate::sim::time::SimTime;
use crate::sim::trace::{OpClass, Trace};
use crate::util::content::Bytes;

/// One FDB instance per simulated process (like linking libfdb).
pub struct Fdb {
    pub schema: Schema,
    store: Box<dyn Store>,
    catalogue: Box<dyn Catalogue>,
    pub trace: Trace,
    sim: Sim,
}

impl Fdb {
    /// Wire a Store/Catalogue pair directly. Prefer
    /// [`crate::fdb::builder::FdbBuilder`], which validates configs and
    /// picks matching pairs.
    pub fn new(
        sim: &Sim,
        schema: Schema,
        store: Box<dyn Store>,
        catalogue: Box<dyn Catalogue>,
    ) -> Fdb {
        Fdb {
            schema,
            store,
            catalogue,
            trace: Trace::new(),
            sim: sim.clone(),
        }
    }

    /// Attach a shared trace collector (benchmark profiling).
    pub fn with_trace(mut self, trace: Trace) -> Fdb {
        self.trace = trace;
        self
    }

    /// Backend tags of the wired (store, catalogue) pair.
    pub fn backend_names(&self) -> (&'static str, &'static str) {
        (self.store.name(), self.catalogue.name())
    }

    /// The shared trace/lock wrapper: record the span since `t0` under
    /// `class`, with any distributed-lock time drained from both
    /// backends split out into [`OpClass::Lock`].
    fn account(&mut self, class: OpClass, t0: SimTime) {
        let lock = self.store.take_lock_time() + self.catalogue.take_lock_time();
        self.trace.record(class, self.sim.now() - t0 - lock);
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
    }

    /// FDB archive(): Store archive then Catalogue archive (§2.7.1).
    pub async fn archive(
        &mut self,
        id: &Key,
        data: impl Into<Bytes>,
    ) -> Result<(), super::FdbError> {
        let data: Bytes = data.into();
        let (ds, colloc, elem) = self.schema.split(id)?;
        let t0 = self.sim.now();
        let loc = self.store.archive(&ds, &colloc, id, data).await;
        self.account(OpClass::DataWrite, t0);
        let loc = loc?;
        let t1 = self.sim.now();
        self.catalogue.archive(&ds, &colloc, &elem, id, &loc).await;
        self.account(OpClass::IndexWrite, t1);
        Ok(())
    }

    /// Batched archive: all Store writes first, then all Catalogue
    /// inserts — the small-object batching pattern (arXiv:2311.18714).
    /// Identifiers are validated up front; nothing is written on a
    /// validation error. A Store error mid-batch stops before the
    /// Catalogue pass: the already-written fields stay un-indexed and
    /// therefore invisible, like a crashed writer's unflushed step.
    pub async fn archive_many(
        &mut self,
        items: Vec<(Key, Bytes)>,
    ) -> Result<(), super::FdbError> {
        let mut split = Vec::with_capacity(items.len());
        for (id, _) in &items {
            split.push(self.schema.split(id)?);
        }
        let t0 = self.sim.now();
        let mut indexed = Vec::with_capacity(items.len());
        let mut failed = None;
        for ((id, data), (ds, colloc, elem)) in items.into_iter().zip(split) {
            match self.store.archive(&ds, &colloc, &id, data).await {
                Ok(loc) => indexed.push((id, ds, colloc, elem, loc)),
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            }
        }
        self.account(OpClass::DataWrite, t0);
        if let Some(e) = failed {
            return Err(e);
        }
        let t1 = self.sim.now();
        for (id, ds, colloc, elem, loc) in &indexed {
            self.catalogue.archive(ds, colloc, elem, id, loc).await;
        }
        self.account(OpClass::IndexWrite, t1);
        Ok(())
    }

    /// FDB flush(): Store flush then Catalogue flush (§2.7.1). Fallible
    /// since tiered stores write absorbed fields through to the backing
    /// tier here; on a Store error the Catalogue flush is skipped, so an
    /// index for non-durable data is never published.
    pub async fn flush(&mut self) -> Result<(), super::FdbError> {
        let t0 = self.sim.now();
        let flushed = self.store.flush().await;
        if flushed.is_ok() {
            self.catalogue.flush().await;
        }
        self.account(OpClass::Flush, t0);
        flushed
    }

    /// Catalogue close() at end of producer lifetime (§2.7.2).
    pub async fn close(&mut self) {
        let t0 = self.sim.now();
        self.catalogue.close().await;
        self.account(OpClass::Flush, t0);
    }

    /// FDB retrieve() for one fully-specified identifier.
    pub async fn retrieve(&mut self, id: &Key) -> Result<Option<DataHandle>, super::FdbError> {
        let (ds, colloc, elem) = self.schema.split(id)?;
        let t0 = self.sim.now();
        // hash-OID fast path (thesis §3.1.2 optimisation): a Store that
        // derives placement from identifiers bypasses the Catalogue
        let loc = if self.store.direct_retrieve_enabled() {
            self.store.retrieve_direct(&ds, id).await
        } else {
            self.catalogue.retrieve(&ds, &colloc, &elem, id).await
        };
        self.account(OpClass::IndexRead, t0);
        // not finding a field is NOT an error (cache use-case, §2.7.1)
        Ok(loc.map(|l| DataHandle::from_location(&l)))
    }

    /// Batched retrieve+read: Catalogue lookups stream into Store reads
    /// through an in-process pipe, so the lookup for `ids[i+1]` overlaps
    /// the data read for `ids[i]` in virtual time. (The pipe is
    /// unbounded: handles are tiny descriptors, so at most `ids.len()`
    /// of them queue if lookups outpace reads.) Returns the found
    /// `(identifier, bytes)` pairs in input order; absent fields are
    /// skipped (cache semantics, like [`Fdb::retrieve`]).
    pub async fn retrieve_many(
        &mut self,
        ids: &[Key],
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let mut split = Vec::with_capacity(ids.len());
        for id in ids {
            split.push(self.schema.split(id)?);
        }
        if self.store.direct_retrieve_enabled() {
            // direct mode: the Store serves the lookups too, so lookup
            // and read contend for the same client — run sequentially
            let mut out = Vec::new();
            for (id, (ds, _, _)) in ids.iter().zip(&split) {
                let t0 = self.sim.now();
                let loc = self.store.retrieve_direct(ds, id).await;
                self.account(OpClass::IndexRead, t0);
                if let Some(loc) = loc {
                    let h = DataHandle::from_location(&loc);
                    let t1 = self.sim.now();
                    let bytes = self.store.read(&h).await;
                    self.account(OpClass::DataRead, t1);
                    out.push((id.clone(), bytes?));
                }
            }
            return Ok(out);
        }
        let pipe: Pipe<(Key, DataHandle)> = Pipe::new();
        let out: RefCell<Vec<(Key, Bytes)>> = RefCell::new(Vec::new());
        let failed: Cell<Option<super::FdbError>> = Cell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        let sim = self.sim.clone();
        let trace = self.trace.clone();
        // split borrows: the Catalogue drives lookups while the Store
        // serves reads — the two halves of the pipeline. Lock time is
        // drained per op (like `account`) so the IndexRead/DataRead
        // spans exclude it and it is recorded once under Lock.
        let store = &mut self.store;
        let catalogue = &mut self.catalogue;
        let lookups = async {
            for (id, (ds, colloc, elem)) in ids.iter().zip(&split) {
                let t0 = sim.now();
                let loc = catalogue.retrieve(ds, colloc, elem, id).await;
                let lock = catalogue.take_lock_time();
                lock_total.set(lock_total.get() + lock);
                trace.record(OpClass::IndexRead, sim.now() - t0 - lock);
                if let Some(loc) = loc {
                    pipe.push((id.clone(), DataHandle::from_location(&loc)));
                }
            }
            pipe.close();
        };
        let reads = async {
            while let Some((id, handle)) = pipe.pop().await {
                let t0 = sim.now();
                match store.read(&handle).await {
                    Ok(bytes) => {
                        let lock = store.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        trace.record(OpClass::DataRead, sim.now() - t0 - lock);
                        out.borrow_mut().push((id, bytes));
                    }
                    Err(e) => {
                        failed.set(Some(e));
                        break;
                    }
                }
            }
        };
        join_all(vec![boxed(lookups), boxed(reads)]).await;
        let lock = lock_total.get();
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        if let Some(e) = failed.take() {
            return Err(e);
        }
        Ok(out.into_inner())
    }

    /// Expand a request's wildcard dimensions from the axes.
    async fn expand_request(
        &mut self,
        request: &Request,
    ) -> Result<Vec<Key>, super::FdbError> {
        let mut request = request.clone();
        let wildcards = request.wildcards();
        if !wildcards.is_empty() {
            // need dataset+colloc keys from the fixed part
            let fixed = request.fixed_key();
            let ds = fixed
                .project(&self.schema.dataset)
                .ok_or(super::FdbError::UnderspecifiedRequest)?;
            let colloc = fixed
                .project(&self.schema.collocation)
                .ok_or(super::FdbError::UnderspecifiedRequest)?;
            for dim in wildcards {
                let vals = self.axes(&ds, &colloc, &dim).await;
                request.bind(&dim, vals);
            }
        }
        Ok(request.expand())
    }

    /// FDB retrieve() for a (possibly multi-valued) request: expands via
    /// axis(), retrieves every identifier, merges the handles.
    pub async fn retrieve_request(
        &mut self,
        request: &Request,
    ) -> Result<Vec<DataHandle>, super::FdbError> {
        let mut handles = Vec::new();
        for id in self.expand_request(request).await? {
            if let Some(h) = self.retrieve(&id).await? {
                handles.push(h);
            }
        }
        Ok(DataHandle::merge_all(handles))
    }

    /// Streaming request retrieval: wildcard expansion, then the
    /// pipelined [`Fdb::retrieve_many`] path (lookups overlap reads).
    pub async fn retrieve_request_streaming(
        &mut self,
        request: &Request,
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let ids = self.expand_request(request).await?;
        self.retrieve_many(&ids).await
    }

    /// Catalogue axis() values for one element dimension.
    pub async fn axes(&mut self, ds: &Key, colloc: &Key, dim: &str) -> Vec<String> {
        let t0 = self.sim.now();
        let out = self.catalogue.axis(ds, colloc, dim).await;
        self.account(OpClass::IndexRead, t0);
        out
    }

    /// FDB list(): all indexed identifiers matching a partial request.
    pub async fn list(
        &mut self,
        ds: &Key,
        request: &Request,
    ) -> Vec<(Key, crate::fdb::location::FieldLocation)> {
        let t0 = self.sim.now();
        let out = self.catalogue.list(ds, request).await;
        self.account(OpClass::IndexRead, t0);
        out
    }

    /// Drop reader-side caches so later flushes become visible.
    pub fn invalidate_preload(&mut self, ds: &Key) {
        self.catalogue.invalidate_preload(ds);
    }

    /// Read a handle's bytes through the Store. A handle minted by a
    /// different backend yields [`super::FdbError::BackendMismatch`].
    pub async fn read(&mut self, handle: &DataHandle) -> Result<Bytes, super::FdbError> {
        let t0 = self.sim.now();
        let out = self.store.read(handle).await;
        self.account(OpClass::DataRead, t0);
        out
    }

    /// Remove a dataset wholesale (fdb-wipe). Returns whether anything
    /// was removed. One Store wipe + one Catalogue deregistration —
    /// DAOS: a single `daos_cont_destroy` (the container-per-dataset
    /// argument); RADOS: per-object deletes in the dataset namespace;
    /// POSIX: unlink of the dataset directory's files. A strict no-op
    /// on Stores without wipe support (S3, Null): deregistering the
    /// catalogue while the data survives would orphan live objects.
    pub async fn wipe(&mut self, ds: &Key) -> bool {
        if !self.store.supports_wipe() {
            return false;
        }
        let removed = self.store.wipe_dataset(ds).await;
        self.catalogue.deregister_dataset(ds).await;
        removed
    }
}

/// A single-producer single-consumer in-process queue connecting the
/// two halves of the retrieve pipeline. Waker-based so the consumer
/// suspends cleanly while the producer awaits backend I/O.
struct Pipe<T> {
    queue: RefCell<VecDeque<T>>,
    closed: Cell<bool>,
    waker: RefCell<Option<Waker>>,
}

impl<T> Pipe<T> {
    fn new() -> Pipe<T> {
        Pipe {
            queue: RefCell::new(VecDeque::new()),
            closed: Cell::new(false),
            waker: RefCell::new(None),
        }
    }

    fn push(&self, item: T) {
        self.queue.borrow_mut().push_back(item);
        if let Some(w) = self.waker.borrow_mut().take() {
            w.wake();
        }
    }

    fn close(&self) {
        self.closed.set(true);
        if let Some(w) = self.waker.borrow_mut().take() {
            w.wake();
        }
    }

    fn pop(&self) -> Pop<'_, T> {
        Pop { pipe: self }
    }
}

struct Pop<'a, T> {
    pipe: &'a Pipe<T>,
}

impl<'a, T> std::future::Future for Pop<'a, T> {
    type Output = Option<T>;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<Option<T>> {
        if let Some(item) = self.pipe.queue.borrow_mut().pop_front() {
            return std::task::Poll::Ready(Some(item));
        }
        if self.pipe.closed.get() {
            return std::task::Poll::Ready(None);
        }
        *self.pipe.waker.borrow_mut() = Some(cx.waker().clone());
        std::task::Poll::Pending
    }
}
