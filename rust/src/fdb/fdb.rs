//! The top-level FDB API (thesis §2.7): `archive() / flush() /
//! retrieve() / list()` plus `axes()` and `close()`, and the batched
//! `archive_many()` / `retrieve_many()` paths the DAOS follow-up papers
//! identify as the key to scalable small-object I/O.
//!
//! All backend dispatch is virtual: one `Box<dyn Store>` and one
//! `Box<dyn Catalogue>` (see [`crate::fdb::backend`]), with per-op-class
//! trace and distributed-lock accounting factored into a single shared
//! wrapper ([`Fdb::account`]). Construction goes through
//! [`crate::fdb::builder::FdbBuilder`].
//!
//! The **I/O engine**: with [`IoProfile::depth`] > 1 every batched path
//! is a thin *resolve → plan → execute* submission to the shared
//! [`IoEngine`] (see [`crate::fdb::engine`]), which owns the depth
//! semaphore, the store/catalogue session pools, in-flight
//! instrumentation, and per-op-class trace/lock accounting in exactly
//! one place. Results are re-ordered to input order, so any
//! `depth >= 1` is byte- and order-identical to `depth = 1` — only the
//! virtual time changes. This is the queue-depth client asynchrony of
//! the DAOS interface papers (event queues with N outstanding ops).

use std::cell::{Cell, RefCell};

use crate::fdb::backend::{Catalogue, Store};
use crate::fdb::builder::{IoProfile, ResilienceProfile};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::engine::{IoEngine, Pipe};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::plan::{PlanStats, ReadPlan};
use crate::fdb::request::Request;
use crate::fdb::schema::Schema;
use crate::fdb::scrub::{FsckReport, RangeCheck};
use crate::fdb::telemetry::{is_injected_fault, EngineMetrics, MetricsRegistry};
use crate::sim::exec::Sim;
use crate::sim::futures::{boxed, join_all};
use crate::sim::time::SimTime;
use crate::sim::trace::{OpClass, Trace};
use crate::util::content::Bytes;

/// One store-pass result awaiting its catalogue insert:
/// `(identifier, dataset, collocation, element, location)`.
type Indexed = (Key, Key, Key, Key, FieldLocation);

/// One FDB instance per simulated process (like linking libfdb).
pub struct Fdb {
    pub schema: Schema,
    store: Box<dyn Store>,
    catalogue: Box<dyn Catalogue>,
    pub trace: Trace,
    sim: Sim,
    /// queue-depth configuration (depth 1 = the serial legacy paths)
    io: IoProfile,
    /// the shared scheduler behind every batched path: depth semaphore,
    /// store/catalogue session pools (lazily minted, reused across
    /// batches so session client state persists like a real client's),
    /// in-flight instrumentation, per-op trace/lock accounting
    engine: IoEngine,
    /// cumulative read-plan counters (zero until a coalesced retrieve
    /// runs; see [`IoProfile::coalesce_gap`])
    plan_stats: Cell<PlanStats>,
    /// pre-bound per-class telemetry handles for the serial paths
    /// (`None` = metrics off, the zero-overhead default)
    metrics: Option<EngineMetrics>,
    /// the attached registry (journal spans, slow-op log, plan/recovery
    /// counters)
    registry: Option<MetricsRegistry>,
    /// slow-op threshold in ns (from [`IoProfile::slow_op_us`]; 0 = off)
    slow_op_ns: u64,
}

impl Fdb {
    /// Wire a Store/Catalogue pair directly. Prefer
    /// [`crate::fdb::builder::FdbBuilder`], which validates configs and
    /// picks matching pairs.
    pub fn new(
        sim: &Sim,
        schema: Schema,
        store: Box<dyn Store>,
        catalogue: Box<dyn Catalogue>,
    ) -> Fdb {
        Fdb {
            schema,
            store,
            catalogue,
            trace: Trace::new(),
            sim: sim.clone(),
            io: IoProfile::default(),
            engine: IoEngine::new(sim),
            plan_stats: Cell::new(PlanStats::default()),
            metrics: None,
            registry: None,
            slow_op_ns: 0,
        }
    }

    /// Attach a shared trace collector (benchmark profiling).
    pub fn with_trace(mut self, trace: Trace) -> Fdb {
        self.trace = trace.clone();
        self.engine.set_trace(trace);
        self
    }

    /// Set the I/O-depth profile (callers go through
    /// [`crate::fdb::builder::FdbBuilder::io`], which validates it).
    pub fn with_io(mut self, io: IoProfile) -> Fdb {
        self.io = io;
        self.engine.set_depth(io.depth);
        self
    }

    /// Attach a metrics registry (after [`Fdb::with_io`] — the slow-op
    /// threshold comes from the profile): serial-path ops mirror their
    /// trace accounting into per-class service histograms *at the same
    /// sites with the same lock-subtracted durations* as
    /// [`Trace::record`], so registry histogram totals agree exactly
    /// with the trace; the engine records the admission-wait vs.
    /// service-time split on the fan-out paths. The builder wires this
    /// for [`crate::fdb::builder::FdbBuilder::metrics`].
    pub fn with_metrics(mut self, reg: &MetricsRegistry) -> Fdb {
        self.metrics = Some(EngineMetrics::bind(reg));
        self.registry = Some(reg.clone());
        self.slow_op_ns = self.io.slow_op_us.saturating_mul(1_000);
        self.engine.set_metrics(reg, self.io.slow_op_us);
        self
    }

    /// Install the engine's retry/backoff/deadline policy (after
    /// [`Fdb::with_metrics`] if counters should record — the builder
    /// orders the two correctly). Hedging and quarantine live in the
    /// replicated store, wired by the builder from the same profile.
    pub fn with_resilience(mut self, res: ResilienceProfile) -> Fdb {
        self.engine.set_resilience(res);
        self
    }

    /// The attached metrics registry, if any.
    pub fn metrics(&self) -> Option<&MetricsRegistry> {
        self.registry.as_ref()
    }

    /// The active I/O profile.
    pub fn io_profile(&self) -> IoProfile {
        self.io
    }

    /// Client sessions minted so far (0 until a batched op runs at
    /// depth > 1).
    pub fn io_sessions(&self) -> usize {
        self.engine.store_sessions()
    }

    /// High-water mark of concurrently in-flight admitted operations —
    /// never exceeds [`IoProfile::depth`] (the engine's semaphore bound;
    /// asserted by the integration tests). Catalogue-session lookups
    /// and store I/O share the one semaphore, so the bound covers both.
    /// With a registry attached the same value is exported live as the
    /// `engine.inflight_peak` gauge.
    pub fn io_inflight_peak(&self) -> usize {
        self.engine.inflight_peak()
    }

    /// Cumulative read-plan counters across this instance's coalesced
    /// retrieves: requested vs issued ops, merges, hole bytes read
    /// through. All-zero until [`IoProfile::coalesce_gap`] > 0. With a
    /// registry attached the same counters are exported as `plan.*`.
    pub fn plan_stats(&self) -> PlanStats {
        self.plan_stats.get()
    }

    /// Accumulate one batch's plan counters — the `Cell` the
    /// [`Fdb::plan_stats`] accessor reads, mirrored in lockstep onto
    /// the registry's `plan.*` counters when metrics are attached.
    fn absorb_plan_stats(&self, stats: PlanStats) {
        let mut acc = self.plan_stats.get();
        acc.absorb(stats);
        self.plan_stats.set(acc);
        if let Some(reg) = &self.registry {
            reg.counter("plan.ops_in").add(stats.ops_in);
            reg.counter("plan.ops_out").add(stats.ops_out);
            reg.counter("plan.ops_merged").add(stats.ops_merged);
            reg.counter("plan.bytes_read_through")
                .add(stats.bytes_read_through);
        }
    }

    /// Backend tags of the wired (store, catalogue) pair.
    pub fn backend_names(&self) -> (&'static str, &'static str) {
        (self.store.name(), self.catalogue.name())
    }

    /// The whole-field check set of a single-field read: one
    /// [`RangeCheck`] when the location carries a checksum, empty (no
    /// verification) for legacy entries.
    fn whole_checks(loc: &FieldLocation) -> Vec<RangeCheck> {
        loc.checksum()
            .map(|ck| vec![RangeCheck::whole(loc.length(), ck)])
            .unwrap_or_default()
    }

    /// Count a surfaced integrity failure on the attached registry.
    /// Surfaced means the caller sees it: with replication the verified
    /// read paths repair from the next healthy copy instead, and this
    /// counter stays zero.
    fn note_corrupt(&self, e: &super::FdbError) {
        if let (Some(reg), super::FdbError::Corrupt { .. }) = (&self.registry, e) {
            reg.counter("integrity.corrupt").inc();
        }
    }

    /// Fill the engine's store-session pool up to the configured depth.
    /// Returns whether the engine's fan-out paths can run; `false`
    /// (depth 1, or a backend without session support) keeps callers on
    /// the serial paths.
    fn ensure_sessions(&mut self) -> bool {
        self.engine.ensure_store_sessions(self.store.as_mut())
    }

    /// The shared trace/lock wrapper: record the span since `t0` under
    /// `class`, with any distributed-lock time drained from the backends
    /// (and any idle pooled sessions) split out into [`OpClass::Lock`].
    fn account(&mut self, class: OpClass, t0: SimTime) {
        let lock = self.store.take_lock_time()
            + self.catalogue.take_lock_time()
            + self.engine.take_pooled_lock_time();
        let now = self.sim.now();
        self.trace.record(class, now - t0 - lock);
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
        }
        if let Some(m) = &self.metrics {
            m.probe(class).service.observe_duration(now - t0 - lock);
            if lock > SimTime::ZERO {
                m.probe(OpClass::Lock).service.observe_duration(lock);
            }
        }
        if let Some(reg) = &self.registry {
            reg.record_span(0, class.label(), t0, now);
            if self.slow_op_ns > 0 && (now - t0).as_nanos() >= self.slow_op_ns {
                let backend = match class {
                    OpClass::DataRead | OpClass::DataWrite => self.store.name(),
                    _ => self.catalogue.name(),
                };
                reg.record_slow_op(class, backend, now - t0);
            }
        }
    }

    /// FDB archive(): Store archive then Catalogue archive (§2.7.1).
    pub async fn archive(
        &mut self,
        id: &Key,
        data: impl Into<Bytes>,
    ) -> Result<(), super::FdbError> {
        let data: Bytes = data.into();
        // the end-to-end integrity envelope: checksum the payload ONCE
        // here, before any store/wrapper touches it, and carry it in the
        // location → catalogue entry → every verified read
        let ck = data.content_checksum();
        let (ds, colloc, elem) = self.schema.split(id)?;
        let t0 = self.sim.now();
        let loc = self.store.archive(&ds, &colloc, id, data).await;
        self.account(OpClass::DataWrite, t0);
        let loc = loc?.with_checksum(ck);
        let t1 = self.sim.now();
        let indexed = self.catalogue.archive(&ds, &colloc, &elem, id, &loc).await;
        self.account(OpClass::IndexWrite, t1);
        // on a catalogue error the written field stays un-indexed and
        // therefore invisible — same story as a crashed writer
        indexed
    }

    /// Batched archive: all Store writes first, then all Catalogue
    /// inserts — the small-object batching pattern (arXiv:2311.18714).
    /// Identifiers are validated up front; nothing is written on a
    /// validation error. A Store error in the batch stops before the
    /// Catalogue pass: the already-written fields stay un-indexed and
    /// therefore invisible, like a crashed writer's unflushed step.
    ///
    /// At [`IoProfile::depth`] > 1 the Store pass submits to the
    /// [`IoEngine`] with up to `depth` writes in flight; the Catalogue
    /// pass stays in input order either way, so the index is identical.
    /// The Catalogue pass runs as one **write group**
    /// ([`Catalogue::begin_archive_group`]): a durable (WAL'd) catalogue
    /// defers its per-intent fdatasync and issues ONE barrier per dirty
    /// WAL at group end — group commit — so a durable N-field batch
    /// costs one fsync instead of N. The group barrier completes before
    /// this returns, on every path including errors: nothing is
    /// reported archived whose intent is not yet on disk.
    pub async fn archive_many(
        &mut self,
        items: Vec<(Key, Bytes)>,
    ) -> Result<(), super::FdbError> {
        let mut split = Vec::with_capacity(items.len());
        for (id, _) in &items {
            split.push(self.schema.split(id)?);
        }
        let indexed = if self.ensure_sessions() {
            let (ids, datas): (Vec<Key>, Vec<Bytes>) = items.into_iter().unzip();
            let cks: Vec<u64> = datas.iter().map(Bytes::content_checksum).collect();
            let locs = self.engine.archive_batch(&ids, datas, &split).await?;
            ids.into_iter()
                .zip(split)
                .zip(locs.into_iter().zip(cks))
                .map(|((id, (ds, colloc, elem)), (loc, ck))| {
                    (id, ds, colloc, elem, loc.with_checksum(ck))
                })
                .collect()
        } else {
            let t0 = self.sim.now();
            let mut indexed: Vec<Indexed> = Vec::with_capacity(items.len());
            let mut failed = None;
            for ((id, data), (ds, colloc, elem)) in items.into_iter().zip(split) {
                let ck = data.content_checksum();
                match self.store.archive(&ds, &colloc, &id, data).await {
                    Ok(loc) => indexed.push((id, ds, colloc, elem, loc.with_checksum(ck))),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            self.account(OpClass::DataWrite, t0);
            if let Some(e) = failed {
                return Err(e);
            }
            indexed
        };
        let t1 = self.sim.now();
        self.catalogue.begin_archive_group();
        let mut inserted = Ok(());
        for (id, ds, colloc, elem, loc) in &indexed {
            if let Err(e) = self.catalogue.archive(ds, colloc, elem, id, loc).await {
                // later fields of the batch stay un-indexed — invisible,
                // like the store-error story above
                inserted = Err(e);
                break;
            }
        }
        // the group barrier runs on the error path too: intents appended
        // BEFORE the failing insert must still reach disk
        let ended = self.catalogue.end_archive_group().await;
        self.account(OpClass::IndexWrite, t1);
        inserted?;
        ended
    }

    /// FDB flush(): Store flush (including every minted client session —
    /// their buffered writes must be durable too), then Catalogue flush
    /// (§2.7.1). Fallible since tiered stores write absorbed fields
    /// through to the backing tier here; on a Store error the Catalogue
    /// flush is skipped, so an index for non-durable data is never
    /// published.
    pub async fn flush(&mut self) -> Result<(), super::FdbError> {
        let t0 = self.sim.now();
        let mut flushed = self.store.flush().await;
        if flushed.is_ok() {
            flushed = self.engine.flush_store_sessions().await;
        }
        if flushed.is_ok() {
            flushed = self.catalogue.flush().await;
        }
        self.account(OpClass::Flush, t0);
        flushed
    }

    /// Catalogue close() at end of producer lifetime (§2.7.2). Fallible:
    /// the POSIX catalogue persists full indexes and TOC masks here.
    pub async fn close(&mut self) -> Result<(), super::FdbError> {
        let t0 = self.sim.now();
        let closed = self.catalogue.close().await;
        self.account(OpClass::Flush, t0);
        closed
    }

    /// Crash recovery (durable mode): replay write-ahead logs left in
    /// the dataset by crashed producers, re-indexing their unflushed
    /// entries. Call [`Fdb::flush`] (or [`Fdb::close`]) afterwards to
    /// publish the recovered entries to readers. No-op on catalogues
    /// without WAL support.
    pub async fn recover(
        &mut self,
        ds: &Key,
    ) -> Result<super::fault::RecoveryStats, super::FdbError> {
        let t0 = self.sim.now();
        let stats = self.catalogue.recover_dataset(ds).await;
        self.account(OpClass::IndexRead, t0);
        if let (Some(reg), Ok(s)) = (&self.registry, &stats) {
            reg.counter("recovery.replayed").add(s.replayed as u64);
            reg.counter("recovery.committed").add(s.committed as u64);
            reg.counter("recovery.data_missing").add(s.data_missing as u64);
            reg.counter("recovery.data_corrupt").add(s.data_corrupt as u64);
            reg.counter("recovery.wal_files").add(s.wal_files as u64);
            reg.counter("recovery.torn_bytes").add(s.torn_bytes as u64);
        }
        stats
    }

    /// FDB retrieve() for one fully-specified identifier.
    pub async fn retrieve(&mut self, id: &Key) -> Result<Option<DataHandle>, super::FdbError> {
        let (ds, colloc, elem) = self.schema.split(id)?;
        let t0 = self.sim.now();
        // hash-OID fast path (thesis §3.1.2 optimisation): a Store that
        // derives placement from identifiers bypasses the Catalogue
        let loc = if self.store.direct_retrieve_enabled() {
            self.store.retrieve_direct(&ds, id).await
        } else {
            self.catalogue.retrieve(&ds, &colloc, &elem, id).await
        };
        self.account(OpClass::IndexRead, t0);
        // not finding a field is NOT an error (cache use-case, §2.7.1)
        Ok(loc.map(|l| DataHandle::from_location(&l)))
    }

    /// Batched retrieve+read: Catalogue lookups stream into Store reads
    /// through an in-process pipe, so the lookup for `ids[i+1]` overlaps
    /// the data read for `ids[i]` in virtual time. (The pipe is
    /// unbounded: handles are tiny descriptors, so at most `ids.len()`
    /// of them queue if lookups outpace reads.) Returns the found
    /// `(identifier, bytes)` pairs in input order; absent fields are
    /// skipped (cache semantics, like [`Fdb::retrieve`]).
    ///
    /// At [`IoProfile::depth`] > 1 the Store half fans out over client
    /// sessions: up to `depth` data reads in flight behind the pipelined
    /// lookups, results re-ordered to input order — the intra-store read
    /// parallelism the serial pipe cannot express.
    ///
    /// With [`IoProfile::coalesce_gap`] > 0 the read planner takes over
    /// instead (`retrieve_coalesced`): adjacent fields merge into large
    /// ranged I/Os, byte- and order-identical output, fewer ops.
    pub async fn retrieve_many(
        &mut self,
        ids: &[Key],
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let mut split = Vec::with_capacity(ids.len());
        for id in ids {
            split.push(self.schema.split(id)?);
        }
        let fanout = self.ensure_sessions();
        if self.store.direct_retrieve_enabled() {
            if fanout {
                return self.engine.direct_batch(ids, &split).await;
            }
            // direct mode: the Store serves the lookups too, so lookup
            // and read contend for the same client — run sequentially
            let mut out = Vec::new();
            for (id, (ds, _, _)) in ids.iter().zip(&split) {
                let t0 = self.sim.now();
                let loc = self.store.retrieve_direct(ds, id).await;
                self.account(OpClass::IndexRead, t0);
                if let Some(loc) = loc {
                    let h = DataHandle::from_location(&loc);
                    let checks = Self::whole_checks(&loc);
                    let t1 = self.sim.now();
                    let bytes = self.store.read_verified(&h, &checks).await;
                    self.account(OpClass::DataRead, t1);
                    match bytes {
                        Ok(b) => out.push((id.clone(), b)),
                        Err(e) => {
                            self.note_corrupt(&e);
                            return Err(e);
                        }
                    }
                }
            }
            return Ok(out);
        }
        if self.io.coalesce_enabled() {
            return self.retrieve_coalesced(ids, &split, fanout).await;
        }
        if fanout {
            // catalogue sessions (where the backend supports them) let
            // the lookups themselves run at depth; without them the
            // engine falls back to one serial lookup client like the
            // pipe path
            self.engine.ensure_cat_sessions(self.catalogue.as_mut());
            return self
                .engine
                .retrieve_batch(self.catalogue.as_mut(), ids, &split)
                .await;
        }
        let pipe: Pipe<(Key, DataHandle, Vec<RangeCheck>)> = Pipe::new();
        let out: RefCell<Vec<(Key, Bytes)>> = RefCell::new(Vec::new());
        let failed: Cell<Option<super::FdbError>> = Cell::new(None);
        let lock_total: Cell<SimTime> = Cell::new(SimTime::ZERO);
        let sim = self.sim.clone();
        let trace = self.trace.clone();
        // split borrows: the Catalogue drives lookups while the Store
        // serves reads — the two halves of the pipeline. Lock time is
        // drained per op (like `account`) so the IndexRead/DataRead
        // spans exclude it and it is recorded once under Lock.
        let store_name = self.store.name();
        let cat_name = self.catalogue.name();
        let slow_op_ns = self.slow_op_ns;
        let store = &mut self.store;
        let catalogue = &mut self.catalogue;
        let metrics = &self.metrics;
        let registry = &self.registry;
        let lookups = async {
            for (id, (ds, colloc, elem)) in ids.iter().zip(&split) {
                let t0 = sim.now();
                let loc = catalogue.retrieve(ds, colloc, elem, id).await;
                let lock = catalogue.take_lock_time();
                lock_total.set(lock_total.get() + lock);
                trace.record(OpClass::IndexRead, sim.now() - t0 - lock);
                if let Some(m) = metrics {
                    m.probe(OpClass::IndexRead)
                        .service
                        .observe_duration(sim.now() - t0 - lock);
                }
                if let Some(reg) = registry {
                    reg.record_span(0, OpClass::IndexRead.label(), t0, sim.now());
                    if slow_op_ns > 0 && (sim.now() - t0).as_nanos() >= slow_op_ns {
                        reg.record_slow_op(OpClass::IndexRead, cat_name, sim.now() - t0);
                    }
                }
                if let Some(loc) = loc {
                    let checks = Self::whole_checks(&loc);
                    pipe.push((id.clone(), DataHandle::from_location(&loc), checks));
                }
            }
            pipe.close();
        };
        let reads = async {
            while let Some((id, handle, checks)) = pipe.pop().await {
                let t0 = sim.now();
                match store.read_verified(&handle, &checks).await {
                    Ok(bytes) => {
                        let lock = store.take_lock_time();
                        lock_total.set(lock_total.get() + lock);
                        trace.record(OpClass::DataRead, sim.now() - t0 - lock);
                        if let Some(m) = metrics {
                            m.probe(OpClass::DataRead)
                                .service
                                .observe_duration(sim.now() - t0 - lock);
                            m.probe(OpClass::DataRead).ok.inc();
                            m.bytes_read.add(bytes.len());
                        }
                        if let Some(reg) = registry {
                            reg.record_span(1, OpClass::DataRead.label(), t0, sim.now());
                            if slow_op_ns > 0 && (sim.now() - t0).as_nanos() >= slow_op_ns {
                                reg.record_slow_op(OpClass::DataRead, store_name, sim.now() - t0);
                            }
                        }
                        out.borrow_mut().push((id, bytes));
                    }
                    Err(e) => {
                        if let Some(m) = metrics {
                            if is_injected_fault(&e) {
                                m.probe(OpClass::DataRead).fault.inc();
                            } else {
                                m.probe(OpClass::DataRead).err.inc();
                            }
                        }
                        if let (Some(reg), super::FdbError::Corrupt { .. }) =
                            (registry.as_ref(), &e)
                        {
                            reg.counter("integrity.corrupt").inc();
                        }
                        failed.set(Some(e));
                        break;
                    }
                }
            }
        };
        join_all(vec![boxed(lookups), boxed(reads)]).await;
        let lock = lock_total.get();
        if lock > SimTime::ZERO {
            self.trace.record(OpClass::Lock, lock);
            if let Some(m) = &self.metrics {
                m.probe(OpClass::Lock).service.observe_duration(lock);
            }
        }
        if let Some(e) = failed.take() {
            return Err(e);
        }
        Ok(out.into_inner())
    }

    /// [`Fdb::retrieve_many`] with the read planner on
    /// ([`IoProfile::coalesce_gap`] > 0): merge adjacent fields into
    /// large ranged I/Os, byte- and order-identical to the uncoalesced
    /// paths — only the op count (and so the virtual time) changes.
    ///
    /// At depth 1: resolve every location first, build a [`ReadPlan`],
    /// and issue the whole plan as a single vectored
    /// [`Store::read_ranges`] batch — a bare POSIX/RADOS store then
    /// resolves each container (file descriptor, pool handle) once for
    /// the batch, while wrappers route range by range by design (tiered
    /// per minting tier, replicated per read policy).
    ///
    /// At depth > 1 the engine runs **streaming plan execution**
    /// ([`IoEngine::retrieve_streaming`]): catalogue resolution (at
    /// depth when the backend supports catalogue sessions), an
    /// incremental planner that seals merged ranges as soon as each
    /// container's location run closes, and range workers that start
    /// issuing sealed ranges while later lookups are still in flight —
    /// resolve overlaps execute instead of forming a barrier. Merged
    /// ranges — not raw fields — stay the unit of in-flight admission.
    async fn retrieve_coalesced(
        &mut self,
        ids: &[Key],
        split: &[(Key, Key, Key)],
        fanout: bool,
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let n = ids.len();
        let out = if fanout {
            self.engine.ensure_cat_sessions(self.catalogue.as_mut());
            let (out, stats) = self
                .engine
                .retrieve_streaming(
                    self.catalogue.as_mut(),
                    ids,
                    split,
                    self.io.coalesce_gap,
                    self.io.coalesce_max,
                )
                .await?;
            self.absorb_plan_stats(stats);
            out
        } else {
            // catalogue phase: serial lookups on the one index client,
            // accounted per op like the legacy paths
            let mut located: Vec<(usize, FieldLocation)> = Vec::new();
            for (i, (id, (ds, colloc, elem))) in ids.iter().zip(split).enumerate() {
                let t0 = self.sim.now();
                let loc = self.catalogue.retrieve(ds, colloc, elem, id).await;
                self.account(OpClass::IndexRead, t0);
                if let Some(loc) = loc {
                    located.push((i, loc));
                }
            }
            let plan = ReadPlan::build(&located, self.io.coalesce_gap, self.io.coalesce_max);
            self.absorb_plan_stats(plan.stats);
            // the whole plan as ONE vectored batch: a bare backend
            // resolves each container (fd, ioctx) once across every
            // merged range (wrappers route per range by design)
            let mut out: Vec<Option<Bytes>> = (0..n).map(|_| None).collect();
            if !plan.reads.is_empty() {
                let handles: Vec<DataHandle> =
                    plan.reads.iter().map(|pr| pr.handle.clone()).collect();
                let checks: Vec<Vec<RangeCheck>> =
                    plan.reads.iter().map(|pr| pr.checks()).collect();
                let t0 = self.sim.now();
                let r = self.store.read_ranges_verified(&handles, &checks).await;
                self.account(OpClass::DataRead, t0);
                let r = match r {
                    Ok(r) => r,
                    Err(e) => {
                        self.note_corrupt(&e);
                        return Err(e);
                    }
                };
                for (pr, buf) in plan.reads.iter().zip(r) {
                    for &(idx, rel, len) in &pr.fields {
                        out[idx] = Some(buf.slice(rel, len));
                    }
                }
            }
            out
        };
        Ok(ids
            .iter()
            .zip(out)
            .filter_map(|(id, b)| b.map(|b| (id.clone(), b)))
            .collect())
    }

    /// Expand a request's wildcard dimensions from the axes.
    async fn expand_request(
        &mut self,
        request: &Request,
    ) -> Result<Vec<Key>, super::FdbError> {
        let mut request = request.clone();
        let wildcards = request.wildcards();
        if !wildcards.is_empty() {
            // need dataset+colloc keys from the fixed part
            let fixed = request.fixed_key();
            let ds = fixed
                .project(&self.schema.dataset)
                .ok_or(super::FdbError::UnderspecifiedRequest)?;
            let colloc = fixed
                .project(&self.schema.collocation)
                .ok_or(super::FdbError::UnderspecifiedRequest)?;
            for dim in wildcards {
                let vals = self.axes(&ds, &colloc, &dim).await;
                request.bind(&dim, vals);
            }
        }
        Ok(request.expand())
    }

    /// FDB retrieve() for a (possibly multi-valued) request: expands via
    /// axis(), retrieves every identifier, merges the handles.
    pub async fn retrieve_request(
        &mut self,
        request: &Request,
    ) -> Result<Vec<DataHandle>, super::FdbError> {
        let mut handles = Vec::new();
        for id in self.expand_request(request).await? {
            if let Some(h) = self.retrieve(&id).await? {
                handles.push(h);
            }
        }
        Ok(DataHandle::merge_all(handles))
    }

    /// Streaming request retrieval: wildcard expansion, then the
    /// pipelined [`Fdb::retrieve_many`] path (lookups overlap reads).
    pub async fn retrieve_request_streaming(
        &mut self,
        request: &Request,
    ) -> Result<Vec<(Key, Bytes)>, super::FdbError> {
        let ids = self.expand_request(request).await?;
        self.retrieve_many(&ids).await
    }

    /// Catalogue axis() values for one element dimension.
    pub async fn axes(&mut self, ds: &Key, colloc: &Key, dim: &str) -> Vec<String> {
        let t0 = self.sim.now();
        let out = self.catalogue.axis(ds, colloc, dim).await;
        self.account(OpClass::IndexRead, t0);
        out
    }

    /// FDB list(): all indexed identifiers matching a partial request.
    pub async fn list(
        &mut self,
        ds: &Key,
        request: &Request,
    ) -> Vec<(Key, crate::fdb::location::FieldLocation)> {
        let t0 = self.sim.now();
        let out = self.catalogue.list(ds, request).await;
        self.account(OpClass::IndexRead, t0);
        out
    }

    /// Drop reader-side caches so later flushes become visible. Pooled
    /// catalogue sessions are dropped too — their caches are as stale as
    /// the main client's — and re-minted from the (now invalidated)
    /// catalogue on the next batched retrieve.
    pub fn invalidate_preload(&mut self, ds: &Key) {
        self.catalogue.invalidate_preload(ds);
        self.engine.clear_catalogue_sessions();
    }

    /// Read a handle's bytes through the Store. A handle minted by a
    /// different backend yields [`super::FdbError::BackendMismatch`].
    pub async fn read(&mut self, handle: &DataHandle) -> Result<Bytes, super::FdbError> {
        let t0 = self.sim.now();
        let out = self.store.read(handle).await;
        self.account(OpClass::DataRead, t0);
        out
    }

    /// Integrity-scenario hook (`fdbctl fsck` scenarios, scrub tests):
    /// direct mutable access to the backend pair, for seeding the
    /// damage classes no healthy API path produces — quarantining a
    /// live container behind the catalogue's back (ghost entries) or
    /// forgetting entries while their container stays on disk
    /// (orphaned objects).
    pub fn backend_mut(&mut self) -> (&mut dyn Store, &mut dyn Catalogue) {
        (self.store.as_mut(), self.catalogue.as_mut())
    }

    /// Online scrub (`fdbctl fsck`): cross-check the catalogue against
    /// the store in both directions.
    ///
    /// Catalogue → store: every listed entry's physical copies are
    /// probed for existence, length, and (when the entry carries one)
    /// content checksum — an entry with no readable copy is a *ghost*,
    /// one with damaged copies is *corrupt*. Store → catalogue: the
    /// store's container inventory (where the backend can enumerate,
    /// see [`Store::scrub_inventory`]) is matched against the listed
    /// locations — unreferenced containers are *orphans*.
    ///
    /// With `repair`: damaged copies are rewritten from a verified
    /// replica (inside [`Store::scrub_field`]), ghost entries are
    /// dropped from the catalogue ([`Catalogue::forget`]), and orphaned
    /// objects are quarantined out of the data path. A converged repair
    /// pass ([`FsckReport::converged`]) leaves the next fsck clean.
    pub async fn fsck(
        &mut self,
        ds: &Key,
        repair: bool,
    ) -> Result<FsckReport, super::FdbError> {
        let mut report = FsckReport::default();
        let entries = self.list(ds, &Request::default()).await;
        let mut referenced: std::collections::BTreeSet<String> =
            std::collections::BTreeSet::new();
        let t0 = self.sim.now();
        let mut scrubbed: Result<(), super::FdbError> = Ok(());
        for (id, loc) in &entries {
            report.entries += 1;
            referenced.insert(loc.container_uri());
            let ck = loc.checksum();
            if ck.is_some() {
                report.verified += 1;
            }
            let handle = DataHandle::from_location(loc);
            let outcome = match self.store.scrub_field(&handle, loc.length(), ck, repair).await
            {
                Ok(o) => o,
                Err(e) => {
                    scrubbed = Err(e);
                    break;
                }
            };
            report.absorb(&outcome);
            let is_ghost = outcome.copies > 0 && outcome.missing == outcome.copies;
            if is_ghost && repair {
                let (_, colloc, elem) = self.schema.split(id)?;
                if self.catalogue.forget(ds, &colloc, &elem, id).await? {
                    report.ghosts_dropped += 1;
                }
            }
        }
        self.account(OpClass::DataRead, t0);
        scrubbed?;
        // store → catalogue: anything on disk no entry points at
        let t1 = self.sim.now();
        let inventory = self.store.scrub_inventory(ds).await;
        if let Some(inventory) = inventory {
            for (container, _len) in inventory {
                if referenced.contains(&container) {
                    continue;
                }
                report.orphans += 1;
                if repair && self.store.quarantine_object(ds, &container).await? {
                    report.orphans_quarantined += 1;
                }
            }
        }
        self.account(OpClass::DataRead, t1);
        if repair && report.ghosts_dropped > 0 {
            // persist the tombstones forget() appended and drop reader
            // caches so the masked entries disappear from this client
            let t2 = self.sim.now();
            let flushed = self.catalogue.flush().await;
            self.account(OpClass::Flush, t2);
            flushed?;
            self.invalidate_preload(ds);
        }
        if let Some(reg) = &self.registry {
            reg.counter("integrity.fsck_runs").inc();
            reg.counter("integrity.fsck_ghosts").add(report.ghosts);
            reg.counter("integrity.fsck_orphans").add(report.orphans);
            reg.counter("integrity.fsck_corrupt").add(report.corrupt);
            reg.counter("integrity.fsck_repaired").add(report.repaired);
        }
        Ok(report)
    }

    /// Remove a dataset wholesale (fdb-wipe). Returns whether anything
    /// was removed. One Store wipe + one Catalogue deregistration —
    /// DAOS: a single `daos_cont_destroy` (the container-per-dataset
    /// argument); RADOS: per-object deletes in the dataset namespace;
    /// POSIX: unlink of the dataset directory's files. A strict no-op
    /// on Stores without wipe support (S3, Null): deregistering the
    /// catalogue while the data survives would orphan live objects.
    pub async fn wipe(&mut self, ds: &Key) -> bool {
        if !self.store.supports_wipe() {
            return false;
        }
        let removed = self.store.wipe_dataset(ds).await;
        // sessions wipe too: that purges their per-dataset client state
        // (open data files, absorbed-but-unspilled tiered fields) for
        // `ds` only — state for OTHER datasets must survive exactly as
        // it does at depth 1. The main store already unlinked the files,
        // so session wipes find nothing on disk.
        self.engine.wipe_store_sessions(ds).await;
        self.catalogue.deregister_dataset(ds).await;
        removed
    }
}

