//! [`ShardedCatalogue`]: hash-partitions the index network across N
//! inner Catalogues keyed on the collocation key (arXiv:2208.06752's
//! distributed index-KV design).

use crate::fdb::backend::{Catalogue, LocalBoxFuture};
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::request::Request;
use crate::fdb::FdbError;
use crate::sim::time::SimTime;

/// A hash-partitioned Catalogue. `archive()`/`retrieve()` route to the
/// shard owning the collocation key, so index traffic for different
/// collocations lands on different inner catalogues (different servers
/// in a real deployment). `axis()` and `list()` fan out to every shard
/// and merge: axis values union (sorted, deduplicated), listings dedup
/// per identifier — so inner catalogues that happen to share a
/// persistent namespace still produce exactly one entry per field.
pub struct ShardedCatalogue {
    shards: Vec<Box<dyn Catalogue>>,
}

impl ShardedCatalogue {
    /// `shards` must be non-empty; the builder validates `shards >= 1`
    /// before constructing one.
    pub fn new(shards: Vec<Box<dyn Catalogue>>) -> ShardedCatalogue {
        assert!(!shards.is_empty(), "ShardedCatalogue needs >= 1 shard");
        ShardedCatalogue { shards }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning a collocation key (stable hash partition).
    pub fn shard_of(&self, colloc: &Key) -> usize {
        (crate::ceph::hash_name(&colloc.canonical()) % self.shards.len() as u64) as usize
    }
}

impl Catalogue for ShardedCatalogue {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
        loc: &'a FieldLocation,
    ) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        let shard = self.shard_of(colloc);
        self.shards[shard].archive(ds, colloc, elem, id, loc)
    }

    fn forget<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        // same routing as archive: the shard owning the collocation
        // holds the entry an fsck ghost-drop removes
        let shard = self.shard_of(colloc);
        self.shards[shard].forget(ds, colloc, elem, id)
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            for shard in &mut self.shards {
                shard.flush().await?;
            }
            Ok(())
        })
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::CatalogueSession>> {
        // a session is a sharded catalogue of every shard's session —
        // routing is pure hashing, so the composed session resolves each
        // lookup on the same shard the main client would. All-or-nothing:
        // one session-less shard would silently re-route its slice to a
        // mismatched client, so we decline instead.
        let mut shards = Vec::with_capacity(self.shards.len());
        for shard in &mut self.shards {
            shards.push(shard.session()?.into_catalogue());
        }
        Some(Box::new(ShardedCatalogue::new(shards)))
    }

    fn begin_archive_group(&mut self) {
        for shard in &mut self.shards {
            shard.begin_archive_group();
        }
    }

    fn end_archive_group<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            // barrier every shard even if an earlier one fails: each
            // holds un-synced intents for its own slice of the batch
            let mut first_err = Ok(());
            for shard in &mut self.shards {
                let r = shard.end_archive_group().await;
                if first_err.is_ok() {
                    first_err = r;
                }
            }
            first_err
        })
    }

    fn close<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            for shard in &mut self.shards {
                shard.close().await?;
            }
            Ok(())
        })
    }

    fn recover_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> LocalBoxFuture<'a, Result<crate::fdb::fault::RecoveryStats, FdbError>> {
        Box::pin(async move {
            // every shard may hold WALs for its slice of the collocations
            let mut stats = crate::fdb::fault::RecoveryStats::default();
            for shard in &mut self.shards {
                stats.merge(&shard.recover_dataset(ds).await?);
            }
            Ok(stats)
        })
    }

    fn retrieve<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        let shard = self.shard_of(colloc);
        self.shards[shard].retrieve(ds, colloc, elem, id)
    }

    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> LocalBoxFuture<'a, Vec<String>> {
        Box::pin(async move {
            // collect every shard's values, then sort + dedup ONCE at
            // the end (per-shard ordered-set maintenance re-sorted the
            // accumulated result on every shard merge)
            let mut vals = Vec::new();
            for shard in &mut self.shards {
                vals.extend(shard.axis(ds, colloc, dim).await);
            }
            vals.sort_unstable();
            vals.dedup();
            vals
        })
    }

    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> LocalBoxFuture<'a, Vec<(Key, FieldLocation)>> {
        Box::pin(async move {
            // collect across shards, then one stable sort + dedup pass:
            // per identifier the LOWEST shard wins, so inner catalogues
            // that share a persistent namespace still produce exactly
            // one entry per field, in deterministic key order
            let mut all: Vec<(usize, Key, FieldLocation)> = Vec::new();
            for (si, shard) in self.shards.iter_mut().enumerate() {
                for (id, loc) in shard.list(ds, request).await {
                    all.push((si, id, loc));
                }
            }
            all.sort_by(|a, b| a.1.cmp(&b.1).then(a.0.cmp(&b.0)));
            all.dedup_by(|next, kept| next.1 == kept.1);
            all.into_iter().map(|(_, id, loc)| (id, loc)).collect()
        })
    }

    fn invalidate_preload(&mut self, ds: &Key) {
        for shard in &mut self.shards {
            shard.invalidate_preload(ds);
        }
    }

    fn deregister_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, ()> {
        Box::pin(async move {
            for shard in &mut self.shards {
                shard.deregister_dataset(ds).await;
            }
        })
    }

    fn take_lock_time(&self) -> SimTime {
        self.shards
            .iter()
            .map(|s| s.take_lock_time())
            .fold(SimTime::ZERO, |a, b| a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullCatalogue, SharedNullCatalogue};
    use std::collections::BTreeSet;

    fn sharded(n: usize) -> ShardedCatalogue {
        ShardedCatalogue::new(
            (0..n)
                .map(|_| Box::new(NullCatalogue::new()) as Box<dyn Catalogue>)
                .collect(),
        )
    }

    fn loc(n: u64) -> FieldLocation {
        FieldLocation::Null { length: n }
    }

    #[test]
    fn routes_by_collocation_and_merges_listings() {
        let mut cat = sharded(4);
        let ds = Key::of(&[("class", "od")]);
        // distinct collocations spread over shards; every entry must be
        // retrievable and listed exactly once
        let mut ids = Vec::new();
        for step in 1..=12u32 {
            let colloc = Key::of(&[("class", "od"), ("step", &step.to_string())]);
            let id = colloc.clone().with("param", "p0");
            block_on(cat.archive(&ds, &colloc, &id, &id, &loc(step as u64))).unwrap();
            ids.push((colloc, id));
        }
        for (colloc, id) in &ids {
            let got = block_on(cat.retrieve(&ds, colloc, id, id));
            assert!(got.is_some(), "missing {id}");
        }
        let listed = block_on(cat.list(&ds, &Request::parse("").unwrap()));
        assert_eq!(listed.len(), ids.len());
        // axis merges across shards: 12 distinct steps
        let axis = block_on(cat.axis(&ds, &Key::new(), "step"));
        assert_eq!(axis.len(), 12);
        // actually partitioned: with 12 collocations over 4 shards at
        // least two shards must own entries
        let routes: BTreeSet<usize> = ids.iter().map(|(c, _)| cat.shard_of(c)).collect();
        assert!(routes.len() >= 2, "hash routing collapsed to one shard");
    }

    #[test]
    fn duplicate_keys_across_shards_surface_exactly_once() {
        // two shards backed by ONE shared namespace: every archived
        // entry is reported by both shards, the worst case the dedup
        // pass must collapse. Regression for the cross-shard merge.
        let shared = SharedNullCatalogue::new();
        let mut cat = ShardedCatalogue::new(vec![
            Box::new(shared.clone()),
            Box::new(shared.clone()),
        ]);
        let ds = Key::of(&[("class", "od")]);
        for step in 1..=5u32 {
            let colloc = Key::of(&[("class", "od"), ("step", &step.to_string())]);
            let id = colloc.clone().with("param", "p0");
            block_on(cat.archive(&ds, &colloc, &id, &id, &loc(step as u64))).unwrap();
        }
        let listed = block_on(cat.list(&ds, &Request::parse("").unwrap()));
        assert_eq!(listed.len(), 5, "each duplicated key must appear once");
        // deterministic key order, no adjacent duplicates
        for w in listed.windows(2) {
            assert!(w[0].0 < w[1].0, "listing must stay strictly sorted");
        }
        let axis = block_on(cat.axis(&ds, &Key::new(), "step"));
        assert_eq!(axis, vec!["1", "2", "3", "4", "5"]);
    }

    #[test]
    fn deregister_spans_all_shards() {
        let mut cat = sharded(3);
        let ds = Key::of(&[("class", "od")]);
        for step in 1..=6u32 {
            let colloc = Key::of(&[("class", "od"), ("step", &step.to_string())]);
            let id = colloc.clone().with("param", "p0");
            block_on(cat.archive(&ds, &colloc, &id, &id, &loc(1))).unwrap();
        }
        block_on(cat.deregister_dataset(&ds));
        let listed = block_on(cat.list(&ds, &Request::parse("").unwrap()));
        assert!(listed.is_empty());
    }
}
