//! [`TieredStore`]: a fast front Store absorbing writes ahead of a
//! backing object Store (SCM/NVMe burst-buffer pattern, arXiv:2404.03107).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::fdb::backend::{LocalBoxFuture, Store, StoreSession};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::scrub::RangeCheck;
use crate::fdb::FdbError;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

/// A two-tier Store. `archive()` lands in the fast front tier only (and
/// the returned location — what the Catalogue indexes — points there);
/// `flush()` first writes every absorbed field through to the backing
/// tier, then flushes both tiers, so a flush leaves the data durable in
/// the back store as well. `read()` serves a handle from whichever tier
/// minted it: the front is tried first and a
/// [`FdbError::BackendMismatch`] falls through to the back, so handles
/// from either tier resolve.
pub struct TieredStore {
    front: Box<dyn Store>,
    back: Box<dyn Store>,
    /// fields absorbed since the last flush, pending write-through —
    /// each with the front location the Catalogue indexed, so the spill
    /// can record where the back-tier copy of that entry landed
    pending: Vec<(Key, Key, Key, Bytes, FieldLocation)>,
    /// spill-time back-tier locations, keyed by the front handle (the
    /// one the Catalogue references) — the map scrub repair uses to
    /// reach the redundant write-through copy. Shared with sessions so
    /// engine-lane spills record here too; a fresh process starts empty
    /// and a damaged front copy is then detect-only.
    spilled: Rc<RefCell<BTreeMap<String, FieldLocation>>>,
}

impl TieredStore {
    pub fn new(front: Box<dyn Store>, back: Box<dyn Store>) -> TieredStore {
        TieredStore {
            front,
            back,
            pending: Vec::new(),
            spilled: Rc::new(RefCell::new(BTreeMap::new())),
        }
    }

    /// Fields absorbed by the front tier and not yet written through.
    pub fn pending_fields(&self) -> usize {
        self.pending.len()
    }

    /// The spill map key for one field: the front handle in debug form
    /// (deterministic, checksum-free — [`DataHandle::from_location`]
    /// drops the checksum, so keys built from a bare archive return and
    /// from a checksummed catalogue entry agree).
    fn loc_key(handle: &DataHandle) -> String {
        format!("{handle:?}")
    }

    /// Write every absorbed field through to the backing tier. On a
    /// back-tier error the failed field and everything after it stay
    /// pending, so a later flush retries them.
    async fn spill(&mut self) -> Result<(), FdbError> {
        let pending = std::mem::take(&mut self.pending);
        for (i, (ds, colloc, id, data, front_loc)) in pending.iter().enumerate() {
            match self.back.archive(ds, colloc, id, data.clone()).await {
                Ok(back_loc) => {
                    let key = Self::loc_key(&DataHandle::from_location(front_loc));
                    self.spilled.borrow_mut().insert(key, back_loc);
                }
                Err(e) => {
                    self.pending = pending[i..].to_vec();
                    return Err(e);
                }
            }
        }
        Ok(())
    }
}

impl Store for TieredStore {
    fn name(&self) -> &'static str {
        "tiered"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        Box::pin(async move {
            let loc = self.front.archive(ds, colloc, id, data.clone()).await?;
            self.pending
                .push((ds.clone(), colloc.clone(), id.clone(), data, loc.clone()));
            Ok(loc)
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            self.spill().await?;
            self.front.flush().await?;
            self.back.flush().await
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(async move {
            match self.front.read(handle).await {
                Err(FdbError::BackendMismatch { .. }) => self.back.read(handle).await,
                other => other,
            }
        })
    }

    /// Vectored reads route each merged range to the tier that minted
    /// its locations: the front is tried first and a
    /// [`FdbError::BackendMismatch`] falls through to the back, range by
    /// range, so one plan may span both tiers.
    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            let mut out = Vec::with_capacity(handles.len());
            for handle in handles {
                let one = std::slice::from_ref(handle);
                match self.front.read_ranges(one).await {
                    Err(FdbError::BackendMismatch { .. }) => {
                        out.extend(self.back.read_ranges(one).await?)
                    }
                    other => out.extend(other?),
                }
            }
            Ok(out)
        })
    }

    /// Repair routes like `read`: the front is tried first and a
    /// [`FdbError::BackendMismatch`] (or an inability to rewrite) falls
    /// through to the back, so a damaged copy is rewritten in whichever
    /// tier minted its handle.
    fn repair<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        Box::pin(async move {
            match self.front.repair(handle, data.clone()).await {
                Ok(true) => Ok(true),
                Ok(false) | Err(FdbError::BackendMismatch { .. }) => {
                    self.back.repair(handle, data).await
                }
                Err(e) => Err(e),
            }
        })
    }

    /// Scrub probes the FRONT tier: every catalogue entry points at the
    /// location the front minted at archive time, so the bytes an entry
    /// references live there. With `do_repair`, a damaged front copy is
    /// rewritten from the back tier's write-through copy (located via
    /// the spill map, read verified) — the spill is exactly the
    /// redundant copy a burst buffer repairs from.
    fn scrub_field<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        expect_len: u64,
        ck: Option<u64>,
        do_repair: bool,
    ) -> LocalBoxFuture<'a, Result<crate::fdb::scrub::ScrubOutcome, FdbError>> {
        Box::pin(async move {
            let mut out = self.front.scrub_field(handle, expect_len, ck, false).await?;
            if do_repair && (out.missing > 0 || out.corrupt > 0) {
                let back_loc = self.spilled.borrow().get(&Self::loc_key(handle)).cloned();
                if let Some(back_loc) = back_loc {
                    let checks: Vec<RangeCheck> = ck
                        .map(|c| vec![RangeCheck::whole(expect_len, c)])
                        .unwrap_or_default();
                    let bh = DataHandle::from_location(&back_loc);
                    // the repair source must itself verify before it is
                    // written back over the damaged front copy
                    if let Ok(good) = self.back.read_verified(&bh, &checks).await {
                        if good.len() == expect_len
                            && matches!(self.front.repair(handle, good).await, Ok(true))
                        {
                            out.repaired += 1;
                        }
                    }
                }
            }
            Ok(out)
        })
    }

    /// Inventory covers the FRONT tier only — the catalogue references
    /// front containers, so back-tier objects would all read as orphans.
    fn scrub_inventory<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> LocalBoxFuture<'a, Option<Vec<(String, u64)>>> {
        self.front.scrub_inventory(ds)
    }

    fn quarantine_object<'a>(
        &'a mut self,
        ds: &'a Key,
        container: &'a str,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        self.front.quarantine_object(ds, container)
    }

    /// Direct (catalogue-bypassing) retrieval is forwarded from the
    /// FRONT tier only: every archived field lands there first, so a
    /// direct-capable front resolves unflushed fields too. A
    /// direct-capable back alone stays on the catalogue path — the back
    /// tier only sees fields after flush, so bypassing the catalogue
    /// through it would lose unspilled fields.
    fn direct_retrieve_enabled(&self) -> bool {
        self.front.direct_retrieve_enabled()
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        self.front.retrieve_direct(ds, id)
    }

    /// Wipe needs both tiers to support it: removing only one tier's
    /// copy while the Catalogue deregisters would orphan the other.
    fn supports_wipe(&self) -> bool {
        self.front.supports_wipe() && self.back.supports_wipe()
    }

    fn wipe_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        Box::pin(async move {
            self.pending.retain(|(d, _, _, _, _)| d != ds);
            let front = self.front.wipe_dataset(ds).await;
            let back = self.back.wipe_dataset(ds).await;
            front || back
        })
    }

    fn take_lock_time(&self) -> SimTime {
        self.front.take_lock_time() + self.back.take_lock_time()
    }

    fn session(&mut self) -> Option<Box<dyn crate::fdb::backend::StoreSession>> {
        // a tiered session pairs sessions of both tiers; its absorbed
        // fields spill through its own back session on (Fdb-driven)
        // session flush — into the SHARED spill map, so scrub repair
        // reaches engine-lane spills too
        let front = self.front.session()?.into_store();
        let back = self.back.session()?.into_store();
        let mut session = TieredStore::new(front, back);
        session.spilled = self.spilled.clone();
        Some(Box::new(session))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullStore};

    #[test]
    fn absorbs_until_flush_then_spills() {
        let mut tiered = TieredStore::new(Box::new(NullStore), Box::new(NullStore));
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        let loc = block_on(tiered.archive(&ds, &ds, &id, Bytes::virt(128, 7))).unwrap();
        assert_eq!(loc.length(), 128);
        assert_eq!(tiered.pending_fields(), 1);
        block_on(tiered.flush()).unwrap();
        assert_eq!(tiered.pending_fields(), 0);
    }

    #[test]
    fn reads_fall_through_to_back_tier() {
        // front is Null; a posix handle mismatches it, and the back tier
        // (also Null here) mismatches too → the back tier's typed error
        let mut tiered = TieredStore::new(Box::new(NullStore), Box::new(NullStore));
        let null_handle = DataHandle::Null { length: 16 };
        assert_eq!(block_on(tiered.read(&null_handle)).unwrap().len(), 16);
        let posix_handle = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        let err = block_on(tiered.read(&posix_handle)).unwrap_err();
        assert!(matches!(err, FdbError::BackendMismatch { .. }));
    }
}
