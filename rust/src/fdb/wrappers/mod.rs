//! Composable backend wrappers: Store/Catalogue impls that wrap *other*
//! Store/Catalogue impls instead of talking to a storage system
//! directly. They are the follow-on the trait split (PR 1) was built
//! for — each scaling construct in the companion papers is one wrapper:
//!
//! * [`TieredStore`] — an SCM/NVMe front tier absorbs bursty NWP
//!   `archive()` writes ahead of a slower backing object store and
//!   writes them through on `flush()` (the burst-buffer pattern of
//!   arXiv:2404.03107). Reads are served from whichever tier minted the
//!   handle.
//! * [`ReplicatedStore`] — fan-out writes to N replica Stores, reads
//!   balanced over healthy replicas by a [`ReadPolicy`] (round-robin by
//!   default; `FirstHealthy` keeps the old primary-only behaviour;
//!   `Fastest` routes by a per-replica EWMA of observed read latency),
//!   with a typed [`FdbError::AllReplicasFailed`](crate::fdb::FdbError)
//!   when every replica rejects the handle.
//!
//! All three compose with the vectored read planner
//! ([`crate::fdb::plan`]): tiered stores route each merged range to the
//! tier that minted it, replicated stores apply their [`ReadPolicy`]
//! per merged range, and the sharded catalogue is pass-through on the
//! store side.
//! * [`ShardedCatalogue`] — hash-partitions the index network across N
//!   inner Catalogues keyed on the collocation key (the distributed
//!   index-KV design DAOS demonstrated over Lustre, arXiv:2208.06752);
//!   `list()`/`axis()` merge across shards with per-identifier dedup.
//!
//! Wrappers compose recursively through
//! [`BackendConfig`](crate::fdb::BackendConfig): a tiered store over a
//! replicated RADOS store with a sharded catalogue is
//! `Sharded { inner: Tiered { front, back: Replicated { .. } }, .. }`.
//! [`FdbBuilder::build`](crate::fdb::FdbBuilder) validates and wires the
//! whole tree; benches sweep the wrappers via
//! [`WrapperOpt`](crate::bench::scenario::WrapperOpt).

pub mod replicated;
pub mod sharded;
pub mod tiered;

pub use replicated::{ReadPolicy, ReplicatedStore};
pub use sharded::ShardedCatalogue;
pub use tiered::TieredStore;
