//! [`ReplicatedStore`]: fan-out writes to N replica Stores, reads
//! balanced across healthy replicas by a [`ReadPolicy`].

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll};

use crate::fdb::backend::{LocalBoxFuture, Store, StoreSession};
use crate::fdb::builder::ResilienceProfile;
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::scrub::{verify_ranges, RangeCheck, ScrubOutcome};
use crate::fdb::telemetry::{Counter, MetricsRegistry};
use crate::fdb::FdbError;
use crate::sim::exec::{Sim, Sleep};
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

/// Where a replicated read starts probing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadPolicy {
    /// Always probe replica 0 first — the original behaviour; keeps all
    /// read load on the primary.
    FirstHealthy,
    /// Rotate the starting replica per read, spreading read load evenly
    /// across healthy replicas (the default). Unhealthy replicas are
    /// skipped by falling through the rotation, so availability matches
    /// `FirstHealthy`.
    #[default]
    RoundRobin,
    /// Probe the replica with the lowest exponentially-weighted moving
    /// average of observed **per-byte** read latency (normalized so a
    /// replica that happened to serve a large coalesced range is not
    /// mistaken for a slow one; each replica is probed once to seed its
    /// estimate). Needs the store's virtual clock
    /// ([`ReplicatedStore::with_clock`], wired by the builder) to
    /// observe latencies; without one the policy degrades to probing
    /// replica 0 first. Failures fall through the ring like the other
    /// policies.
    Fastest,
}

/// EWMA smoothing for [`ReadPolicy::Fastest`] latency estimates: new
/// samples get a quarter of the weight, so a transiently slow replica
/// is not written off on one observation.
const EWMA_ALPHA: f64 = 0.25;

/// Floor of the per-byte latency sample charged to a replica whose
/// probe FAILED (seconds/byte — orders of magnitude above any healthy
/// rate). Failures must poison the estimate — a fast error (e.g. an
/// instant handle mismatch) would otherwise look like the lowest
/// latency and a dead replica would be re-probed first on every read.
/// The actual charge is `max(this, 4 × slowest SUCCESSFUL observation)`
/// — never derived from penalized estimates, so it cannot compound —
/// which keeps it above healthy reads of any size yet finite: a
/// recovered replica decays back through the EWMA once fall-through
/// probes reach it again.
const FAILURE_PENALTY: f64 = 0.01;

/// Pre-bound hedge telemetry, cloned into every session so all lanes
/// record into the same counters.
#[derive(Clone)]
struct HedgeStats {
    launched: Counter,
    won: Counter,
    wasted_bytes: Counter,
}

/// One replica's health record in the quarantine ledger.
#[derive(Clone, Copy)]
struct ReplicaHealth {
    /// consecutive read failures since the last success
    consecutive: u32,
    /// `Some(t)` = ejected from the read rotation until `t`; once `t`
    /// passes, the next read through this replica is a reinstatement
    /// probe
    quarantined_until: Option<SimTime>,
    /// current quarantine backoff (µs) — doubles on every failed probe
    backoff_us: u64,
}

/// Replica quarantine: consecutive-failure ejection from the read
/// rotation with probe-on-backoff reinstatement. Shared through an
/// `Rc<RefCell<…>>` across the parent store and every minted session
/// (replica vectors are index-aligned), so one lane discovering a dead
/// replica stops *all* lanes from routing reads to it.
struct QuarantineState {
    /// consecutive failures that trigger ejection
    after: u32,
    /// initial backoff before a reinstatement probe (µs)
    base_us: u64,
    health: Vec<ReplicaHealth>,
    ejected: Option<Counter>,
    probes: Option<Counter>,
    reinstated: Option<Counter>,
}

impl QuarantineState {
    /// Whether the read rotation should route around this replica.
    fn skip(&self, idx: usize, now: SimTime) -> bool {
        matches!(self.health[idx].quarantined_until, Some(t) if now < t)
    }

    /// Count a read issued to a quarantined replica (a reinstatement
    /// probe — either its backoff expired, or every replica is
    /// quarantined and the rotation probes them all as a last resort).
    fn mark_probe(&mut self, idx: usize) {
        if self.health[idx].quarantined_until.is_some() {
            if let Some(c) = &self.probes {
                c.inc();
            }
        }
    }

    fn note_success(&mut self, idx: usize) {
        let h = &mut self.health[idx];
        if h.quarantined_until.is_some() {
            if let Some(c) = &self.reinstated {
                c.inc();
            }
        }
        h.consecutive = 0;
        h.quarantined_until = None;
        h.backoff_us = self.base_us;
    }

    fn note_failure(&mut self, idx: usize, now: SimTime) {
        let h = &mut self.health[idx];
        if h.quarantined_until.is_some() {
            // failed reinstatement probe: relapse with doubled backoff,
            // capped so a recovered replica is never weeks away
            h.backoff_us = (h.backoff_us * 2).min(self.base_us * 10);
            h.quarantined_until = Some(now + SimTime::micros(h.backoff_us));
        } else {
            h.consecutive += 1;
            if h.consecutive >= self.after {
                h.quarantined_until = Some(now + SimTime::micros(h.backoff_us));
                if let Some(c) = &self.ejected {
                    c.inc();
                }
            }
        }
    }
}

/// One replica read as a boxed future — `read` or single-range
/// `read_ranges` (strict vectored semantics preserved).
fn read_fut<'a>(
    store: &'a mut Box<dyn Store>,
    handle: &'a DataHandle,
    vectored: bool,
) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
    if vectored {
        Box::pin(async move {
            store
                .read_ranges(std::slice::from_ref(handle))
                .await
                .map(|mut bufs| bufs.pop().expect("one buffer per handle"))
        })
    } else {
        store.read(handle)
    }
}

/// Simultaneous `&mut` access to two distinct replicas (the hedge race
/// drives both reads at once).
fn two_mut(
    v: &mut [Box<dyn Store>],
    a: usize,
    b: usize,
) -> (&mut Box<dyn Store>, &mut Box<dyn Store>) {
    assert_ne!(a, b, "hedge needs two distinct replicas");
    if a < b {
        let (lo, hi) = v.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = v.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

/// What a hedge race resolved to. Errors are carried out (not just the
/// winner) so the caller can feed every observed failure into the
/// quarantine ledger and EWMA penalties.
struct RaceResult {
    /// `(bytes, hedge_won)`; `None` = both attempts failed
    winner: Option<(Bytes, bool)>,
    hedge_launched: bool,
    primary_err: Option<FdbError>,
    hedge_err: Option<FdbError>,
}

/// The hedged-read race: drive the primary replica's read; if it is
/// still pending when the hedge timer fires — or fails outright — launch
/// the hedge attempt on the second replica and race both. First `Ok`
/// wins; the loser future is dropped (cancelled mid-flight, its backend
/// timers fire harmlessly into the sim). A loser that managed to
/// *complete* before the winner returned has fetched bytes nobody will
/// read — counted as `engine.hedge.wasted_bytes`.
struct HedgeRace<'a, F>
where
    F: FnOnce() -> LocalBoxFuture<'a, Result<Bytes, FdbError>>,
{
    primary: Option<LocalBoxFuture<'a, Result<Bytes, FdbError>>>,
    timer: Option<Sleep>,
    launch: Option<F>,
    hedge: Option<LocalBoxFuture<'a, Result<Bytes, FdbError>>>,
    primary_err: Option<FdbError>,
    hedge_err: Option<FdbError>,
    hedge_launched: bool,
    stats: Option<HedgeStats>,
}

impl<'a, F> Future for HedgeRace<'a, F>
where
    F: FnOnce() -> LocalBoxFuture<'a, Result<Bytes, FdbError>>,
{
    type Output = RaceResult;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<RaceResult> {
        // Unpin: both attempts are boxed, the timer is plain state
        let this = self.get_mut();
        if let Some(p) = this.primary.as_mut() {
            match p.as_mut().poll(cx) {
                Poll::Ready(Ok(bytes)) => {
                    // primary wins; a hedge that also completed fetched
                    // bytes nobody will read
                    if let Some(h) = this.hedge.as_mut() {
                        match h.as_mut().poll(cx) {
                            Poll::Ready(Ok(b)) => {
                                if let Some(s) = &this.stats {
                                    s.wasted_bytes.add(b.len());
                                }
                            }
                            Poll::Ready(Err(e)) => this.hedge_err = Some(e),
                            Poll::Pending => {}
                        }
                    }
                    return Poll::Ready(RaceResult {
                        winner: Some((bytes, false)),
                        hedge_launched: this.hedge_launched,
                        primary_err: this.primary_err.take(),
                        hedge_err: this.hedge_err.take(),
                    });
                }
                Poll::Ready(Err(e)) => {
                    this.primary = None;
                    this.primary_err = Some(e);
                }
                Poll::Pending => {}
            }
        }
        // launch the hedge when the timer fires — or immediately, if the
        // primary already failed
        if this.hedge.is_none() && this.hedge_err.is_none() && this.launch.is_some() {
            let fire = if this.primary.is_none() {
                this.timer = None;
                true
            } else if let Some(t) = this.timer.as_mut() {
                match Pin::new(t).poll(cx) {
                    Poll::Ready(()) => {
                        this.timer = None;
                        true
                    }
                    Poll::Pending => false,
                }
            } else {
                false
            };
            if fire {
                if let Some(launch) = this.launch.take() {
                    if let Some(s) = &this.stats {
                        s.launched.inc();
                    }
                    this.hedge_launched = true;
                    this.hedge = Some(launch());
                }
            }
        }
        if let Some(h) = this.hedge.as_mut() {
            match h.as_mut().poll(cx) {
                Poll::Ready(Ok(bytes)) => {
                    if let Some(s) = &this.stats {
                        s.won.inc();
                    }
                    // symmetric wasted-work check on the primary
                    if let Some(p) = this.primary.as_mut() {
                        match p.as_mut().poll(cx) {
                            Poll::Ready(Ok(b)) => {
                                if let Some(s) = &this.stats {
                                    s.wasted_bytes.add(b.len());
                                }
                            }
                            Poll::Ready(Err(e)) => this.primary_err = Some(e),
                            Poll::Pending => {}
                        }
                    }
                    return Poll::Ready(RaceResult {
                        winner: Some((bytes, true)),
                        hedge_launched: this.hedge_launched,
                        primary_err: this.primary_err.take(),
                        hedge_err: this.hedge_err.take(),
                    });
                }
                Poll::Ready(Err(e)) => {
                    this.hedge = None;
                    this.hedge_err = Some(e);
                }
                Poll::Pending => {}
            }
        }
        if this.primary_err.is_some() && (this.hedge_err.is_some() || this.launch.is_none()) {
            if this.hedge.is_none() {
                return Poll::Ready(RaceResult {
                    winner: None,
                    hedge_launched: this.hedge_launched,
                    primary_err: this.primary_err.take(),
                    hedge_err: this.hedge_err.take(),
                });
            }
        }
        Poll::Pending
    }
}

/// A replicating Store. `archive()` writes the field to every replica
/// and returns the primary's (replica 0's) location — that is what the
/// Catalogue indexes. `read()` probes replicas starting at the
/// [`ReadPolicy`]'s pick and returns the first healthy answer; replicas
/// whose client cannot resolve the handle report
/// [`FdbError::BackendMismatch`] and are skipped. If every replica
/// fails, the typed [`FdbError::AllReplicasFailed`] carries the replica
/// count and the last underlying error.
///
/// Two resilience mechanisms layer on top
/// ([`ReplicatedStore::with_resilience`]):
///
/// * **Hedged reads** — after `hedge_us` with no answer from the first
///   replica, a second attempt launches on the next replica in the
///   rotation; first completion wins, the loser is cancelled.
/// * **Quarantine** — replicas failing `quarantine_after` consecutive
///   reads are ejected from the rotation until a backoff expires and a
///   probe read reinstates them, so [`ReadPolicy`] variants stop
///   routing to dead replicas (the serial fall-through still works, it
///   just stops being the common path).
pub struct ReplicatedStore {
    replicas: Vec<Box<dyn Store>>,
    policy: ReadPolicy,
    /// rotation cursor for [`ReadPolicy::RoundRobin`]
    next_read: usize,
    /// virtual clock for [`ReadPolicy::Fastest`] latency observation
    clock: Option<Sim>,
    /// per-replica per-byte latency EWMA (seconds/byte); `None` = not
    /// yet measured
    ewma: Vec<Option<f64>>,
    /// slowest SUCCESSFUL sample seen (seconds/byte) — the base of
    /// the failure penalty, kept separate from `ewma` so penalized
    /// estimates never feed back into the penalty
    slowest_healthy: f64,
    /// hedged-read delay; ZERO = hedging off
    hedge: SimTime,
    /// pre-bound hedge counters (`None` = metrics off)
    hedge_stats: Option<HedgeStats>,
    /// shared replica-health ledger (`None` = quarantine off)
    quarantine: Option<Rc<RefCell<QuarantineState>>>,
    /// Archive-time per-replica locations, keyed by the primary handle
    /// — the "replica catalogue" scrub and repair use to reach the
    /// secondary copies (the Catalogue only ever indexes the primary's
    /// location). Shared with sessions so engine-lane archives record
    /// here too; a fresh process starts empty and scrub degrades to
    /// probing every replica through the primary's handle.
    replica_locs: Rc<RefCell<BTreeMap<String, Vec<FieldLocation>>>>,
    /// `integrity.replica_repaired` counter (`None` = metrics off)
    repaired: Option<Counter>,
}

impl ReplicatedStore {
    /// `replicas` must be non-empty; the builder validates `copies >= 1`
    /// before constructing one.
    pub fn new(replicas: Vec<Box<dyn Store>>) -> ReplicatedStore {
        assert!(!replicas.is_empty(), "ReplicatedStore needs >= 1 replica");
        let ewma = vec![None; replicas.len()];
        ReplicatedStore {
            replicas,
            policy: ReadPolicy::default(),
            next_read: 0,
            clock: None,
            ewma,
            slowest_healthy: 0.0,
            hedge: SimTime::ZERO,
            hedge_stats: None,
            quarantine: None,
            replica_locs: Rc::new(RefCell::new(BTreeMap::new())),
            repaired: None,
        }
    }

    /// Bind the `integrity.replica_repaired` counter (the builder
    /// passes its registry) so scrub and read-path repairs are
    /// observable.
    pub fn with_integrity(mut self, reg: Option<&MetricsRegistry>) -> ReplicatedStore {
        self.repaired = reg.map(|r| r.counter("integrity.replica_repaired"));
        self
    }

    /// The map key for one field's archive-time replica locations: the
    /// primary's handle in debug form (deterministic, checksum-free —
    /// [`DataHandle::from_location`] drops the checksum, so keys built
    /// from a bare archive return and from a checksummed catalogue
    /// entry agree).
    fn loc_key(handle: &DataHandle) -> String {
        format!("{handle:?}")
    }

    pub fn with_read_policy(mut self, policy: ReadPolicy) -> ReplicatedStore {
        self.policy = policy;
        self
    }

    /// Wire hedged reads and replica quarantine from a resilience
    /// profile; `reg` binds the `engine.hedge.*` /
    /// `replica.quarantine.*` counters (the builder passes its
    /// registry). Quarantine and hedging both need the virtual clock
    /// ([`ReplicatedStore::with_clock`], call it first); without one
    /// they stay off.
    pub fn with_resilience(
        mut self,
        res: &ResilienceProfile,
        reg: Option<&MetricsRegistry>,
    ) -> ReplicatedStore {
        if res.hedge_us > 0 {
            self.hedge = SimTime::micros(res.hedge_us);
            self.hedge_stats = reg.map(|reg| HedgeStats {
                launched: reg.counter("engine.hedge.launched"),
                won: reg.counter("engine.hedge.won"),
                wasted_bytes: reg.counter("engine.hedge.wasted_bytes"),
            });
        }
        if res.quarantine_after > 0 && self.clock.is_some() {
            self.quarantine = Some(Rc::new(RefCell::new(QuarantineState {
                after: res.quarantine_after,
                base_us: res.quarantine_backoff_us,
                health: vec![
                    ReplicaHealth {
                        consecutive: 0,
                        quarantined_until: None,
                        backoff_us: res.quarantine_backoff_us,
                    };
                    self.replicas.len()
                ],
                ejected: reg.map(|r| r.counter("replica.quarantine.ejected")),
                probes: reg.map(|r| r.counter("replica.quarantine.probes")),
                reinstated: reg.map(|r| r.counter("replica.quarantine.reinstated")),
            })));
        }
        self
    }

    /// Which replicas are currently ejected from the read rotation
    /// (diagnostics and tests). All `false` when quarantine is off.
    pub fn quarantined_now(&self) -> Vec<bool> {
        match (&self.quarantine, &self.clock) {
            (Some(q), Some(clock)) => {
                let now = clock.now();
                let q = q.borrow();
                (0..self.replicas.len()).map(|i| q.skip(i, now)).collect()
            }
            _ => vec![false; self.replicas.len()],
        }
    }

    /// Attach the virtual clock [`ReadPolicy::Fastest`] observes read
    /// latencies with (the builder wires this for every replicated
    /// config).
    pub fn with_clock(mut self, sim: &Sim) -> ReplicatedStore {
        self.clock = Some(sim.clone());
        self
    }

    pub fn read_policy(&self) -> ReadPolicy {
        self.policy
    }

    pub fn copies(&self) -> usize {
        self.replicas.len()
    }

    /// The latency estimates [`ReadPolicy::Fastest`] routes by
    /// (seconds/byte; `None` = replica not yet measured).
    pub fn latency_estimates(&self) -> &[Option<f64>] {
        &self.ewma
    }

    /// The replica a read should probe first under the active policy.
    fn read_start(&mut self) -> usize {
        match self.policy {
            ReadPolicy::FirstHealthy => 0,
            ReadPolicy::RoundRobin => {
                let start = self.next_read % self.replicas.len();
                self.next_read = self.next_read.wrapping_add(1);
                start
            }
            ReadPolicy::Fastest => {
                // probe unmeasured replicas first (seeds every estimate),
                // then the current lowest EWMA
                self.ewma
                    .iter()
                    .position(|e| e.is_none())
                    .unwrap_or_else(|| {
                        self.ewma
                            .iter()
                            .enumerate()
                            .min_by(|a, b| {
                                a.1.unwrap_or(f64::MAX).total_cmp(&b.1.unwrap_or(f64::MAX))
                            })
                            .map(|(i, _)| i)
                            .unwrap_or(0)
                    })
            }
        }
    }

    /// Fold one observed sample (seconds/byte) into a replica's EWMA.
    fn observe(&mut self, idx: usize, sample: f64) {
        self.ewma[idx] = Some(match self.ewma[idx] {
            Some(prev) => (1.0 - EWMA_ALPHA) * prev + EWMA_ALPHA * sample,
            None => sample,
        });
    }

    /// The full probe order for one read: the policy's rotation, with
    /// quarantined replicas routed around. If EVERY replica is
    /// quarantined the unfiltered rotation is used — availability
    /// degrades to the plain fall-through, never below it.
    fn probe_order(&mut self, now: Option<SimTime>) -> Vec<usize> {
        let copies = self.replicas.len();
        let start = self.read_start();
        let order: Vec<usize> = (0..copies).map(|k| (start + k) % copies).collect();
        let (Some(q), Some(now)) = (&self.quarantine, now) else {
            return order;
        };
        let q = q.borrow();
        let avail: Vec<usize> = order.iter().copied().filter(|&i| !q.skip(i, now)).collect();
        if avail.is_empty() {
            order
        } else {
            avail
        }
    }

    /// Count a read issued to a quarantined replica as a reinstatement
    /// probe.
    fn mark_probe(&self, idx: usize) {
        if let Some(q) = &self.quarantine {
            q.borrow_mut().mark_probe(idx);
        }
    }

    fn note_quarantine_success(&self, idx: usize) {
        if let Some(q) = &self.quarantine {
            q.borrow_mut().note_success(idx);
        }
    }

    /// Feed one read failure into the `Fastest` penalty and the
    /// quarantine ledger.
    fn note_read_failure(&mut self, idx: usize, observing: bool) {
        // charge the failure so `Fastest` stops probing a dead replica
        // first on every read (an instant error must not read as
        // "lowest latency"); based on the slowest SUCCESSFUL sample so
        // it tops healthy reads of any size without compounding
        if observing {
            self.observe(idx, FAILURE_PENALTY.max(4.0 * self.slowest_healthy));
        }
        if let (Some(q), Some(clock)) = (&self.quarantine, &self.clock) {
            q.borrow_mut().note_failure(idx, clock.now());
        }
    }

    /// Feed one successful read into the `Fastest` EWMA (per-byte
    /// normalized) and the quarantine ledger.
    fn note_read_success(
        &mut self,
        idx: usize,
        t0: Option<SimTime>,
        handle: &DataHandle,
    ) {
        if let Some(t0) = t0 {
            let now = self.clock.as_ref().expect("observing implies clock").now();
            // per-byte normalization: a replica that served a large
            // coalesced range must not look slow next to one that
            // served a single small field
            let sample = (now - t0).as_secs_f64() / handle.total_len().max(1) as f64;
            self.slowest_healthy = self.slowest_healthy.max(sample);
            self.observe(idx, sample);
        }
        self.note_quarantine_success(idx);
    }

    /// One policy-routed read: probe replicas starting at the policy's
    /// pick, first healthy answer wins; latency is observed for
    /// [`ReadPolicy::Fastest`]. Shared by `read` (one raw handle, probed
    /// via the inner `read`) and `read_ranges` (`vectored`: probed via
    /// the inner `read_ranges`, so a strict vectored inner — the RADOS
    /// short-buffer guard — reports a typed error and the wrapper fails
    /// over to the next replica instead of passing corrupt bytes up).
    /// The policy applies **per merged range**, so one plan's ranges
    /// spread over replicas like individual reads would.
    /// Fold one replica failure into the error that
    /// [`FdbError::AllReplicasFailed`] will surface as `last`. A
    /// transient error is never displaced by a permanent one: the
    /// engine's retry policy classifies the whole failure by `last`
    /// (via [`crate::fdb::telemetry::is_transient`]), and a read where
    /// *any* replica failed transiently is worth retrying even when the
    /// final replica probed happened to be fail-stopped.
    fn keep_retryable(last: &mut Option<FdbError>, e: FdbError) {
        let prev_transient = last
            .as_ref()
            .is_some_and(crate::fdb::telemetry::is_transient);
        if !prev_transient || crate::fdb::telemetry::is_transient(&e) {
            *last = Some(e);
        }
    }

    /// Rewrite replicas that served corrupt bytes from a copy that
    /// verified — best-effort: a failed repair leaves the copy for the
    /// next `fsck` pass.
    async fn heal_corrupt(&mut self, corrupt: &[usize], handle: &DataHandle, good: &Bytes) {
        for &idx in corrupt {
            if let Ok(true) = self.replicas[idx].repair(handle, good.clone()).await {
                if let Some(c) = &self.repaired {
                    c.inc();
                }
            }
        }
    }

    async fn read_one(
        &mut self,
        handle: &DataHandle,
        vectored: bool,
        checks: &[RangeCheck],
    ) -> Result<Bytes, FdbError> {
        let copies = self.replicas.len();
        // the estimates only steer `Fastest` — skip the bookkeeping
        // (two clock samples + EWMA fold per read) for other policies
        let observing = self.policy == ReadPolicy::Fastest && self.clock.is_some();
        let now = self.clock.as_ref().map(|s| s.now());
        let order = self.probe_order(now);
        let mut last = None;
        // replicas whose bytes failed verification — healed from the
        // first copy that verifies before returning (repair-from-replica)
        let mut corrupt: Vec<usize> = Vec::new();
        // raced replicas already counted as failed (or rotten) — the
        // serial fall-through must not probe them a second time
        let mut skip: Vec<usize> = Vec::new();

        // hedged fast path: race the first two candidates
        if self.hedge > SimTime::ZERO && order.len() >= 2 {
            if let Some(clock) = self.clock.clone() {
                let (pi, hi) = (order[0], order[1]);
                self.mark_probe(pi);
                let t0 = clock.now();
                let rr = {
                    let timer = clock.sleep(self.hedge);
                    let (pstore, hstore) = two_mut(&mut self.replicas, pi, hi);
                    HedgeRace {
                        primary: Some(read_fut(pstore, handle, vectored)),
                        timer: Some(timer),
                        launch: Some(move || read_fut(hstore, handle, vectored)),
                        hedge: None,
                        primary_err: None,
                        hedge_err: None,
                        hedge_launched: false,
                        stats: self.hedge_stats.clone(),
                    }
                    .await
                };
                if rr.hedge_launched {
                    self.mark_probe(hi);
                }
                if rr.primary_err.is_some() {
                    self.note_read_failure(pi, observing);
                    skip.push(pi);
                }
                if rr.hedge_err.is_some() {
                    self.note_read_failure(hi, observing);
                    skip.push(hi);
                }
                match rr.winner {
                    Some((bytes, hedge_won)) => {
                        let widx = if hedge_won { hi } else { pi };
                        match verify_ranges(&bytes, checks) {
                            Ok(()) => {
                                // the sample spans the whole race window —
                                // a conservative overestimate for a hedge
                                // winner (includes the hedge delay), but
                                // failures and penalties stay exact
                                self.note_read_success(
                                    widx,
                                    if observing { Some(t0) } else { None },
                                    handle,
                                );
                                return Ok(bytes);
                            }
                            Err(e) => {
                                // the winner's bytes are rot: count a
                                // failed probe and fall through to the
                                // rest of the ring (a cancelled loser is
                                // still fair game)
                                self.note_read_failure(widx, observing);
                                corrupt.push(widx);
                                skip.push(widx);
                                Self::keep_retryable(&mut last, e);
                            }
                        }
                    }
                    None => {
                        for e in [rr.primary_err, rr.hedge_err].into_iter().flatten() {
                            Self::keep_retryable(&mut last, e);
                        }
                    }
                }
            }
        }

        for &idx in &order {
            if skip.contains(&idx) {
                continue;
            }
            self.mark_probe(idx);
            let t0 = if observing {
                self.clock.as_ref().map(|s| s.now())
            } else {
                None
            };
            let r = if vectored {
                self.replicas[idx]
                    .read_ranges(std::slice::from_ref(handle))
                    .await
                    .map(|mut bufs| bufs.pop().expect("one buffer per handle"))
            } else {
                self.replicas[idx].read(handle).await
            };
            match r {
                Ok(bytes) => match verify_ranges(&bytes, checks) {
                    Ok(()) => {
                        self.note_read_success(idx, t0, handle);
                        self.heal_corrupt(&corrupt, handle, &bytes).await;
                        return Ok(bytes);
                    }
                    Err(e) => {
                        self.note_read_failure(idx, observing);
                        corrupt.push(idx);
                        Self::keep_retryable(&mut last, e);
                    }
                },
                Err(e) => {
                    self.note_read_failure(idx, observing);
                    Self::keep_retryable(&mut last, e);
                }
            }
        }
        let last = last.expect("at least one replica");
        // every probed copy rotten: surface the typed corruption itself,
        // not the replica wrapper — it is the signal telemetry counts
        // and the engine's retry policy must never retry
        if matches!(last, FdbError::Corrupt { .. }) {
            return Err(last);
        }
        Err(FdbError::AllReplicasFailed {
            op: "read",
            copies,
            last: Box::new(last),
        })
    }
}

impl Store for ReplicatedStore {
    fn name(&self) -> &'static str {
        "replicated"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        Box::pin(async move {
            let mut locs = Vec::with_capacity(self.replicas.len());
            for replica in &mut self.replicas {
                locs.push(replica.archive(ds, colloc, id, data.clone()).await?);
            }
            let primary = locs[0].clone();
            if locs.len() > 1 {
                // remember where the secondary copies went — the
                // catalogue only indexes the primary's location, and
                // scrub repair needs to reach the other copies
                let key = Self::loc_key(&DataHandle::from_location(&primary));
                self.replica_locs.borrow_mut().insert(key, locs);
            }
            Ok(primary)
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            for replica in &mut self.replicas {
                replica.flush().await?;
            }
            Ok(())
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(self.read_one(handle, false, &[]))
    }

    /// Vectored reads apply the [`ReadPolicy`] per merged range: each
    /// planned range is routed like an individual read (through the
    /// inner `read_ranges`, keeping strict vectored error semantics),
    /// so round-robin spreads a plan's ranges over replicas and
    /// `Fastest` keeps its latency estimates warm.
    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            let mut out = Vec::with_capacity(handles.len());
            for handle in handles {
                out.push(self.read_one(handle, true, &[]).await?);
            }
            Ok(out)
        })
    }

    /// Verified reads route corruption into the replica fall-through:
    /// bytes failing their checksum count as a failed probe, the next
    /// replica serves, and the rotten copy is rewritten in place from
    /// the verified bytes — callers never see the damage while at
    /// least one copy (or access path) is clean.
    fn read_verified<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        checks: &'a [RangeCheck],
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(self.read_one(handle, false, checks))
    }

    fn read_ranges_verified<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
        checks: &'a [Vec<RangeCheck>],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            let mut out = Vec::with_capacity(handles.len());
            for (i, handle) in handles.iter().enumerate() {
                let cks = checks.get(i).map(Vec::as_slice).unwrap_or(&[]);
                out.push(self.read_one(handle, true, cks).await?);
            }
            Ok(out)
        })
    }

    /// Repair fans out to every copy: each replica rewrites its own
    /// archive-time location when one is recorded, else the shared
    /// handle.
    fn repair<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        Box::pin(async move {
            let locs = self
                .replica_locs
                .borrow()
                .get(&Self::loc_key(handle))
                .cloned();
            let mut any = false;
            for (i, replica) in self.replicas.iter_mut().enumerate() {
                let own = locs
                    .as_ref()
                    .and_then(|l| l.get(i))
                    .map(DataHandle::from_location);
                let h = own.as_ref().unwrap_or(handle);
                any |= replica.repair(h, data.clone()).await.unwrap_or(false);
            }
            Ok(any)
        })
    }

    /// Scrub probes every replica's copy (via the archive-time location
    /// map; a fresh process without one probes all replicas through the
    /// primary's handle, which still reaches the bytes on shared
    /// storage). With `do_repair`, damaged copies are rewritten from a
    /// copy that verifies.
    fn scrub_field<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        expect_len: u64,
        ck: Option<u64>,
        do_repair: bool,
    ) -> LocalBoxFuture<'a, Result<ScrubOutcome, FdbError>> {
        Box::pin(async move {
            let locs = self
                .replica_locs
                .borrow()
                .get(&Self::loc_key(handle))
                .cloned();
            let handles: Vec<DataHandle> = (0..self.replicas.len())
                .map(|i| match locs.as_ref().and_then(|l| l.get(i)) {
                    Some(loc) => DataHandle::from_location(loc),
                    None => handle.clone(),
                })
                .collect();
            let mut out = ScrubOutcome::default();
            let mut healthy: Vec<usize> = Vec::new();
            let mut damaged: Vec<usize> = Vec::new();
            for (i, h) in handles.iter().enumerate() {
                let o = self.replicas[i].scrub_field(h, expect_len, ck, false).await?;
                out.copies += o.copies;
                out.missing += o.missing;
                out.corrupt += o.corrupt;
                if o.missing == 0 && o.corrupt == 0 {
                    healthy.push(i);
                } else {
                    damaged.push(i);
                }
            }
            if do_repair && !damaged.is_empty() {
                let checks: Vec<RangeCheck> = ck
                    .map(|c| vec![RangeCheck::whole(expect_len, c)])
                    .unwrap_or_default();
                let mut good: Option<Bytes> = None;
                for &i in &healthy {
                    // the repair source must itself verify — this read
                    // runs through the live path, where injected wire
                    // rot can strike again
                    if let Ok(b) = self.replicas[i].read_verified(&handles[i], &checks).await {
                        if b.len() == expect_len {
                            good = Some(b);
                            break;
                        }
                    }
                }
                if let Some(good) = good {
                    for &i in &damaged {
                        if let Ok(true) = self.replicas[i].repair(&handles[i], good.clone()).await
                        {
                            out.repaired += 1;
                            if let Some(c) = &self.repaired {
                                c.inc();
                            }
                        }
                    }
                }
            }
            Ok(out)
        })
    }

    /// No inventory under replication: secondary copies are by design
    /// unreferenced by the catalogue (only the primary's location is
    /// indexed), so an orphan scan would flag every one of them.
    fn scrub_inventory<'a>(
        &'a mut self,
        _ds: &'a Key,
    ) -> LocalBoxFuture<'a, Option<Vec<(String, u64)>>> {
        crate::fdb::backend::ready(None)
    }

    /// Catalogue-bypassing retrieval is forwarded when EVERY replica
    /// supports it (replicas are instances of one config, so in practice
    /// all or none do); lookups try replicas in order, first hit wins.
    fn direct_retrieve_enabled(&self) -> bool {
        self.replicas.iter().all(|r| r.direct_retrieve_enabled())
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(async move {
            for replica in &mut self.replicas {
                if let Some(loc) = replica.retrieve_direct(ds, id).await {
                    return Some(loc);
                }
            }
            None
        })
    }

    fn supports_wipe(&self) -> bool {
        self.replicas.iter().all(|r| r.supports_wipe())
    }

    fn wipe_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        Box::pin(async move {
            let mut any = false;
            for replica in &mut self.replicas {
                any |= replica.wipe_dataset(ds).await;
            }
            any
        })
    }

    fn take_lock_time(&self) -> SimTime {
        self.replicas
            .iter()
            .map(|r| r.take_lock_time())
            .fold(SimTime::ZERO, |a, b| a + b)
    }

    fn session(&mut self) -> Option<Box<dyn StoreSession>> {
        // fan a session out of every replica: the session's writes still
        // hit all N copies, and its reads rotate (or race by latency)
        // independently — each session gathers its own EWMA estimates.
        // Hedge settings are copied; the quarantine ledger is SHARED
        // (replica vectors are index-aligned), so one lane discovering a
        // dead replica routes every lane around it.
        let mut replicas = Vec::with_capacity(self.replicas.len());
        for replica in &mut self.replicas {
            replicas.push(replica.session()?.into_store());
        }
        let mut session = ReplicatedStore::new(replicas).with_read_policy(self.policy);
        if let Some(sim) = &self.clock {
            session = session.with_clock(sim);
        }
        session.hedge = self.hedge;
        session.hedge_stats = self.hedge_stats.clone();
        session.quarantine = self.quarantine.clone();
        // the replica-location map is SHARED: engine-lane archives must
        // record where the secondaries went for scrub to find them
        session.replica_locs = self.replica_locs.clone();
        session.repaired = self.repaired.clone();
        Some(Box::new(session))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullStore};
    use std::cell::Cell;
    use std::rc::Rc;

    #[test]
    fn all_replicas_failed_keeps_a_retryable_last() {
        // mixed failure: one replica died transiently, another is
        // fail-stopped — the surfaced `last` must stay transient no
        // matter the probe order, or the retry layer gives up on a
        // read that a retry would have recovered
        let transient = || FdbError::Backend {
            backend: "fault",
            detail: "injected transient Read error (op 3)".into(),
        };
        let permanent = || FdbError::Backend {
            backend: "fault",
            detail: "fail-stop after 4 Read ops".into(),
        };
        let mut last = None;
        ReplicatedStore::keep_retryable(&mut last, transient());
        ReplicatedStore::keep_retryable(&mut last, permanent());
        assert!(crate::fdb::telemetry::is_transient(last.as_ref().unwrap()));

        let mut last = None;
        ReplicatedStore::keep_retryable(&mut last, permanent());
        ReplicatedStore::keep_retryable(&mut last, transient());
        assert!(crate::fdb::telemetry::is_transient(last.as_ref().unwrap()));

        // all-permanent: the newest permanent error wins (no masking)
        let mut last = None;
        ReplicatedStore::keep_retryable(&mut last, permanent());
        ReplicatedStore::keep_retryable(&mut last, permanent());
        assert!(!crate::fdb::telemetry::is_transient(last.as_ref().unwrap()));
    }

    /// A Null-semantics store that counts the reads it serves — lets the
    /// rotation tests observe which replica a read landed on.
    struct CountingStore {
        reads: Rc<Cell<usize>>,
    }

    impl Store for CountingStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            crate::fdb::backend::ready(match handle {
                DataHandle::Null { length } => {
                    self.reads.set(self.reads.get() + 1);
                    Ok(Bytes::virt(*length, 0))
                }
                other => Err(FdbError::BackendMismatch {
                    store: "null",
                    handle: other.backend_name(),
                }),
            })
        }
    }

    fn counting_pair() -> (ReplicatedStore, Rc<Cell<usize>>, Rc<Cell<usize>>) {
        let (c0, c1) = (Rc::new(Cell::new(0)), Rc::new(Cell::new(0)));
        let rep = ReplicatedStore::new(vec![
            Box::new(CountingStore { reads: c0.clone() }),
            Box::new(CountingStore { reads: c1.clone() }),
        ]);
        (rep, c0, c1)
    }

    #[test]
    fn round_robin_rotates_reads_across_replicas() {
        let (mut rep, c0, c1) = counting_pair();
        assert_eq!(rep.read_policy(), ReadPolicy::RoundRobin);
        let h = DataHandle::Null { length: 8 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        // rotation: 4 reads over 2 replicas -> 2 each (not 4 on primary)
        assert_eq!((c0.get(), c1.get()), (2, 2));
    }

    #[test]
    fn first_healthy_keeps_reads_on_primary() {
        let (rep, c0, c1) = counting_pair();
        let mut rep = rep.with_read_policy(ReadPolicy::FirstHealthy);
        let h = DataHandle::Null { length: 8 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        assert_eq!((c0.get(), c1.get()), (4, 0));
    }

    #[test]
    fn round_robin_falls_through_unhealthy_replica() {
        // replica 1 is a posix-handle-only mismatch for Null handles:
        // rotation starting there must fall through to replica 0
        let reads = Rc::new(Cell::new(0));
        let mut rep = ReplicatedStore::new(vec![
            Box::new(CountingStore { reads: reads.clone() }),
            Box::new(NullStore),
        ]);
        let posix = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        // NullStore also mismatches posix handles -> AllReplicasFailed,
        // regardless of which replica the rotation starts at
        for _ in 0..2 {
            let err = block_on(rep.read(&posix)).unwrap_err();
            assert!(matches!(err, FdbError::AllReplicasFailed { .. }));
        }
        // a Null handle always finds a healthy replica
        let h = DataHandle::Null { length: 4 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        // the counting replica saw only its rotation share
        assert_eq!(reads.get(), 2);
    }

    #[test]
    fn primary_location_returned_and_reads_serve() {
        let mut rep = ReplicatedStore::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        assert_eq!(rep.copies(), 2);
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        let loc = block_on(rep.archive(&ds, &ds, &id, Bytes::virt(64, 3))).unwrap();
        let h = DataHandle::from_location(&loc);
        assert_eq!(block_on(rep.read(&h)).unwrap().len(), 64);
    }

    /// A Null-semantics store whose reads take a configurable virtual
    /// duration — lets the Fastest tests shape per-replica latency.
    struct DelayStore {
        sim: Sim,
        delay: Rc<Cell<SimTime>>,
        reads: Rc<Cell<usize>>,
    }

    impl Store for DelayStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            Box::pin(async move {
                match handle {
                    DataHandle::Null { length } => {
                        self.sim.sleep(self.delay.get()).await;
                        self.reads.set(self.reads.get() + 1);
                        Ok(Bytes::virt(*length, 0))
                    }
                    other => Err(FdbError::BackendMismatch {
                        store: "null",
                        handle: other.backend_name(),
                    }),
                }
            })
        }
    }

    /// (tunable delay, reads served) of one probe replica.
    type Probe = (Rc<Cell<SimTime>>, Rc<Cell<usize>>);

    fn delayed_pair(sim: &Sim, d0: SimTime, d1: SimTime) -> (ReplicatedStore, Probe, Probe) {
        let mk = |d: SimTime| {
            let delay = Rc::new(Cell::new(d));
            let reads = Rc::new(Cell::new(0));
            let store = DelayStore {
                sim: sim.clone(),
                delay: delay.clone(),
                reads: reads.clone(),
            };
            (store, delay, reads)
        };
        let (s0, delay0, reads0) = mk(d0);
        let (s1, delay1, reads1) = mk(d1);
        let rep = ReplicatedStore::new(vec![Box::new(s0), Box::new(s1)])
            .with_read_policy(ReadPolicy::Fastest)
            .with_clock(sim);
        (rep, (delay0, reads0), (delay1, reads1))
    }

    #[test]
    fn fastest_routes_to_lowest_latency_replica() {
        let sim = Sim::new();
        let (mut rep, (_, slow_reads), (_, fast_reads)) = delayed_pair(
            &sim,
            SimTime::micros(500), // replica 0: slow
            SimTime::micros(50),  // replica 1: fast
        );
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            for _ in 0..10 {
                rep.read(&h).await.unwrap();
            }
            let est = rep.latency_estimates();
            assert!(est.iter().all(|e| e.is_some()), "both replicas seeded");
            assert!(est[1].unwrap() < est[0].unwrap());
        });
        sim.run();
        // one seeding probe each, then every read lands on the fast one
        assert_eq!(slow_reads.get(), 1);
        assert_eq!(fast_reads.get(), 9);
    }

    #[test]
    fn fastest_adapts_when_latencies_change() {
        let sim = Sim::new();
        let (mut rep, (_, other_reads), (fast_delay, fast_reads)) =
            delayed_pair(&sim, SimTime::micros(200), SimTime::micros(50));
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            for _ in 0..6 {
                rep.read(&h).await.unwrap();
            }
            // the fast replica degrades (e.g. a rebuilding OST behind it):
            // its EWMA rises past the other's within a few observations
            fast_delay.set(SimTime::micros(5000));
            for _ in 0..6 {
                rep.read(&h).await.unwrap();
            }
        });
        sim.run();
        // after the flip, traffic moves back to the now-faster replica
        assert!(
            other_reads.get() >= 4,
            "routing never adapted: other={} fast={}",
            other_reads.get(),
            fast_reads.get()
        );
    }

    /// An always-failing replica (e.g. a lost client connection) that
    /// counts how often it is probed.
    struct FailStore {
        probes: Rc<Cell<usize>>,
    }

    impl Store for FailStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            _handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            self.probes.set(self.probes.get() + 1);
            crate::fdb::backend::ready(Err(FdbError::Backend {
                backend: "null",
                detail: "replica down".to_string(),
            }))
        }
    }

    #[test]
    fn fastest_stops_probing_a_dead_replica_first() {
        // a dead replica fails instantly; without the failure penalty
        // its EWMA would stay unseeded (or near zero) and every read
        // would probe it first before falling through
        let sim = Sim::new();
        let healthy_reads = Rc::new(Cell::new(0));
        let probes = Rc::new(Cell::new(0));
        let healthy = DelayStore {
            sim: sim.clone(),
            delay: Rc::new(Cell::new(SimTime::micros(50))),
            reads: healthy_reads.clone(),
        };
        let dead = FailStore {
            probes: probes.clone(),
        };
        let mut rep = ReplicatedStore::new(vec![Box::new(healthy), Box::new(dead)])
            .with_read_policy(ReadPolicy::Fastest)
            .with_clock(&sim);
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            for _ in 0..10 {
                rep.read(&h).await.unwrap();
            }
        });
        sim.run();
        // seeded once, then the penalty keeps it out of the rotation
        assert_eq!(probes.get(), 1, "dead replica re-probed");
        assert_eq!(healthy_reads.get(), 10);
    }

    #[test]
    fn fastest_without_clock_still_serves_and_falls_through() {
        // no clock: no latency observations, so the policy degrades to
        // probing replica 0 first — availability semantics unchanged
        let (mut rep, c0, c1) = counting_pair();
        rep = rep.with_read_policy(ReadPolicy::Fastest);
        let h = DataHandle::Null { length: 8 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        assert_eq!((c0.get(), c1.get()), (4, 0));
        assert!(rep.latency_estimates().iter().all(|e| e.is_none()));
    }

    /// A store whose reads fail while `fail` is set — flips healthy for
    /// the quarantine reinstatement tests.
    struct FlakyStore {
        fail: Rc<Cell<bool>>,
        reads: Rc<Cell<usize>>,
    }

    impl Store for FlakyStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            self.reads.set(self.reads.get() + 1);
            crate::fdb::backend::ready(if self.fail.get() {
                Err(FdbError::Backend {
                    backend: "null",
                    detail: "replica down".to_string(),
                })
            } else {
                match handle {
                    DataHandle::Null { length } => Ok(Bytes::virt(*length, 0)),
                    other => Err(FdbError::BackendMismatch {
                        store: "null",
                        handle: other.backend_name(),
                    }),
                }
            })
        }

        fn session(&mut self) -> Option<Box<dyn StoreSession>> {
            // sessions share the fault switch and the probe counter
            Some(Box::new(FlakyStore {
                fail: self.fail.clone(),
                reads: self.reads.clone(),
            }))
        }
    }

    #[test]
    fn hedged_read_wins_when_primary_is_slow() {
        use crate::fdb::telemetry::MetricsRegistry;
        let sim = Sim::new();
        let (rep, (_, slow_reads), (_, fast_reads)) = delayed_pair(
            &sim,
            SimTime::micros(1000), // replica 0: slow primary
            SimTime::micros(50),   // replica 1: fast hedge target
        );
        let reg = MetricsRegistry::new();
        let res = crate::fdb::ResilienceProfile::default().with_hedge_us(100);
        let mut rep = rep
            .with_read_policy(ReadPolicy::FirstHealthy)
            .with_resilience(&res, Some(&reg));
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            assert_eq!(rep.read(&h).await.unwrap().len(), 8);
        });
        let end = sim.run();
        // hedge launches at 100µs, completes at 150µs — the caller never
        // waits out the primary's 1000µs
        assert_eq!(end, SimTime::micros(150));
        assert_eq!(slow_reads.get(), 0, "primary was cancelled mid-flight");
        assert_eq!(fast_reads.get(), 1);
        assert_eq!(reg.counter_value("engine.hedge.launched"), 1);
        assert_eq!(reg.counter_value("engine.hedge.won"), 1);
        assert_eq!(reg.counter_value("engine.hedge.wasted_bytes"), 0);
    }

    #[test]
    fn fast_primary_never_launches_a_hedge() {
        use crate::fdb::telemetry::MetricsRegistry;
        let sim = Sim::new();
        let (rep, (_, r0), (_, r1)) =
            delayed_pair(&sim, SimTime::micros(50), SimTime::micros(50));
        let reg = MetricsRegistry::new();
        let res = crate::fdb::ResilienceProfile::default().with_hedge_us(200);
        let mut rep = rep
            .with_read_policy(ReadPolicy::FirstHealthy)
            .with_resilience(&res, Some(&reg));
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            for _ in 0..3 {
                rep.read(&h).await.unwrap();
            }
        });
        let end = sim.run();
        assert_eq!(end, SimTime::micros(150), "three serial 50µs reads");
        assert_eq!((r0.get(), r1.get()), (3, 0));
        assert_eq!(reg.counter_value("engine.hedge.launched"), 0);
    }

    #[test]
    fn failed_primary_launches_hedge_immediately() {
        use crate::fdb::telemetry::MetricsRegistry;
        let sim = Sim::new();
        let probes = Rc::new(Cell::new(0));
        let fast_reads = Rc::new(Cell::new(0));
        let dead = FailStore {
            probes: probes.clone(),
        };
        let healthy = DelayStore {
            sim: sim.clone(),
            delay: Rc::new(Cell::new(SimTime::micros(50))),
            reads: fast_reads.clone(),
        };
        let reg = MetricsRegistry::new();
        let res = crate::fdb::ResilienceProfile::default().with_hedge_us(500);
        let mut rep = ReplicatedStore::new(vec![Box::new(dead), Box::new(healthy)])
            .with_read_policy(ReadPolicy::FirstHealthy)
            .with_clock(&sim)
            .with_resilience(&res, Some(&reg));
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            assert_eq!(rep.read(&h).await.unwrap().len(), 8);
        });
        let end = sim.run();
        // the primary fails instantly; the hedge fires without waiting
        // out the 500µs hedge delay
        assert_eq!(end, SimTime::micros(50));
        assert_eq!(probes.get(), 1);
        assert_eq!(fast_reads.get(), 1);
        assert_eq!(reg.counter_value("engine.hedge.launched"), 1);
        assert_eq!(reg.counter_value("engine.hedge.won"), 1);
    }

    #[test]
    fn hedge_loser_that_completes_counts_wasted_bytes() {
        use crate::fdb::telemetry::MetricsRegistry;
        let sim = Sim::new();
        // primary: 100µs; hedge launches at 50µs and also takes 50µs, so
        // both complete at the same virtual instant — the primary wins
        // the race and the hedge's fetched bytes are wasted work
        let (rep, (_, r0), (_, r1)) =
            delayed_pair(&sim, SimTime::micros(100), SimTime::micros(50));
        let reg = MetricsRegistry::new();
        let res = crate::fdb::ResilienceProfile::default().with_hedge_us(50);
        let mut rep = rep
            .with_read_policy(ReadPolicy::FirstHealthy)
            .with_resilience(&res, Some(&reg));
        sim.spawn(async move {
            let h = DataHandle::Null { length: 32 };
            assert_eq!(rep.read(&h).await.unwrap().len(), 32);
        });
        let end = sim.run();
        assert_eq!(end, SimTime::micros(100));
        assert_eq!((r0.get(), r1.get()), (1, 1), "both replicas served");
        assert_eq!(reg.counter_value("engine.hedge.launched"), 1);
        assert_eq!(reg.counter_value("engine.hedge.won"), 0, "primary won");
        assert_eq!(reg.counter_value("engine.hedge.wasted_bytes"), 32);
    }

    #[test]
    fn quarantine_ejects_dead_replica_and_reinstates_after_probe() {
        use crate::fdb::telemetry::MetricsRegistry;
        let sim = Sim::new();
        let fail = Rc::new(Cell::new(true));
        let flaky_reads = Rc::new(Cell::new(0));
        let healthy_reads = Rc::new(Cell::new(0));
        let reg = MetricsRegistry::new();
        let res = crate::fdb::ResilienceProfile::default().with_quarantine(2, 1_000);
        let mut rep = ReplicatedStore::new(vec![
            Box::new(FlakyStore {
                fail: fail.clone(),
                reads: flaky_reads.clone(),
            }),
            Box::new(CountingStore {
                reads: healthy_reads.clone(),
            }),
        ])
        .with_read_policy(ReadPolicy::FirstHealthy)
        .with_clock(&sim)
        .with_resilience(&res, Some(&reg));
        let sim2 = sim.clone();
        let flaky = flaky_reads.clone();
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            // two consecutive failures trip the threshold; both reads
            // fall through to the healthy replica
            rep.read(&h).await.unwrap();
            rep.read(&h).await.unwrap();
            assert_eq!(rep.quarantined_now(), vec![true, false]);
            assert_eq!(flaky.get(), 2);
            // while quarantined, reads route straight to the healthy one
            rep.read(&h).await.unwrap();
            assert_eq!(flaky.get(), 2, "no traffic to a quarantined replica");
            // the replica recovers; once the backoff expires, one probe
            // read reinstates it
            fail.set(false);
            sim2.sleep(SimTime::micros(1_500)).await;
            rep.read(&h).await.unwrap();
            assert_eq!(flaky.get(), 3, "reinstatement probe");
            assert_eq!(rep.quarantined_now(), vec![false, false]);
        });
        sim.run();
        assert_eq!(healthy_reads.get(), 3);
        assert_eq!(reg.counter_value("replica.quarantine.ejected"), 1);
        assert_eq!(reg.counter_value("replica.quarantine.probes"), 1);
        assert_eq!(reg.counter_value("replica.quarantine.reinstated"), 1);
    }

    #[test]
    fn all_replicas_quarantined_still_probes_as_last_resort() {
        let sim = Sim::new();
        let fail = Rc::new(Cell::new(true));
        let reads = Rc::new(Cell::new(0));
        let res = crate::fdb::ResilienceProfile::default().with_quarantine(1, 10_000);
        let mut rep = ReplicatedStore::new(vec![Box::new(FlakyStore {
            fail: fail.clone(),
            reads: reads.clone(),
        })])
        .with_clock(&sim)
        .with_resilience(&res, None);
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            // one failure quarantines the only replica
            assert!(rep.read(&h).await.is_err());
            assert_eq!(rep.quarantined_now(), vec![true]);
            // with everyone quarantined the rotation probes anyway —
            // availability never drops below the plain fall-through
            fail.set(false);
            assert_eq!(rep.read(&h).await.unwrap().len(), 8);
            assert_eq!(rep.quarantined_now(), vec![false]);
        });
        sim.run();
        assert_eq!(reads.get(), 2);
    }

    #[test]
    fn sessions_share_one_quarantine_ledger() {
        let sim = Sim::new();
        let fail = Rc::new(Cell::new(true));
        let reads = Rc::new(Cell::new(0));
        let res = crate::fdb::ResilienceProfile::default().with_quarantine(1, 10_000);
        let mut rep = ReplicatedStore::new(vec![
            Box::new(FlakyStore {
                fail: fail.clone(),
                reads: reads.clone(),
            }),
            Box::new(NullStore),
        ])
        .with_read_policy(ReadPolicy::FirstHealthy)
        .with_clock(&sim)
        .with_resilience(&res, None);
        let mut lane = rep.session().expect("replicated session").into_store();
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            // the parent discovers the dead replica...
            rep.read(&h).await.unwrap();
            assert_eq!(rep.quarantined_now(), vec![true, false]);
            // ...and the session lane routes around it without ever
            // probing (the ledger is shared, not per-lane)
            let before = reads.get();
            lane.read(&h).await.unwrap();
            assert_eq!(reads.get(), before);
        });
        sim.run();
    }

    /// A Null-semantics store serving ROTTEN bytes while `rotten` is
    /// set; `repair` clears the flag — models a copy whose bit-rot a
    /// rewrite genuinely fixes.
    struct RottenStore {
        rotten: Rc<Cell<bool>>,
        repairs: Rc<Cell<usize>>,
    }

    impl Store for RottenStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            crate::fdb::backend::ready(match handle {
                DataHandle::Null { length } => {
                    let fill = if self.rotten.get() { 7 } else { 0 };
                    Ok(Bytes::virt(*length, fill))
                }
                other => Err(FdbError::BackendMismatch {
                    store: "null",
                    handle: other.backend_name(),
                }),
            })
        }

        fn repair<'a>(
            &'a mut self,
            _handle: &'a DataHandle,
            _data: Bytes,
        ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
            self.rotten.set(false);
            self.repairs.set(self.repairs.get() + 1);
            crate::fdb::backend::ready(Ok(true))
        }
    }

    fn rotten_pair() -> (ReplicatedStore, Rc<Cell<bool>>, Rc<Cell<usize>>) {
        let rotten = Rc::new(Cell::new(true));
        let repairs = Rc::new(Cell::new(0));
        let rep = ReplicatedStore::new(vec![
            Box::new(RottenStore {
                rotten: rotten.clone(),
                repairs: repairs.clone(),
            }),
            Box::new(NullStore),
        ])
        .with_read_policy(ReadPolicy::FirstHealthy);
        (rep, rotten, repairs)
    }

    #[test]
    fn verified_read_fails_over_corruption_and_heals_the_copy() {
        let (mut rep, rotten, repairs) = rotten_pair();
        let h = DataHandle::Null { length: 16 };
        let clean = Bytes::virt(16, 0);
        let checks = [RangeCheck::whole(16, clean.content_checksum())];
        // the primary serves rot; the caller still gets verified bytes
        let got = block_on(rep.read_verified(&h, &checks)).unwrap();
        assert_eq!(got.content_checksum(), clean.content_checksum());
        // ...and the rotten copy was rewritten in place on the way out
        assert_eq!(repairs.get(), 1);
        assert!(!rotten.get());
        // an UNVERIFIED read would have returned the rot silently —
        // which is exactly why every engine path now carries checks
        let again = block_on(rep.read_verified(&h, &checks)).unwrap();
        assert_eq!(again.content_checksum(), clean.content_checksum());
        assert_eq!(repairs.get(), 1, "healthy copies are not rewritten");
    }

    #[test]
    fn every_copy_rotten_surfaces_typed_corruption() {
        let rotten = Rc::new(Cell::new(true));
        let repairs = Rc::new(Cell::new(0));
        // two rotten replicas, repair disabled by never clearing: use
        // two independent stores sharing the flag so both serve rot
        let mut rep = ReplicatedStore::new(vec![
            Box::new(RottenStore {
                rotten: rotten.clone(),
                repairs: repairs.clone(),
            }),
            Box::new(RottenStore {
                rotten: rotten.clone(),
                repairs: repairs.clone(),
            }),
        ]);
        let h = DataHandle::Null { length: 16 };
        let clean = Bytes::virt(16, 0);
        let checks = [RangeCheck::whole(16, clean.content_checksum())];
        let err = block_on(rep.read_verified(&h, &checks)).unwrap_err();
        assert!(matches!(err, FdbError::Corrupt { .. }), "got {err}");
        assert_eq!(repairs.get(), 0, "no verified source, no repair");
    }

    #[test]
    fn scrub_probes_all_replicas_and_repairs_from_verified_copy() {
        let rotten = Rc::new(Cell::new(true));
        let repairs = Rc::new(Cell::new(0));
        let mut rep = ReplicatedStore::new(vec![
            Box::new(NullStore),
            Box::new(RottenStore {
                rotten: rotten.clone(),
                repairs: repairs.clone(),
            }),
        ]);
        let h = DataHandle::Null { length: 16 };
        let ck = Bytes::virt(16, 0).content_checksum();
        // detect-only: the damaged secondary is found, nothing rewritten
        let o = block_on(rep.scrub_field(&h, 16, Some(ck), false)).unwrap();
        assert_eq!((o.copies, o.missing, o.corrupt, o.repaired), (2, 0, 1, 0));
        assert!(!o.healthy());
        // repair: rewritten from the primary's verified bytes
        let o = block_on(rep.scrub_field(&h, 16, Some(ck), true)).unwrap();
        assert_eq!((o.corrupt, o.repaired), (1, 1));
        assert!(o.healthy());
        assert_eq!(repairs.get(), 1);
        // the next pass is clean — fsck convergence at the store layer
        let o = block_on(rep.scrub_field(&h, 16, Some(ck), true)).unwrap();
        assert_eq!((o.copies, o.missing, o.corrupt, o.repaired), (2, 0, 0, 0));
    }

    #[test]
    fn all_replicas_mismatching_is_typed_error() {
        let mut rep = ReplicatedStore::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        let foreign = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        let err = block_on(rep.read(&foreign)).unwrap_err();
        match err {
            FdbError::AllReplicasFailed { op, copies, last } => {
                assert_eq!(op, "read");
                assert_eq!(copies, 2);
                assert!(matches!(*last, FdbError::BackendMismatch { .. }));
            }
            other => panic!("expected AllReplicasFailed, got {other}"),
        }
    }
}
