//! [`ReplicatedStore`]: fan-out writes to N replica Stores, reads
//! balanced across healthy replicas by a [`ReadPolicy`].

use crate::fdb::backend::{LocalBoxFuture, Store, StoreSession};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::FdbError;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

/// Where a replicated read starts probing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadPolicy {
    /// Always probe replica 0 first — the original behaviour; keeps all
    /// read load on the primary.
    FirstHealthy,
    /// Rotate the starting replica per read, spreading read load evenly
    /// across healthy replicas (the default). Unhealthy replicas are
    /// skipped by falling through the rotation, so availability matches
    /// `FirstHealthy`.
    #[default]
    RoundRobin,
}

/// A replicating Store. `archive()` writes the field to every replica
/// and returns the primary's (replica 0's) location — that is what the
/// Catalogue indexes. `read()` probes replicas starting at the
/// [`ReadPolicy`]'s pick and returns the first healthy answer; replicas
/// whose client cannot resolve the handle report
/// [`FdbError::BackendMismatch`] and are skipped. If every replica
/// fails, the typed [`FdbError::AllReplicasFailed`] carries the replica
/// count and the last underlying error.
pub struct ReplicatedStore {
    replicas: Vec<Box<dyn Store>>,
    policy: ReadPolicy,
    /// rotation cursor for [`ReadPolicy::RoundRobin`]
    next_read: usize,
}

impl ReplicatedStore {
    /// `replicas` must be non-empty; the builder validates `copies >= 1`
    /// before constructing one.
    pub fn new(replicas: Vec<Box<dyn Store>>) -> ReplicatedStore {
        assert!(!replicas.is_empty(), "ReplicatedStore needs >= 1 replica");
        ReplicatedStore {
            replicas,
            policy: ReadPolicy::default(),
            next_read: 0,
        }
    }

    pub fn with_read_policy(mut self, policy: ReadPolicy) -> ReplicatedStore {
        self.policy = policy;
        self
    }

    pub fn read_policy(&self) -> ReadPolicy {
        self.policy
    }

    pub fn copies(&self) -> usize {
        self.replicas.len()
    }

    /// The replica a read should probe first under the active policy.
    fn read_start(&mut self) -> usize {
        match self.policy {
            ReadPolicy::FirstHealthy => 0,
            ReadPolicy::RoundRobin => {
                let start = self.next_read % self.replicas.len();
                self.next_read = self.next_read.wrapping_add(1);
                start
            }
        }
    }
}

impl Store for ReplicatedStore {
    fn name(&self) -> &'static str {
        "replicated"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        Box::pin(async move {
            let mut primary = None;
            for replica in &mut self.replicas {
                let loc = replica.archive(ds, colloc, id, data.clone()).await?;
                if primary.is_none() {
                    primary = Some(loc);
                }
            }
            Ok(primary.expect("at least one replica"))
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            for replica in &mut self.replicas {
                replica.flush().await?;
            }
            Ok(())
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(async move {
            let copies = self.replicas.len();
            let start = self.read_start();
            let mut last = None;
            for k in 0..copies {
                let idx = (start + k) % copies;
                match self.replicas[idx].read(handle).await {
                    Ok(bytes) => return Ok(bytes),
                    Err(e) => last = Some(e),
                }
            }
            Err(FdbError::AllReplicasFailed {
                op: "read",
                copies,
                last: Box::new(last.expect("at least one replica")),
            })
        })
    }

    /// Catalogue-bypassing retrieval is forwarded when EVERY replica
    /// supports it (replicas are instances of one config, so in practice
    /// all or none do); lookups try replicas in order, first hit wins.
    fn direct_retrieve_enabled(&self) -> bool {
        self.replicas.iter().all(|r| r.direct_retrieve_enabled())
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(async move {
            for replica in &mut self.replicas {
                if let Some(loc) = replica.retrieve_direct(ds, id).await {
                    return Some(loc);
                }
            }
            None
        })
    }

    fn supports_wipe(&self) -> bool {
        self.replicas.iter().all(|r| r.supports_wipe())
    }

    fn wipe_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        Box::pin(async move {
            let mut any = false;
            for replica in &mut self.replicas {
                any |= replica.wipe_dataset(ds).await;
            }
            any
        })
    }

    fn take_lock_time(&self) -> SimTime {
        self.replicas
            .iter()
            .map(|r| r.take_lock_time())
            .fold(SimTime::ZERO, |a, b| a + b)
    }

    fn session(&mut self) -> Option<Box<dyn StoreSession>> {
        // fan a session out of every replica: the session's writes still
        // hit all N copies, and its reads rotate independently
        let mut replicas = Vec::with_capacity(self.replicas.len());
        for replica in &mut self.replicas {
            replicas.push(replica.session()?.into_store());
        }
        Some(Box::new(
            ReplicatedStore::new(replicas).with_read_policy(self.policy),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullStore};
    use std::cell::Cell;
    use std::rc::Rc;

    /// A Null-semantics store that counts the reads it serves — lets the
    /// rotation tests observe which replica a read landed on.
    struct CountingStore {
        reads: Rc<Cell<usize>>,
    }

    impl Store for CountingStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            crate::fdb::backend::ready(match handle {
                DataHandle::Null { length } => {
                    self.reads.set(self.reads.get() + 1);
                    Ok(Bytes::virt(*length, 0))
                }
                other => Err(FdbError::BackendMismatch {
                    store: "null",
                    handle: other.backend_name(),
                }),
            })
        }
    }

    fn counting_pair() -> (ReplicatedStore, Rc<Cell<usize>>, Rc<Cell<usize>>) {
        let (c0, c1) = (Rc::new(Cell::new(0)), Rc::new(Cell::new(0)));
        let rep = ReplicatedStore::new(vec![
            Box::new(CountingStore { reads: c0.clone() }),
            Box::new(CountingStore { reads: c1.clone() }),
        ]);
        (rep, c0, c1)
    }

    #[test]
    fn round_robin_rotates_reads_across_replicas() {
        let (mut rep, c0, c1) = counting_pair();
        assert_eq!(rep.read_policy(), ReadPolicy::RoundRobin);
        let h = DataHandle::Null { length: 8 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        // rotation: 4 reads over 2 replicas -> 2 each (not 4 on primary)
        assert_eq!((c0.get(), c1.get()), (2, 2));
    }

    #[test]
    fn first_healthy_keeps_reads_on_primary() {
        let (rep, c0, c1) = counting_pair();
        let mut rep = rep.with_read_policy(ReadPolicy::FirstHealthy);
        let h = DataHandle::Null { length: 8 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        assert_eq!((c0.get(), c1.get()), (4, 0));
    }

    #[test]
    fn round_robin_falls_through_unhealthy_replica() {
        // replica 1 is a posix-handle-only mismatch for Null handles:
        // rotation starting there must fall through to replica 0
        let reads = Rc::new(Cell::new(0));
        let mut rep = ReplicatedStore::new(vec![
            Box::new(CountingStore { reads: reads.clone() }),
            Box::new(NullStore),
        ]);
        let posix = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        // NullStore also mismatches posix handles -> AllReplicasFailed,
        // regardless of which replica the rotation starts at
        for _ in 0..2 {
            let err = block_on(rep.read(&posix)).unwrap_err();
            assert!(matches!(err, FdbError::AllReplicasFailed { .. }));
        }
        // a Null handle always finds a healthy replica
        let h = DataHandle::Null { length: 4 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        // the counting replica saw only its rotation share
        assert_eq!(reads.get(), 2);
    }

    #[test]
    fn primary_location_returned_and_reads_serve() {
        let mut rep = ReplicatedStore::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        assert_eq!(rep.copies(), 2);
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        let loc = block_on(rep.archive(&ds, &ds, &id, Bytes::virt(64, 3))).unwrap();
        let h = DataHandle::from_location(&loc);
        assert_eq!(block_on(rep.read(&h)).unwrap().len(), 64);
    }

    #[test]
    fn all_replicas_mismatching_is_typed_error() {
        let mut rep = ReplicatedStore::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        let foreign = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        let err = block_on(rep.read(&foreign)).unwrap_err();
        match err {
            FdbError::AllReplicasFailed { op, copies, last } => {
                assert_eq!(op, "read");
                assert_eq!(copies, 2);
                assert!(matches!(*last, FdbError::BackendMismatch { .. }));
            }
            other => panic!("expected AllReplicasFailed, got {other}"),
        }
    }
}
