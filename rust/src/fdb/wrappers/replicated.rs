//! [`ReplicatedStore`]: fan-out writes to N replica Stores, reads
//! balanced across healthy replicas by a [`ReadPolicy`].

use crate::fdb::backend::{LocalBoxFuture, Store, StoreSession};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::FdbError;
use crate::sim::exec::Sim;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

/// Where a replicated read starts probing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadPolicy {
    /// Always probe replica 0 first — the original behaviour; keeps all
    /// read load on the primary.
    FirstHealthy,
    /// Rotate the starting replica per read, spreading read load evenly
    /// across healthy replicas (the default). Unhealthy replicas are
    /// skipped by falling through the rotation, so availability matches
    /// `FirstHealthy`.
    #[default]
    RoundRobin,
    /// Probe the replica with the lowest exponentially-weighted moving
    /// average of observed **per-byte** read latency (normalized so a
    /// replica that happened to serve a large coalesced range is not
    /// mistaken for a slow one; each replica is probed once to seed its
    /// estimate). Needs the store's virtual clock
    /// ([`ReplicatedStore::with_clock`], wired by the builder) to
    /// observe latencies; without one the policy degrades to probing
    /// replica 0 first. Failures fall through the ring like the other
    /// policies.
    Fastest,
}

/// EWMA smoothing for [`ReadPolicy::Fastest`] latency estimates: new
/// samples get a quarter of the weight, so a transiently slow replica
/// is not written off on one observation.
const EWMA_ALPHA: f64 = 0.25;

/// Floor of the per-byte latency sample charged to a replica whose
/// probe FAILED (seconds/byte — orders of magnitude above any healthy
/// rate). Failures must poison the estimate — a fast error (e.g. an
/// instant handle mismatch) would otherwise look like the lowest
/// latency and a dead replica would be re-probed first on every read.
/// The actual charge is `max(this, 4 × slowest SUCCESSFUL observation)`
/// — never derived from penalized estimates, so it cannot compound —
/// which keeps it above healthy reads of any size yet finite: a
/// recovered replica decays back through the EWMA once fall-through
/// probes reach it again.
const FAILURE_PENALTY: f64 = 0.01;

/// A replicating Store. `archive()` writes the field to every replica
/// and returns the primary's (replica 0's) location — that is what the
/// Catalogue indexes. `read()` probes replicas starting at the
/// [`ReadPolicy`]'s pick and returns the first healthy answer; replicas
/// whose client cannot resolve the handle report
/// [`FdbError::BackendMismatch`] and are skipped. If every replica
/// fails, the typed [`FdbError::AllReplicasFailed`] carries the replica
/// count and the last underlying error.
pub struct ReplicatedStore {
    replicas: Vec<Box<dyn Store>>,
    policy: ReadPolicy,
    /// rotation cursor for [`ReadPolicy::RoundRobin`]
    next_read: usize,
    /// virtual clock for [`ReadPolicy::Fastest`] latency observation
    clock: Option<Sim>,
    /// per-replica per-byte latency EWMA (seconds/byte); `None` = not
    /// yet measured
    ewma: Vec<Option<f64>>,
    /// slowest SUCCESSFUL sample seen (seconds/byte) — the base of
    /// the failure penalty, kept separate from `ewma` so penalized
    /// estimates never feed back into the penalty
    slowest_healthy: f64,
}

impl ReplicatedStore {
    /// `replicas` must be non-empty; the builder validates `copies >= 1`
    /// before constructing one.
    pub fn new(replicas: Vec<Box<dyn Store>>) -> ReplicatedStore {
        assert!(!replicas.is_empty(), "ReplicatedStore needs >= 1 replica");
        let ewma = vec![None; replicas.len()];
        ReplicatedStore {
            replicas,
            policy: ReadPolicy::default(),
            next_read: 0,
            clock: None,
            ewma,
            slowest_healthy: 0.0,
        }
    }

    pub fn with_read_policy(mut self, policy: ReadPolicy) -> ReplicatedStore {
        self.policy = policy;
        self
    }

    /// Attach the virtual clock [`ReadPolicy::Fastest`] observes read
    /// latencies with (the builder wires this for every replicated
    /// config).
    pub fn with_clock(mut self, sim: &Sim) -> ReplicatedStore {
        self.clock = Some(sim.clone());
        self
    }

    pub fn read_policy(&self) -> ReadPolicy {
        self.policy
    }

    pub fn copies(&self) -> usize {
        self.replicas.len()
    }

    /// The latency estimates [`ReadPolicy::Fastest`] routes by
    /// (seconds/byte; `None` = replica not yet measured).
    pub fn latency_estimates(&self) -> &[Option<f64>] {
        &self.ewma
    }

    /// The replica a read should probe first under the active policy.
    fn read_start(&mut self) -> usize {
        match self.policy {
            ReadPolicy::FirstHealthy => 0,
            ReadPolicy::RoundRobin => {
                let start = self.next_read % self.replicas.len();
                self.next_read = self.next_read.wrapping_add(1);
                start
            }
            ReadPolicy::Fastest => {
                // probe unmeasured replicas first (seeds every estimate),
                // then the current lowest EWMA
                self.ewma
                    .iter()
                    .position(|e| e.is_none())
                    .unwrap_or_else(|| {
                        self.ewma
                            .iter()
                            .enumerate()
                            .min_by(|a, b| {
                                a.1.unwrap_or(f64::MAX).total_cmp(&b.1.unwrap_or(f64::MAX))
                            })
                            .map(|(i, _)| i)
                            .unwrap_or(0)
                    })
            }
        }
    }

    /// Fold one observed sample (seconds/byte) into a replica's EWMA.
    fn observe(&mut self, idx: usize, sample: f64) {
        self.ewma[idx] = Some(match self.ewma[idx] {
            Some(prev) => (1.0 - EWMA_ALPHA) * prev + EWMA_ALPHA * sample,
            None => sample,
        });
    }

    /// One policy-routed read: probe replicas starting at the policy's
    /// pick, first healthy answer wins; latency is observed for
    /// [`ReadPolicy::Fastest`]. Shared by `read` (one raw handle, probed
    /// via the inner `read`) and `read_ranges` (`vectored`: probed via
    /// the inner `read_ranges`, so a strict vectored inner — the RADOS
    /// short-buffer guard — reports a typed error and the wrapper fails
    /// over to the next replica instead of passing corrupt bytes up).
    /// The policy applies **per merged range**, so one plan's ranges
    /// spread over replicas like individual reads would.
    async fn read_one(&mut self, handle: &DataHandle, vectored: bool) -> Result<Bytes, FdbError> {
        let copies = self.replicas.len();
        let start = self.read_start();
        // the estimates only steer `Fastest` — skip the bookkeeping
        // (two clock samples + EWMA fold per read) for other policies
        let observing = self.policy == ReadPolicy::Fastest && self.clock.is_some();
        let mut last = None;
        for k in 0..copies {
            let idx = (start + k) % copies;
            let t0 = if observing {
                self.clock.as_ref().map(|s| s.now())
            } else {
                None
            };
            let r = if vectored {
                self.replicas[idx]
                    .read_ranges(std::slice::from_ref(handle))
                    .await
                    .map(|mut bufs| bufs.pop().expect("one buffer per handle"))
            } else {
                self.replicas[idx].read(handle).await
            };
            match r {
                Ok(bytes) => {
                    if let Some(t0) = t0 {
                        let now = self.clock.as_ref().expect("observing implies clock").now();
                        // per-byte normalization: a replica that served a
                        // large coalesced range must not look slow next
                        // to one that served a single small field
                        let sample =
                            (now - t0).as_secs_f64() / handle.total_len().max(1) as f64;
                        self.slowest_healthy = self.slowest_healthy.max(sample);
                        self.observe(idx, sample);
                    }
                    return Ok(bytes);
                }
                Err(e) => {
                    // charge the failure so `Fastest` stops probing a
                    // dead replica first on every read (an instant error
                    // must not read as "lowest latency"); based on the
                    // slowest SUCCESSFUL sample so it tops healthy reads
                    // of any size without compounding on itself
                    if observing {
                        self.observe(idx, FAILURE_PENALTY.max(4.0 * self.slowest_healthy));
                    }
                    last = Some(e);
                }
            }
        }
        Err(FdbError::AllReplicasFailed {
            op: "read",
            copies,
            last: Box::new(last.expect("at least one replica")),
        })
    }
}

impl Store for ReplicatedStore {
    fn name(&self) -> &'static str {
        "replicated"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        Box::pin(async move {
            let mut primary = None;
            for replica in &mut self.replicas {
                let loc = replica.archive(ds, colloc, id, data.clone()).await?;
                if primary.is_none() {
                    primary = Some(loc);
                }
            }
            Ok(primary.expect("at least one replica"))
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            for replica in &mut self.replicas {
                replica.flush().await?;
            }
            Ok(())
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(self.read_one(handle, false))
    }

    /// Vectored reads apply the [`ReadPolicy`] per merged range: each
    /// planned range is routed like an individual read (through the
    /// inner `read_ranges`, keeping strict vectored error semantics),
    /// so round-robin spreads a plan's ranges over replicas and
    /// `Fastest` keeps its latency estimates warm.
    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            let mut out = Vec::with_capacity(handles.len());
            for handle in handles {
                out.push(self.read_one(handle, true).await?);
            }
            Ok(out)
        })
    }

    /// Catalogue-bypassing retrieval is forwarded when EVERY replica
    /// supports it (replicas are instances of one config, so in practice
    /// all or none do); lookups try replicas in order, first hit wins.
    fn direct_retrieve_enabled(&self) -> bool {
        self.replicas.iter().all(|r| r.direct_retrieve_enabled())
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(async move {
            for replica in &mut self.replicas {
                if let Some(loc) = replica.retrieve_direct(ds, id).await {
                    return Some(loc);
                }
            }
            None
        })
    }

    fn supports_wipe(&self) -> bool {
        self.replicas.iter().all(|r| r.supports_wipe())
    }

    fn wipe_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        Box::pin(async move {
            let mut any = false;
            for replica in &mut self.replicas {
                any |= replica.wipe_dataset(ds).await;
            }
            any
        })
    }

    fn take_lock_time(&self) -> SimTime {
        self.replicas
            .iter()
            .map(|r| r.take_lock_time())
            .fold(SimTime::ZERO, |a, b| a + b)
    }

    fn session(&mut self) -> Option<Box<dyn StoreSession>> {
        // fan a session out of every replica: the session's writes still
        // hit all N copies, and its reads rotate (or race by latency)
        // independently — each session gathers its own EWMA estimates
        let mut replicas = Vec::with_capacity(self.replicas.len());
        for replica in &mut self.replicas {
            replicas.push(replica.session()?.into_store());
        }
        let mut session = ReplicatedStore::new(replicas).with_read_policy(self.policy);
        if let Some(sim) = &self.clock {
            session = session.with_clock(sim);
        }
        Some(Box::new(session))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullStore};
    use std::cell::Cell;
    use std::rc::Rc;

    /// A Null-semantics store that counts the reads it serves — lets the
    /// rotation tests observe which replica a read landed on.
    struct CountingStore {
        reads: Rc<Cell<usize>>,
    }

    impl Store for CountingStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            crate::fdb::backend::ready(match handle {
                DataHandle::Null { length } => {
                    self.reads.set(self.reads.get() + 1);
                    Ok(Bytes::virt(*length, 0))
                }
                other => Err(FdbError::BackendMismatch {
                    store: "null",
                    handle: other.backend_name(),
                }),
            })
        }
    }

    fn counting_pair() -> (ReplicatedStore, Rc<Cell<usize>>, Rc<Cell<usize>>) {
        let (c0, c1) = (Rc::new(Cell::new(0)), Rc::new(Cell::new(0)));
        let rep = ReplicatedStore::new(vec![
            Box::new(CountingStore { reads: c0.clone() }),
            Box::new(CountingStore { reads: c1.clone() }),
        ]);
        (rep, c0, c1)
    }

    #[test]
    fn round_robin_rotates_reads_across_replicas() {
        let (mut rep, c0, c1) = counting_pair();
        assert_eq!(rep.read_policy(), ReadPolicy::RoundRobin);
        let h = DataHandle::Null { length: 8 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        // rotation: 4 reads over 2 replicas -> 2 each (not 4 on primary)
        assert_eq!((c0.get(), c1.get()), (2, 2));
    }

    #[test]
    fn first_healthy_keeps_reads_on_primary() {
        let (rep, c0, c1) = counting_pair();
        let mut rep = rep.with_read_policy(ReadPolicy::FirstHealthy);
        let h = DataHandle::Null { length: 8 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        assert_eq!((c0.get(), c1.get()), (4, 0));
    }

    #[test]
    fn round_robin_falls_through_unhealthy_replica() {
        // replica 1 is a posix-handle-only mismatch for Null handles:
        // rotation starting there must fall through to replica 0
        let reads = Rc::new(Cell::new(0));
        let mut rep = ReplicatedStore::new(vec![
            Box::new(CountingStore { reads: reads.clone() }),
            Box::new(NullStore),
        ]);
        let posix = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        // NullStore also mismatches posix handles -> AllReplicasFailed,
        // regardless of which replica the rotation starts at
        for _ in 0..2 {
            let err = block_on(rep.read(&posix)).unwrap_err();
            assert!(matches!(err, FdbError::AllReplicasFailed { .. }));
        }
        // a Null handle always finds a healthy replica
        let h = DataHandle::Null { length: 4 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        // the counting replica saw only its rotation share
        assert_eq!(reads.get(), 2);
    }

    #[test]
    fn primary_location_returned_and_reads_serve() {
        let mut rep = ReplicatedStore::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        assert_eq!(rep.copies(), 2);
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        let loc = block_on(rep.archive(&ds, &ds, &id, Bytes::virt(64, 3))).unwrap();
        let h = DataHandle::from_location(&loc);
        assert_eq!(block_on(rep.read(&h)).unwrap().len(), 64);
    }

    /// A Null-semantics store whose reads take a configurable virtual
    /// duration — lets the Fastest tests shape per-replica latency.
    struct DelayStore {
        sim: Sim,
        delay: Rc<Cell<SimTime>>,
        reads: Rc<Cell<usize>>,
    }

    impl Store for DelayStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            Box::pin(async move {
                match handle {
                    DataHandle::Null { length } => {
                        self.sim.sleep(self.delay.get()).await;
                        self.reads.set(self.reads.get() + 1);
                        Ok(Bytes::virt(*length, 0))
                    }
                    other => Err(FdbError::BackendMismatch {
                        store: "null",
                        handle: other.backend_name(),
                    }),
                }
            })
        }
    }

    /// (tunable delay, reads served) of one probe replica.
    type Probe = (Rc<Cell<SimTime>>, Rc<Cell<usize>>);

    fn delayed_pair(sim: &Sim, d0: SimTime, d1: SimTime) -> (ReplicatedStore, Probe, Probe) {
        let mk = |d: SimTime| {
            let delay = Rc::new(Cell::new(d));
            let reads = Rc::new(Cell::new(0));
            let store = DelayStore {
                sim: sim.clone(),
                delay: delay.clone(),
                reads: reads.clone(),
            };
            (store, delay, reads)
        };
        let (s0, delay0, reads0) = mk(d0);
        let (s1, delay1, reads1) = mk(d1);
        let rep = ReplicatedStore::new(vec![Box::new(s0), Box::new(s1)])
            .with_read_policy(ReadPolicy::Fastest)
            .with_clock(sim);
        (rep, (delay0, reads0), (delay1, reads1))
    }

    #[test]
    fn fastest_routes_to_lowest_latency_replica() {
        let sim = Sim::new();
        let (mut rep, (_, slow_reads), (_, fast_reads)) = delayed_pair(
            &sim,
            SimTime::micros(500), // replica 0: slow
            SimTime::micros(50),  // replica 1: fast
        );
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            for _ in 0..10 {
                rep.read(&h).await.unwrap();
            }
            let est = rep.latency_estimates();
            assert!(est.iter().all(|e| e.is_some()), "both replicas seeded");
            assert!(est[1].unwrap() < est[0].unwrap());
        });
        sim.run();
        // one seeding probe each, then every read lands on the fast one
        assert_eq!(slow_reads.get(), 1);
        assert_eq!(fast_reads.get(), 9);
    }

    #[test]
    fn fastest_adapts_when_latencies_change() {
        let sim = Sim::new();
        let (mut rep, (_, other_reads), (fast_delay, fast_reads)) =
            delayed_pair(&sim, SimTime::micros(200), SimTime::micros(50));
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            for _ in 0..6 {
                rep.read(&h).await.unwrap();
            }
            // the fast replica degrades (e.g. a rebuilding OST behind it):
            // its EWMA rises past the other's within a few observations
            fast_delay.set(SimTime::micros(5000));
            for _ in 0..6 {
                rep.read(&h).await.unwrap();
            }
        });
        sim.run();
        // after the flip, traffic moves back to the now-faster replica
        assert!(
            other_reads.get() >= 4,
            "routing never adapted: other={} fast={}",
            other_reads.get(),
            fast_reads.get()
        );
    }

    /// An always-failing replica (e.g. a lost client connection) that
    /// counts how often it is probed.
    struct FailStore {
        probes: Rc<Cell<usize>>,
    }

    impl Store for FailStore {
        fn name(&self) -> &'static str {
            "null"
        }

        fn archive<'a>(
            &'a mut self,
            _ds: &'a Key,
            _colloc: &'a Key,
            _id: &'a Key,
            data: Bytes,
        ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
            crate::fdb::backend::ready(Ok(FieldLocation::Null { length: data.len() }))
        }

        fn read<'a>(
            &'a mut self,
            _handle: &'a DataHandle,
        ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
            self.probes.set(self.probes.get() + 1);
            crate::fdb::backend::ready(Err(FdbError::Backend {
                backend: "null",
                detail: "replica down".to_string(),
            }))
        }
    }

    #[test]
    fn fastest_stops_probing_a_dead_replica_first() {
        // a dead replica fails instantly; without the failure penalty
        // its EWMA would stay unseeded (or near zero) and every read
        // would probe it first before falling through
        let sim = Sim::new();
        let healthy_reads = Rc::new(Cell::new(0));
        let probes = Rc::new(Cell::new(0));
        let healthy = DelayStore {
            sim: sim.clone(),
            delay: Rc::new(Cell::new(SimTime::micros(50))),
            reads: healthy_reads.clone(),
        };
        let dead = FailStore {
            probes: probes.clone(),
        };
        let mut rep = ReplicatedStore::new(vec![Box::new(healthy), Box::new(dead)])
            .with_read_policy(ReadPolicy::Fastest)
            .with_clock(&sim);
        sim.spawn(async move {
            let h = DataHandle::Null { length: 8 };
            for _ in 0..10 {
                rep.read(&h).await.unwrap();
            }
        });
        sim.run();
        // seeded once, then the penalty keeps it out of the rotation
        assert_eq!(probes.get(), 1, "dead replica re-probed");
        assert_eq!(healthy_reads.get(), 10);
    }

    #[test]
    fn fastest_without_clock_still_serves_and_falls_through() {
        // no clock: no latency observations, so the policy degrades to
        // probing replica 0 first — availability semantics unchanged
        let (mut rep, c0, c1) = counting_pair();
        rep = rep.with_read_policy(ReadPolicy::Fastest);
        let h = DataHandle::Null { length: 8 };
        for _ in 0..4 {
            block_on(rep.read(&h)).unwrap();
        }
        assert_eq!((c0.get(), c1.get()), (4, 0));
        assert!(rep.latency_estimates().iter().all(|e| e.is_none()));
    }

    #[test]
    fn all_replicas_mismatching_is_typed_error() {
        let mut rep = ReplicatedStore::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        let foreign = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        let err = block_on(rep.read(&foreign)).unwrap_err();
        match err {
            FdbError::AllReplicasFailed { op, copies, last } => {
                assert_eq!(op, "read");
                assert_eq!(copies, 2);
                assert!(matches!(*last, FdbError::BackendMismatch { .. }));
            }
            other => panic!("expected AllReplicasFailed, got {other}"),
        }
    }
}
