//! [`ReplicatedStore`]: fan-out writes to N replica Stores, reads from
//! the first healthy replica.

use crate::fdb::backend::{LocalBoxFuture, Store};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::FdbError;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

/// A replicating Store. `archive()` writes the field to every replica
/// and returns the primary's (replica 0's) location — that is what the
/// Catalogue indexes. `read()` offers the handle to each replica in
/// order and returns the first healthy answer; replicas whose client
/// cannot resolve the handle report [`FdbError::BackendMismatch`] and
/// are skipped. If every replica fails, the typed
/// [`FdbError::AllReplicasFailed`] carries the replica count and the
/// last underlying error.
pub struct ReplicatedStore {
    replicas: Vec<Box<dyn Store>>,
}

impl ReplicatedStore {
    /// `replicas` must be non-empty; the builder validates `copies >= 1`
    /// before constructing one.
    pub fn new(replicas: Vec<Box<dyn Store>>) -> ReplicatedStore {
        assert!(!replicas.is_empty(), "ReplicatedStore needs >= 1 replica");
        ReplicatedStore { replicas }
    }

    pub fn copies(&self) -> usize {
        self.replicas.len()
    }
}

impl Store for ReplicatedStore {
    fn name(&self) -> &'static str {
        "replicated"
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        Box::pin(async move {
            let mut primary = None;
            for replica in &mut self.replicas {
                let loc = replica.archive(ds, colloc, id, data.clone()).await?;
                if primary.is_none() {
                    primary = Some(loc);
                }
            }
            Ok(primary.expect("at least one replica"))
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            for replica in &mut self.replicas {
                replica.flush().await?;
            }
            Ok(())
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(async move {
            let copies = self.replicas.len();
            let mut last = None;
            for replica in &mut self.replicas {
                match replica.read(handle).await {
                    Ok(bytes) => return Ok(bytes),
                    Err(e) => last = Some(e),
                }
            }
            Err(FdbError::AllReplicasFailed {
                op: "read",
                copies,
                last: Box::new(last.expect("at least one replica")),
            })
        })
    }

    /// Catalogue-bypassing retrieval is forwarded when EVERY replica
    /// supports it (replicas are instances of one config, so in practice
    /// all or none do); lookups try replicas in order, first hit wins.
    fn direct_retrieve_enabled(&self) -> bool {
        self.replicas.iter().all(|r| r.direct_retrieve_enabled())
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(async move {
            for replica in &mut self.replicas {
                if let Some(loc) = replica.retrieve_direct(ds, id).await {
                    return Some(loc);
                }
            }
            None
        })
    }

    fn supports_wipe(&self) -> bool {
        self.replicas.iter().all(|r| r.supports_wipe())
    }

    fn wipe_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        Box::pin(async move {
            let mut any = false;
            for replica in &mut self.replicas {
                any |= replica.wipe_dataset(ds).await;
            }
            any
        })
    }

    fn take_lock_time(&self) -> SimTime {
        self.replicas
            .iter()
            .map(|r| r.take_lock_time())
            .fold(SimTime::ZERO, |a, b| a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullStore};

    #[test]
    fn primary_location_returned_and_reads_serve() {
        let mut rep = ReplicatedStore::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        assert_eq!(rep.copies(), 2);
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        let loc = block_on(rep.archive(&ds, &ds, &id, Bytes::virt(64, 3))).unwrap();
        let h = DataHandle::from_location(&loc);
        assert_eq!(block_on(rep.read(&h)).unwrap().len(), 64);
    }

    #[test]
    fn all_replicas_mismatching_is_typed_error() {
        let mut rep = ReplicatedStore::new(vec![Box::new(NullStore), Box::new(NullStore)]);
        let foreign = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        let err = block_on(rep.read(&foreign)).unwrap_err();
        match err {
            FdbError::AllReplicasFailed { op, copies, last } => {
                assert_eq!(op, "read");
                assert_eq!(copies, 2);
                assert!(matches!(*last, FdbError::BackendMismatch { .. }));
            }
            other => panic!("expected AllReplicasFailed, got {other}"),
        }
    }
}
