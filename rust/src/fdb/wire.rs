//! Binary wire format helpers for the FDB's persistent structures
//! (TOC records, sub-TOC entries, index pages). Little-endian,
//! length-prefixed strings — everything written to simulated storage is
//! real serialized bytes that the readers genuinely parse back.

/// Append-only encoder.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
        self
    }

    pub fn bytes(&mut self, b: &[u8]) -> &mut Self {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-based decoder; all methods return `None` on truncation so
/// corrupt/torn records are detected, never panic.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn u8(&mut self) -> Option<u8> {
        let v = *self.buf.get(self.pos)?;
        self.pos += 1;
        Some(v)
    }

    pub fn u32(&mut self) -> Option<u32> {
        let b = self.buf.get(self.pos..self.pos + 4)?;
        self.pos += 4;
        Some(u32::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Option<u64> {
        let b = self.buf.get(self.pos..self.pos + 8)?;
        self.pos += 8;
        Some(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        let b = self.buf.get(self.pos..self.pos + n)?;
        self.pos += n;
        String::from_utf8(b.to_vec()).ok()
    }

    pub fn bytes(&mut self) -> Option<Vec<u8>> {
        let n = self.u32()? as usize;
        let b = self.buf.get(self.pos..self.pos + n)?;
        self.pos += n;
        Some(b.to_vec())
    }

    pub fn skip(&mut self, n: usize) -> Option<()> {
        if self.pos + n > self.buf.len() {
            return None;
        }
        self.pos += n;
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut e = Enc::new();
        e.u8(7).u32(1234).u64(u64::MAX).str("hello").bytes(&[1, 2, 3]);
        let buf = e.finish();
        let mut d = Dec::new(&buf);
        assert_eq!(d.u8(), Some(7));
        assert_eq!(d.u32(), Some(1234));
        assert_eq!(d.u64(), Some(u64::MAX));
        assert_eq!(d.str().as_deref(), Some("hello"));
        assert_eq!(d.bytes(), Some(vec![1, 2, 3]));
        assert_eq!(d.remaining(), 0);
    }

    #[test]
    fn truncation_detected() {
        let mut e = Enc::new();
        e.str("truncate-me");
        let buf = e.finish();
        let mut d = Dec::new(&buf[..buf.len() - 2]);
        assert_eq!(d.str(), None);
    }

    #[test]
    fn empty_string_and_bytes() {
        let mut e = Enc::new();
        e.str("").bytes(&[]);
        let buf = e.finish();
        let mut d = Dec::new(&buf);
        assert_eq!(d.str().as_deref(), Some(""));
        assert_eq!(d.bytes(), Some(vec![]));
    }
}
