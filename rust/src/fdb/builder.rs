//! Declarative FDB construction: a [`BackendConfig`] names the backend
//! pair and its knobs; [`FdbBuilder`] validates it and wires a matching
//! Store/Catalogue pair. Replaces the former ad-hoc
//! `setup::{posix,daos,rados,s3}_fdb` constructors so the coordinator,
//! benches, workflow driver, examples, and tests all construct FDBs the
//! same way.

use std::rc::Rc;

use super::backend::{Catalogue, NullCatalogue, NullStore, Store};
use super::daos::catalogue::DaosCatalogue;
use super::daos::store::DaosStore;
use super::fdb::Fdb;
use super::posix::catalogue::PosixCatalogue;
use super::posix::store::PosixStore;
use super::rados::catalogue::RadosCatalogue;
use super::rados::store::{RadosStore, RadosStoreConfig};
use super::s3::store::S3Store;
use super::schema::Schema;
use super::FdbError;
use crate::ceph::{Ceph, CephPool, Redundancy};
use crate::daos::Daos;
use crate::hw::node::Node;
use crate::lustre::Lustre;
use crate::s3::MemS3;
use crate::sim::exec::Sim;
use crate::sim::trace::Trace;

/// Which backend pair an FDB instance runs over, plus its knobs.
pub enum BackendConfig {
    /// POSIX Store + Catalogue on a Lustre mount (thesis §2.7.2).
    Posix { fs: Rc<Lustre>, root: String },
    /// DAOS Store + Catalogue (thesis §3.1). `hash_oids` enables the
    /// identifier-hash OID mode (§3.1.2 future-work optimisation):
    /// retrieve() bypasses the Catalogue entirely.
    Daos {
        daos: Rc<Daos>,
        pool: String,
        hash_oids: bool,
    },
    /// Ceph/RADOS Store + Catalogue (thesis §3.2) with the Fig 3.5
    /// store-configuration sweep knobs.
    Rados {
        ceph: Rc<Ceph>,
        pool: Rc<CephPool>,
        store: RadosStoreConfig,
    },
    /// S3 Store + process-local Null catalogue (thesis §3.3 discarded an
    /// S3 Catalogue for lack of atomic append). `multipart` accumulates
    /// fields per (dataset, collocation) into one multipart object.
    S3 {
        s3: Rc<MemS3>,
        client_tag: String,
        multipart: bool,
    },
    /// Zero-cost sink + in-memory catalogue — client-overhead
    /// experiments (Fig 4.30) and API tests.
    Null,
}

impl BackendConfig {
    /// Short tag for diagnostics.
    pub fn label(&self) -> &'static str {
        match self {
            BackendConfig::Posix { .. } => "posix",
            BackendConfig::Daos { .. } => "daos",
            BackendConfig::Rados { .. } => "rados",
            BackendConfig::S3 { .. } => "s3",
            BackendConfig::Null => "null",
        }
    }

    /// The schema variant a backend pair defaults to.
    fn default_schema(&self) -> Schema {
        match self {
            BackendConfig::Posix { .. } => Schema::default_posix(),
            _ => Schema::daos_variant(),
        }
    }

    fn validate(&self, node: Option<&Rc<Node>>) -> Result<(), FdbError> {
        let invalid = |msg: &str| Err(FdbError::InvalidConfig(msg.to_string()));
        match self {
            BackendConfig::Posix { root, .. } => {
                if root.is_empty() || !root.starts_with('/') {
                    return invalid("posix root must be an absolute path");
                }
                if node.is_none() {
                    return invalid("posix backend needs a client node");
                }
            }
            BackendConfig::Daos { pool, .. } => {
                if pool.is_empty() {
                    return invalid("daos pool label must be non-empty");
                }
                if node.is_none() {
                    return invalid("daos backend needs a client node");
                }
            }
            BackendConfig::Rados { store, .. } => {
                if store.pg_per_pool == 0 {
                    return invalid("rados pg_per_pool must be > 0");
                }
                if node.is_none() {
                    return invalid("rados backend needs a client node");
                }
            }
            BackendConfig::S3 { client_tag, .. } => {
                if client_tag.is_empty() {
                    return invalid("s3 client tag must be non-empty");
                }
            }
            BackendConfig::Null => {}
        }
        Ok(())
    }
}

/// Builds one [`Fdb`] per simulated process from a [`BackendConfig`].
pub struct FdbBuilder {
    sim: Sim,
    node: Option<Rc<Node>>,
    trace: Option<Trace>,
    schema: Option<Schema>,
    config: Option<BackendConfig>,
}

impl FdbBuilder {
    pub fn new(sim: &Sim) -> FdbBuilder {
        FdbBuilder {
            sim: sim.clone(),
            node: None,
            trace: None,
            schema: None,
            config: None,
        }
    }

    /// The client node this FDB instance's backends run on (required
    /// for all backends except S3/Null).
    pub fn node(mut self, node: &Rc<Node>) -> FdbBuilder {
        self.node = Some(node.clone());
        self
    }

    /// Attach a shared trace collector (benchmark profiling).
    pub fn trace(mut self, trace: &Trace) -> FdbBuilder {
        self.trace = Some(trace.clone());
        self
    }

    /// Override the backend's default schema variant.
    pub fn schema(mut self, schema: Schema) -> FdbBuilder {
        self.schema = Some(schema);
        self
    }

    pub fn backend(mut self, config: BackendConfig) -> FdbBuilder {
        self.config = Some(config);
        self
    }

    /// Validate the config and wire the matching Store/Catalogue pair.
    pub fn build(self) -> Result<Fdb, FdbError> {
        let config = self
            .config
            .ok_or_else(|| FdbError::InvalidConfig("no backend configured".to_string()))?;
        config.validate(self.node.as_ref())?;
        let schema = self
            .schema
            .unwrap_or_else(|| config.default_schema());
        let (store, catalogue): (Box<dyn Store>, Box<dyn Catalogue>) = match config {
            BackendConfig::Posix { fs, root } => {
                let node = self.node.as_ref().unwrap();
                let store = PosixStore::new(fs.client(node), &root);
                let catalogue =
                    PosixCatalogue::new(fs.client(node), &root, schema.clone());
                (Box::new(store), Box::new(catalogue))
            }
            BackendConfig::Daos {
                daos,
                pool,
                hash_oids,
            } => {
                let node = self.node.as_ref().unwrap();
                let mut store = DaosStore::new(daos.client(node), &pool);
                store.hash_oids = hash_oids;
                // root container label fixed by the administrator
                // (thesis §3.1.2)
                let catalogue = DaosCatalogue::new(
                    daos.client(node),
                    &pool,
                    "fdb_root",
                    schema.clone(),
                );
                (Box::new(store), Box::new(catalogue))
            }
            BackendConfig::Rados {
                ceph,
                pool,
                store: store_cfg,
            } => {
                let node = self.node.as_ref().unwrap();
                let store = RadosStore::new(&ceph, ceph.client(node), &pool)
                    .with_config(store_cfg);
                // Omaps cannot live in erasure-coded pools (librados
                // restriction, thesis §2.4) — for an EC data pool the
                // Catalogue uses the replicated metadata pool, the
                // standard Ceph deployment pattern.
                let meta_pool = if matches!(pool.redundancy, Redundancy::Erasure(..)) {
                    ceph.meta_pool()
                } else {
                    pool.clone()
                };
                let catalogue =
                    RadosCatalogue::new(ceph.client(node), &meta_pool, schema.clone());
                (Box::new(store), Box::new(catalogue))
            }
            BackendConfig::S3 {
                s3,
                client_tag,
                multipart,
            } => {
                let mut store = S3Store::new(&s3, &client_tag);
                store.multipart = multipart;
                (Box::new(store), Box::new(NullCatalogue::new()))
            }
            BackendConfig::Null => (Box::new(NullStore), Box::new(NullCatalogue::new())),
        };
        let mut fdb = Fdb::new(&self.sim, schema, store, catalogue);
        if let Some(trace) = self.trace {
            fdb = fdb.with_trace(trace);
        }
        Ok(fdb)
    }
}
