//! Declarative FDB construction: a [`BackendConfig`] names the backend
//! pair and its knobs; [`FdbBuilder`] validates it and wires a matching
//! Store/Catalogue pair. Replaces the former ad-hoc
//! `setup::{posix,daos,rados,s3}_fdb` constructors so the coordinator,
//! benches, workflow driver, examples, and tests all construct FDBs the
//! same way.
//!
//! Configs compose recursively through the wrapper variants: `Tiered`,
//! `Replicated`, and `Sharded` wrap *other* configs, so a tiered store
//! over a replicated RADOS store with a sharded catalogue is a single
//! config tree, validated and built as a whole.

use std::rc::Rc;

use super::backend::{Catalogue, NullCatalogue, NullStore, SharedNullCatalogue, Store};
use super::daos::catalogue::DaosCatalogue;
use super::fault::{FaultCatalogue, FaultPlan, FaultStore};
use super::daos::store::DaosStore;
use super::fdb::Fdb;
use super::posix::catalogue::PosixCatalogue;
use super::posix::store::PosixStore;
use super::rados::catalogue::RadosCatalogue;
use super::rados::store::{RadosStore, RadosStoreConfig};
use super::s3::store::S3Store;
use super::schema::Schema;
use super::telemetry::{InstrumentCatalogue, InstrumentStore, MetricsRegistry};
use super::wrappers::{ReadPolicy, ReplicatedStore, ShardedCatalogue, TieredStore};
use super::FdbError;
use crate::ceph::{Ceph, CephPool, Redundancy};
use crate::daos::Daos;
use crate::hw::node::Node;
use crate::lustre::Lustre;
use crate::s3::MemS3;
use crate::sim::exec::Sim;
use crate::sim::trace::Trace;

/// The client's I/O-depth profile: how many store operations an FDB
/// instance may keep in flight on the batched paths, and whether the
/// POSIX catalogue may cache loaded index blobs reader-side.
///
/// `depth = 1` (the default) is exactly the pre-engine behaviour: one
/// store client, serial ops. `depth = N` mints N per-request client
/// sessions ([`crate::fdb::backend::StoreSession`]) and admits up to N
/// concurrent reads/writes through a sim-native semaphore — the event-
/// queue asynchrony of the DAOS interface papers. Results are byte- and
/// order-identical across depths; only virtual time changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IoProfile {
    /// max in-flight store operations on `archive_many` /
    /// `retrieve_many` (1..=64)
    pub depth: usize,
    /// POSIX catalogue reader-side index caching: point lookups load an
    /// index blob once per `(file, offset)` and serve later lookups from
    /// memory (the real FDB loads indexes whole; blobs are immutable so
    /// this is always coherent). Off by default to keep the thesis'
    /// calibrated lookup costs; the queue-depth sweeps enable it so the
    /// serial index client does not mask store-side parallelism.
    pub preload_indexes: bool,
    /// Read-plan coalescing ([`crate::fdb::plan`]): on the batched
    /// retrieve paths, merge catalogue-resolved field reads that sit in
    /// the same physical container with holes of at most this many
    /// bytes into one ranged I/O. 0 (the default) disables the planner
    /// — the exact legacy per-field read behaviour.
    pub coalesce_gap: u64,
    /// Cap on one merged read's size; the planner splits runs at this
    /// bound (a single field larger than the cap still reads whole).
    pub coalesce_max: u64,
    /// Durable (WAL'd) catalogue writes ([`crate::fdb::fault::wal`]):
    /// the POSIX catalogue logs an fdatasync'd intent record per archive
    /// before mutating its in-memory index, making unflushed entries
    /// recoverable after a producer crash via [`super::fdb::Fdb::recover`].
    /// Off by default — the exact legacy (non-logging) write path.
    pub durable: bool,
    /// Slow-op threshold in microseconds ([`crate::fdb::telemetry`]):
    /// when a metrics registry is attached, any operation whose raw
    /// duration meets or exceeds this is recorded in the registry's
    /// slow-op log with its class, backend, and duration. 0 (the
    /// default) disables the log.
    pub slow_op_us: u64,
}

impl Default for IoProfile {
    fn default() -> IoProfile {
        IoProfile {
            depth: 1,
            preload_indexes: false,
            coalesce_gap: 0,
            coalesce_max: IoProfile::DEFAULT_COALESCE_MAX,
            durable: false,
            slow_op_us: 0,
        }
    }
}

impl IoProfile {
    /// Default cap on a merged read: 8 MiB, one full Lustre stripe.
    pub const DEFAULT_COALESCE_MAX: u64 = 8 << 20;

    /// Shorthand for a depth-N profile with default caching.
    pub fn depth(depth: usize) -> IoProfile {
        IoProfile {
            depth,
            ..IoProfile::default()
        }
    }

    pub fn with_preload_indexes(mut self, on: bool) -> IoProfile {
        self.preload_indexes = on;
        self
    }

    /// Enable read-plan coalescing with the given hole budget.
    pub fn with_coalesce_gap(mut self, gap: u64) -> IoProfile {
        self.coalesce_gap = gap;
        self
    }

    /// Cap one merged read's size (0 = unbounded).
    pub fn with_coalesce_max(mut self, max: u64) -> IoProfile {
        self.coalesce_max = max;
        self
    }

    /// Enable WAL'd (crash-recoverable) catalogue writes.
    pub fn with_durable(mut self, on: bool) -> IoProfile {
        self.durable = on;
        self
    }

    /// Log ops at or above this many µs to the slow-op log (0 = off).
    pub fn with_slow_op_us(mut self, micros: u64) -> IoProfile {
        self.slow_op_us = micros;
        self
    }

    /// Whether the read planner runs on the batched retrieve paths.
    pub fn coalesce_enabled(&self) -> bool {
        self.coalesce_gap > 0
    }

    /// Bounds check (shared by the builder and the CLI front-ends).
    pub fn validate(&self) -> Result<(), FdbError> {
        if self.depth == 0 || self.depth > 64 {
            return Err(FdbError::InvalidConfig(format!(
                "io depth must be in 1..=64 (got {})",
                self.depth
            )));
        }
        if self.coalesce_gap > 0 && self.coalesce_max > 0 && self.coalesce_gap >= self.coalesce_max
        {
            return Err(FdbError::InvalidConfig(format!(
                "coalesce gap ({}) must be smaller than coalesce max ({}) — \
                 a hole budget at or above the read cap would merge nothing but holes",
                self.coalesce_gap, self.coalesce_max
            )));
        }
        Ok(())
    }
}

/// The resilience policy of one FDB instance: how the I/O engine and
/// the replicated store respond to slow, failing, or dead backends.
///
/// The default is everything OFF — byte-identical legacy behaviour:
/// one attempt per op, no deadline, no hedging, no quarantine. Each
/// knob enables one mechanism:
///
/// * `max_attempts > 1` — the engine retries transient failures
///   ([`crate::fdb::telemetry::is_transient`]: deadline timeouts and
///   `:transient`-marked injected faults) with exponential backoff
///   (`backoff_us * 2^attempt`) plus seeded jitter, slept in virtual
///   time so retry storms are deterministic and measurable.
/// * `op_deadline_us > 0` — a per-op deadline: a backend op still
///   pending when the deadline fires is abandoned and surfaces as
///   [`FdbError::Timeout`] (itself retryable).
/// * `hedge_us > 0` — hedged reads on replicated stores: if the
///   primary replica hasn't answered after the hedge delay, a second
///   replica attempt launches; first completion wins, the loser is
///   cancelled and its bytes discarded.
/// * `quarantine_after > 0` — replica health tracking: that many
///   *consecutive* failures eject a replica from the read rotation for
///   `quarantine_backoff_us` (doubling per relapse); after the backoff
///   one probe read is allowed and a success reinstates it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResilienceProfile {
    /// Total attempts per engine op (1 = retries off; 1..=16).
    pub max_attempts: u32,
    /// Base backoff between attempts in µs (doubles per retry, jittered).
    pub backoff_us: u64,
    /// Seed for the backoff jitter stream.
    pub seed: u64,
    /// Per-op deadline in µs (0 = no deadline).
    pub op_deadline_us: u64,
    /// Hedged-read delay in µs on replicated stores (0 = no hedging).
    pub hedge_us: u64,
    /// Consecutive failures before a replica is quarantined (0 = off).
    pub quarantine_after: u32,
    /// Initial quarantine backoff in µs before a probe is allowed.
    pub quarantine_backoff_us: u64,
}

impl Default for ResilienceProfile {
    fn default() -> ResilienceProfile {
        ResilienceProfile {
            max_attempts: 1,
            backoff_us: 200,
            seed: 0,
            op_deadline_us: 0,
            hedge_us: 0,
            quarantine_after: 0,
            quarantine_backoff_us: 10_000,
        }
    }
}

impl ResilienceProfile {
    /// Shorthand: retries on with `attempts` total attempts.
    pub fn retries(attempts: u32) -> ResilienceProfile {
        ResilienceProfile {
            max_attempts: attempts,
            ..ResilienceProfile::default()
        }
    }

    pub fn with_backoff_us(mut self, micros: u64) -> ResilienceProfile {
        self.backoff_us = micros;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> ResilienceProfile {
        self.seed = seed;
        self
    }

    pub fn with_op_deadline_us(mut self, micros: u64) -> ResilienceProfile {
        self.op_deadline_us = micros;
        self
    }

    pub fn with_hedge_us(mut self, micros: u64) -> ResilienceProfile {
        self.hedge_us = micros;
        self
    }

    pub fn with_quarantine(mut self, after: u32, backoff_us: u64) -> ResilienceProfile {
        self.quarantine_after = after;
        self.quarantine_backoff_us = backoff_us;
        self
    }

    /// Whether any mechanism is on (the default profile is a no-op).
    pub fn enabled(&self) -> bool {
        self.max_attempts > 1
            || self.op_deadline_us > 0
            || self.hedge_us > 0
            || self.quarantine_after > 0
    }

    /// Bounds check (shared by the builder and the CLI front-ends).
    pub fn validate(&self) -> Result<(), FdbError> {
        if self.max_attempts == 0 || self.max_attempts > 16 {
            return Err(FdbError::InvalidConfig(format!(
                "retry attempts must be in 1..=16 (got {})",
                self.max_attempts
            )));
        }
        if self.max_attempts > 1 && self.backoff_us == 0 {
            return Err(FdbError::InvalidConfig(
                "retry backoff must be > 0 µs when retries are on \
                 (a zero backoff is a hot retry storm)"
                    .to_string(),
            ));
        }
        if self.quarantine_after > 0 && self.quarantine_backoff_us == 0 {
            return Err(FdbError::InvalidConfig(
                "quarantine backoff must be > 0 µs when quarantine is on \
                 (a zero backoff re-probes a dead replica every read)"
                    .to_string(),
            ));
        }
        Ok(())
    }
}

/// Which backend pair an FDB instance runs over, plus its knobs.
/// Wrapper variants (`Tiered`, `Replicated`, `Sharded`) nest other
/// configs and compose recursively.
#[derive(Clone)]
pub enum BackendConfig {
    /// POSIX Store + Catalogue on a Lustre mount (thesis §2.7.2).
    Posix { fs: Rc<Lustre>, root: String },
    /// DAOS Store + Catalogue (thesis §3.1). `hash_oids` enables the
    /// identifier-hash OID mode (§3.1.2 future-work optimisation):
    /// retrieve() bypasses the Catalogue entirely.
    Daos {
        daos: Rc<Daos>,
        pool: String,
        hash_oids: bool,
    },
    /// Ceph/RADOS Store + Catalogue (thesis §3.2) with the Fig 3.5
    /// store-configuration sweep knobs.
    Rados {
        ceph: Rc<Ceph>,
        pool: Rc<CephPool>,
        store: RadosStoreConfig,
    },
    /// S3 Store + process-local Null catalogue (thesis §3.3 discarded an
    /// S3 Catalogue for lack of atomic append). `multipart` accumulates
    /// fields per (dataset, collocation) into one multipart object.
    S3 {
        s3: Rc<MemS3>,
        client_tag: String,
        multipart: bool,
    },
    /// Zero-cost sink + in-memory catalogue — client-overhead
    /// experiments (Fig 4.30) and API tests.
    Null,
    /// Zero-cost sink + a [`SharedNullCatalogue`]: every FDB built from
    /// a clone of this config shares one index, giving Null deployments
    /// cross-process visibility (fdb-hammer readers find the writers'
    /// fields).
    SharedNull(SharedNullCatalogue),
    /// [`TieredStore`]: `front` absorbs archives, write-through to
    /// `back` on flush. The Catalogue comes from the durable `back`
    /// tier.
    Tiered {
        front: Box<BackendConfig>,
        back: Box<BackendConfig>,
    },
    /// [`ReplicatedStore`]: `copies` independent instances of `inner`'s
    /// Store; the Catalogue comes from a single `inner` instance.
    Replicated {
        inner: Box<BackendConfig>,
        copies: usize,
    },
    /// [`ShardedCatalogue`]: `shards` independent instances of `inner`'s
    /// Catalogue, hash-partitioned on the collocation key; the Store
    /// comes from a single `inner` instance.
    Sharded {
        inner: Box<BackendConfig>,
        shards: usize,
    },
    /// [`FaultStore`]/[`FaultCatalogue`]: wrap `inner` with seeded,
    /// deterministic fault injection (see [`crate::fdb::fault`] for the
    /// plan grammar). Each *built* instance — every replica of a
    /// replicated inner, every FDB built from a config clone — draws an
    /// independent RNG stream from the plan's seed.
    Fault {
        inner: Box<BackendConfig>,
        plan: FaultPlan,
    },
}

/// Per-layer instrumentation context threaded through the build
/// recursion: the shared registry plus the dotted label prefix of the
/// subtree being built — `""` at the root, `"front."`/`"back."` under a
/// tiered store, `"r0."` under replica 0, `"s2."` under catalogue
/// shard 2. A leaf built under `"front.r1."` reports as e.g.
/// `store.front.r1.posix.read`.
type Instr<'a> = Option<(&'a MetricsRegistry, String)>;

/// Derive the context for a wrapper's child by appending one segment.
fn child_instr<'a>(instr: &Instr<'a>, seg: &str) -> Instr<'a> {
    instr
        .as_ref()
        .map(|(reg, path)| (*reg, format!("{path}{seg}.")))
}

/// Wrap a built Store in the per-layer instrumenting shim (no-op when
/// no registry is attached).
fn instrument_store(
    store: Box<dyn Store>,
    instr: &Instr<'_>,
    leaf: &'static str,
    sim: &Sim,
) -> Box<dyn Store> {
    match instr {
        Some((reg, path)) => Box::new(InstrumentStore::new(
            store,
            reg,
            &format!("{path}{leaf}"),
            Some(sim),
        )),
        None => store,
    }
}

/// Wrap a built Catalogue in the per-layer instrumenting shim.
fn instrument_catalogue(
    cat: Box<dyn Catalogue>,
    instr: &Instr<'_>,
    leaf: &'static str,
    sim: &Sim,
) -> Box<dyn Catalogue> {
    match instr {
        Some((reg, path)) => Box::new(InstrumentCatalogue::new(
            cat,
            reg,
            &format!("{path}{leaf}"),
            Some(sim),
        )),
        None => cat,
    }
}

impl BackendConfig {
    /// Short tag for diagnostics.
    pub fn label(&self) -> &'static str {
        match self {
            BackendConfig::Posix { .. } => "posix",
            BackendConfig::Daos { .. } => "daos",
            BackendConfig::Rados { .. } => "rados",
            BackendConfig::S3 { .. } => "s3",
            BackendConfig::Null | BackendConfig::SharedNull(_) => "null",
            BackendConfig::Tiered { .. } => "tiered",
            BackendConfig::Replicated { .. } => "replicated",
            BackendConfig::Sharded { .. } => "sharded",
            BackendConfig::Fault { .. } => "fault",
        }
    }

    /// Recursive human-readable shape, e.g.
    /// `sharded4(tiered(posix,replicated2(rados)))`.
    pub fn describe(&self) -> String {
        match self {
            BackendConfig::Tiered { front, back } => {
                format!("tiered({},{})", front.describe(), back.describe())
            }
            BackendConfig::Replicated { inner, copies } => {
                format!("replicated{}({})", copies, inner.describe())
            }
            BackendConfig::Sharded { inner, shards } => {
                format!("sharded{}({})", shards, inner.describe())
            }
            BackendConfig::Fault { inner, plan } => {
                format!("fault[{}]({})", plan.describe(), inner.describe())
            }
            other => other.label().to_string(),
        }
    }

    /// The schema variant a backend pair defaults to (wrappers defer to
    /// the config their Catalogue comes from).
    fn default_schema(&self) -> Schema {
        match self {
            BackendConfig::Posix { .. } => Schema::default_posix(),
            BackendConfig::Tiered { back, .. } => back.default_schema(),
            BackendConfig::Replicated { inner, .. }
            | BackendConfig::Sharded { inner, .. }
            | BackendConfig::Fault { inner, .. } => inner.default_schema(),
            _ => Schema::daos_variant(),
        }
    }

    fn validate(&self, node: Option<&Rc<Node>>) -> Result<(), FdbError> {
        let invalid = |msg: &str| Err(FdbError::InvalidConfig(msg.to_string()));
        match self {
            BackendConfig::Posix { root, .. } => {
                if root.is_empty() || !root.starts_with('/') {
                    return invalid("posix root must be an absolute path");
                }
                if node.is_none() {
                    return invalid("posix backend needs a client node");
                }
            }
            BackendConfig::Daos { pool, .. } => {
                if pool.is_empty() {
                    return invalid("daos pool label must be non-empty");
                }
                if node.is_none() {
                    return invalid("daos backend needs a client node");
                }
            }
            BackendConfig::Rados { store, .. } => {
                if store.pg_per_pool == 0 {
                    return invalid("rados pg_per_pool must be > 0");
                }
                if node.is_none() {
                    return invalid("rados backend needs a client node");
                }
            }
            BackendConfig::S3 { client_tag, .. } => {
                if client_tag.is_empty() {
                    return invalid("s3 client tag must be non-empty");
                }
            }
            BackendConfig::Null | BackendConfig::SharedNull(_) => {}
            BackendConfig::Tiered { front, back } => {
                front.validate(node)?;
                back.validate(node)?;
            }
            BackendConfig::Replicated { inner, copies } => {
                if *copies == 0 {
                    return invalid("replicated store needs copies >= 1");
                }
                inner.validate(node)?;
            }
            BackendConfig::Sharded { inner, shards } => {
                if *shards == 0 {
                    return invalid("sharded catalogue needs shards >= 1");
                }
                inner.validate(node)?;
            }
            BackendConfig::Fault { inner, .. } => inner.validate(node)?,
        }
        Ok(())
    }

    /// Build this config's Store side (recursing through wrappers).
    /// Callers validate first; a missing node on a node-requiring
    /// backend still surfaces as `InvalidConfig` rather than a panic.
    /// `sim` is the virtual clock wrapper stores observe latencies with
    /// (the replicated store's `ReadPolicy::Fastest` EWMA). `instr`
    /// threads the per-layer instrumentation context (see [`Instr`]);
    /// `policy` overrides the read policy of every replicated store in
    /// the tree. A `Fault` node absorbs the instrumentation point — the
    /// shim wraps *outside* the fault injector so injected delays and
    /// errors show up in that layer's histograms and fault counters.
    fn build_store(
        &self,
        node: Option<&Rc<Node>>,
        sim: &Sim,
        instr: Instr<'_>,
        policy: Option<ReadPolicy>,
        res: Option<&ResilienceProfile>,
    ) -> Result<Box<dyn Store>, FdbError> {
        let need_node = || {
            FdbError::InvalidConfig(format!("{} backend needs a client node", self.label()))
        };
        Ok(match self {
            BackendConfig::Posix { fs, root } => {
                let node = node.ok_or_else(need_node)?;
                instrument_store(
                    Box::new(PosixStore::new(fs.client(node), root)),
                    &instr,
                    "posix",
                    sim,
                )
            }
            BackendConfig::Daos {
                daos,
                pool,
                hash_oids,
            } => {
                let node = node.ok_or_else(need_node)?;
                let mut store = DaosStore::new(daos.client(node), pool);
                store.hash_oids = *hash_oids;
                instrument_store(Box::new(store), &instr, "daos", sim)
            }
            BackendConfig::Rados {
                ceph,
                pool,
                store: store_cfg,
            } => {
                let node = node.ok_or_else(need_node)?;
                instrument_store(
                    Box::new(
                        RadosStore::new(ceph, ceph.client(node), pool)
                            .with_config(store_cfg.clone()),
                    ),
                    &instr,
                    "rados",
                    sim,
                )
            }
            BackendConfig::S3 {
                s3,
                client_tag,
                multipart,
            } => {
                let mut store = S3Store::new(s3, client_tag);
                store.multipart = *multipart;
                instrument_store(Box::new(store), &instr, "s3", sim)
            }
            BackendConfig::Null | BackendConfig::SharedNull(_) => {
                instrument_store(Box::new(NullStore), &instr, "null", sim)
            }
            BackendConfig::Tiered { front, back } => Box::new(TieredStore::new(
                front.build_store(node, sim, child_instr(&instr, "front"), policy, res)?,
                back.build_store(node, sim, child_instr(&instr, "back"), policy, res)?,
            )),
            BackendConfig::Replicated { inner, copies } => {
                let mut replicas = Vec::with_capacity(*copies);
                for i in 0..*copies {
                    replicas.push(inner.build_store(
                        node,
                        sim,
                        child_instr(&instr, &format!("r{i}")),
                        policy,
                        res,
                    )?);
                }
                let mut store = ReplicatedStore::new(replicas)
                    .with_clock(sim)
                    .with_integrity(instr.as_ref().map(|(reg, _)| *reg));
                if let Some(p) = policy {
                    store = store.with_read_policy(p);
                }
                if let Some(r) = res {
                    store = store.with_resilience(r, instr.as_ref().map(|(reg, _)| *reg));
                }
                Box::new(store)
            }
            BackendConfig::Sharded { inner, .. } => {
                inner.build_store(node, sim, instr, policy, res)?
            }
            BackendConfig::Fault { inner, plan } => instrument_store(
                Box::new(FaultStore::new(
                    inner.build_store(node, sim, None, policy, res)?,
                    plan.build_state(Some(sim)),
                )),
                &instr,
                inner.label(),
                sim,
            ),
        })
    }

    /// Build this config's Catalogue side (recursing through wrappers).
    /// `sim` drives fault-wrapper slow-replica delays. Labels only gain
    /// `s<i>.` segments (sharding is the catalogue-side wrapper); the
    /// store-side `front.`/`r<i>.` structure does not apply here.
    fn build_catalogue(
        &self,
        node: Option<&Rc<Node>>,
        schema: &Schema,
        io: &IoProfile,
        sim: &Sim,
        instr: Instr<'_>,
    ) -> Result<Box<dyn Catalogue>, FdbError> {
        let need_node = || {
            FdbError::InvalidConfig(format!("{} backend needs a client node", self.label()))
        };
        Ok(match self {
            BackendConfig::Posix { fs, root } => {
                let node = node.ok_or_else(need_node)?;
                let mut cat = PosixCatalogue::new(fs.client(node), root, schema.clone())
                    .with_index_cache(io.preload_indexes)
                    .with_durable(io.durable);
                if let Some((reg, path)) = &instr {
                    // migrate the ad-hoc WAL-sync probe onto the registry
                    cat = cat.with_wal_counter(reg.counter(&format!("cat.{path}posix.wal_syncs")));
                }
                instrument_catalogue(Box::new(cat), &instr, "posix", sim)
            }
            BackendConfig::Daos { daos, pool, .. } => {
                let node = node.ok_or_else(need_node)?;
                // root container label fixed by the administrator
                // (thesis §3.1.2)
                instrument_catalogue(
                    Box::new(DaosCatalogue::new(
                        daos.client(node),
                        pool,
                        "fdb_root",
                        schema.clone(),
                    )),
                    &instr,
                    "daos",
                    sim,
                )
            }
            BackendConfig::Rados { ceph, pool, .. } => {
                let node = node.ok_or_else(need_node)?;
                // Omaps cannot live in erasure-coded pools (librados
                // restriction, thesis §2.4) — for an EC data pool the
                // Catalogue uses the replicated metadata pool, the
                // standard Ceph deployment pattern.
                let meta_pool = if matches!(pool.redundancy, Redundancy::Erasure(..)) {
                    ceph.meta_pool()
                } else {
                    pool.clone()
                };
                instrument_catalogue(
                    Box::new(RadosCatalogue::new(
                        ceph.client(node),
                        &meta_pool,
                        schema.clone(),
                    )),
                    &instr,
                    "rados",
                    sim,
                )
            }
            BackendConfig::S3 { .. } | BackendConfig::Null => {
                instrument_catalogue(Box::new(NullCatalogue::new()), &instr, "null", sim)
            }
            BackendConfig::SharedNull(cat) => {
                instrument_catalogue(Box::new(cat.clone()), &instr, "null", sim)
            }
            // the durable back tier owns the index
            BackendConfig::Tiered { back, .. } => {
                back.build_catalogue(node, schema, io, sim, instr)?
            }
            BackendConfig::Replicated { inner, .. } => {
                inner.build_catalogue(node, schema, io, sim, instr)?
            }
            BackendConfig::Sharded { inner, shards } => {
                let mut parts = Vec::with_capacity(*shards);
                for i in 0..*shards {
                    parts.push(inner.build_catalogue(
                        node,
                        schema,
                        io,
                        sim,
                        child_instr(&instr, &format!("s{i}")),
                    )?);
                }
                Box::new(ShardedCatalogue::new(parts))
            }
            BackendConfig::Fault { inner, plan } => instrument_catalogue(
                Box::new(FaultCatalogue::new(
                    inner.build_catalogue(node, schema, io, sim, None)?,
                    plan.build_state(Some(sim)),
                )),
                &instr,
                inner.label(),
                sim,
            ),
        })
    }
}

/// Builds one [`Fdb`] per simulated process from a [`BackendConfig`].
pub struct FdbBuilder {
    sim: Sim,
    node: Option<Rc<Node>>,
    trace: Option<Trace>,
    schema: Option<Schema>,
    config: Option<BackendConfig>,
    io: IoProfile,
    metrics: Option<MetricsRegistry>,
    read_policy: Option<ReadPolicy>,
    resilience: Option<ResilienceProfile>,
}

impl FdbBuilder {
    pub fn new(sim: &Sim) -> FdbBuilder {
        FdbBuilder {
            sim: sim.clone(),
            node: None,
            trace: None,
            schema: None,
            config: None,
            io: IoProfile::default(),
            metrics: None,
            read_policy: None,
            resilience: None,
        }
    }

    /// The client node this FDB instance's backends run on (required
    /// for all backends except S3/Null).
    pub fn node(mut self, node: &Rc<Node>) -> FdbBuilder {
        self.node = Some(node.clone());
        self
    }

    /// Attach a shared trace collector (benchmark profiling).
    pub fn trace(mut self, trace: &Trace) -> FdbBuilder {
        self.trace = Some(trace.clone());
        self
    }

    /// Override the backend's default schema variant.
    pub fn schema(mut self, schema: Schema) -> FdbBuilder {
        self.schema = Some(schema);
        self
    }

    pub fn backend(mut self, config: BackendConfig) -> FdbBuilder {
        self.config = Some(config);
        self
    }

    /// Set the full I/O-depth profile.
    pub fn io(mut self, io: IoProfile) -> FdbBuilder {
        self.io = io;
        self
    }

    /// Convenience: just the queue depth, default caching.
    pub fn io_depth(mut self, depth: usize) -> FdbBuilder {
        self.io.depth = depth;
        self
    }

    /// Attach a shared [`MetricsRegistry`]: the I/O engine records
    /// admission-wait and service histograms, byte counters, outcome
    /// counters, and journal spans into it, and every layer of the
    /// backend tree is wrapped in an instrumenting shim
    /// ([`InstrumentStore`]/[`InstrumentCatalogue`]) reporting
    /// per-layer latency histograms and hit/miss/fault counters under
    /// dotted labels (`store.r1.posix.read`, `cat.s0.posix.lookup`).
    /// Metrics never change behaviour: results and virtual time are
    /// identical with and without a registry attached.
    pub fn metrics(mut self, reg: &MetricsRegistry) -> FdbBuilder {
        self.metrics = Some(reg.clone());
        self
    }

    /// Override the [`ReadPolicy`] of every replicated store in the
    /// config tree (default: the store's own round-robin).
    pub fn read_policy(mut self, policy: ReadPolicy) -> FdbBuilder {
        self.read_policy = Some(policy);
        self
    }

    /// Set the [`ResilienceProfile`]: engine-level retry/backoff and
    /// per-op deadlines, plus hedged reads and replica quarantine on
    /// every replicated store in the config tree. The default profile
    /// (everything off) leaves behaviour byte-identical to a builder
    /// without this call.
    pub fn resilience(mut self, res: ResilienceProfile) -> FdbBuilder {
        self.resilience = Some(res);
        self
    }

    /// Validate the config tree and wire the matching Store/Catalogue
    /// pair, recursing through wrapper configs.
    pub fn build(self) -> Result<Fdb, FdbError> {
        let config = self
            .config
            .ok_or_else(|| FdbError::InvalidConfig("no backend configured".to_string()))?;
        config.validate(self.node.as_ref())?;
        self.io.validate()?;
        if let Some(res) = &self.resilience {
            res.validate()?;
        }
        let schema = self
            .schema
            .unwrap_or_else(|| config.default_schema());
        let instr: Instr<'_> = self.metrics.as_ref().map(|reg| (reg, String::new()));
        let store = config.build_store(
            self.node.as_ref(),
            &self.sim,
            instr.clone(),
            self.read_policy,
            self.resilience.as_ref(),
        )?;
        let catalogue =
            config.build_catalogue(self.node.as_ref(), &schema, &self.io, &self.sim, instr)?;
        let mut fdb = Fdb::new(&self.sim, schema, store, catalogue).with_io(self.io);
        if let Some(trace) = self.trace {
            fdb = fdb.with_trace(trace);
        }
        if let Some(reg) = &self.metrics {
            fdb = fdb.with_metrics(reg);
        }
        if let Some(res) = self.resilience {
            fdb = fdb.with_resilience(res);
        }
        Ok(fdb)
    }
}
