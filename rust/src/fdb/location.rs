//! Object location descriptors (URIs): what Store `archive()` returns and
//! the Catalogue persists in its indexes. Serialized as real URI strings
//! so the Catalogue's stored bytes are genuinely parseable.

use crate::daos::Oid;

/// Where a field's bytes live, per backend.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FieldLocation {
    PosixFile {
        path: String,
        offset: u64,
        length: u64,
    },
    DaosArray {
        pool: String,
        cont: String,
        oid: Oid,
        length: u64,
    },
    RadosObj {
        pool: String,
        ns: String,
        name: String,
        offset: u64,
        length: u64,
    },
    S3Obj {
        bucket: String,
        key: String,
        length: u64,
    },
    /// zero-cost sink used by the "dummy" client-overhead experiments
    Null { length: u64 },
}

impl FieldLocation {
    pub fn length(&self) -> u64 {
        match self {
            FieldLocation::PosixFile { length, .. }
            | FieldLocation::DaosArray { length, .. }
            | FieldLocation::RadosObj { length, .. }
            | FieldLocation::S3Obj { length, .. }
            | FieldLocation::Null { length } => *length,
        }
    }

    /// Serialize as a URI string.
    pub fn to_uri(&self) -> String {
        match self {
            FieldLocation::PosixFile {
                path,
                offset,
                length,
            } => format!("posix://{path}?off={offset}&len={length}"),
            FieldLocation::DaosArray {
                pool,
                cont,
                oid,
                length,
            } => format!(
                "daos://{pool}/{cont}?oid={}.{}&len={length}",
                oid.hi, oid.lo
            ),
            FieldLocation::RadosObj {
                pool,
                ns,
                name,
                offset,
                length,
            } => format!("rados://{pool}/{ns}/{name}?off={offset}&len={length}"),
            FieldLocation::S3Obj {
                bucket,
                key,
                length,
            } => format!("s3://{bucket}/{key}?len={length}"),
            FieldLocation::Null { length } => format!("null://?len={length}"),
        }
    }

    /// Parse a URI string produced by [`FieldLocation::to_uri`].
    pub fn parse_uri(uri: &str) -> Option<FieldLocation> {
        let (scheme, rest) = uri.split_once("://")?;
        let (path, query) = rest.split_once('?').unwrap_or((rest, ""));
        let mut off = 0u64;
        let mut len = 0u64;
        let mut oid = (0u64, 0u64);
        for kv in query.split('&') {
            if let Some((k, v)) = kv.split_once('=') {
                match k {
                    "off" => off = v.parse().ok()?,
                    "len" => len = v.parse().ok()?,
                    "oid" => {
                        let (hi, lo) = v.split_once('.')?;
                        oid = (hi.parse().ok()?, lo.parse().ok()?);
                    }
                    _ => {}
                }
            }
        }
        match scheme {
            "posix" => Some(FieldLocation::PosixFile {
                path: path.to_string(),
                offset: off,
                length: len,
            }),
            "daos" => {
                let (pool, cont) = path.split_once('/')?;
                Some(FieldLocation::DaosArray {
                    pool: pool.to_string(),
                    cont: cont.to_string(),
                    oid: Oid::new(oid.0, oid.1),
                    length: len,
                })
            }
            "rados" => {
                let mut parts = path.splitn(3, '/');
                Some(FieldLocation::RadosObj {
                    pool: parts.next()?.to_string(),
                    ns: parts.next()?.to_string(),
                    name: parts.next()?.to_string(),
                    offset: off,
                    length: len,
                })
            }
            "s3" => {
                let (bucket, key) = path.split_once('/')?;
                Some(FieldLocation::S3Obj {
                    bucket: bucket.to_string(),
                    key: key.to_string(),
                    length: len,
                })
            }
            "null" => Some(FieldLocation::Null { length: len }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uri_roundtrip_all_variants() {
        let locs = vec![
            FieldLocation::PosixFile {
                path: "/ds/data.0".into(),
                offset: 4096,
                length: 1 << 20,
            },
            FieldLocation::DaosArray {
                pool: "fdb".into(),
                cont: "ds1".into(),
                oid: Oid::new(1, 42),
                length: 1 << 20,
            },
            FieldLocation::RadosObj {
                pool: "fdb".into(),
                ns: "ds1".into(),
                name: "abc123".into(),
                offset: 0,
                length: 512,
            },
            FieldLocation::S3Obj {
                bucket: "fdb-ds1".into(),
                key: "h-p-1".into(),
                length: 7,
            },
            FieldLocation::Null { length: 9 },
        ];
        for loc in locs {
            let uri = loc.to_uri();
            let back = FieldLocation::parse_uri(&uri).unwrap();
            assert_eq!(loc, back, "uri {uri}");
            assert_eq!(loc.length(), back.length());
        }
    }

    #[test]
    fn bad_uris_rejected() {
        assert!(FieldLocation::parse_uri("garbage").is_none());
        assert!(FieldLocation::parse_uri("ftp://x/y").is_none());
    }
}
