//! Object location descriptors (URIs): what Store `archive()` returns and
//! the Catalogue persists in its indexes. Serialized as real URI strings
//! so the Catalogue's stored bytes are genuinely parseable.

use crate::daos::Oid;

/// Where a field's bytes live, per backend.
///
/// Real locations optionally carry a **content checksum** (FNV-1a of the
/// field payload, [`crate::util::content::Bytes::content_checksum`])
/// computed at archive time. The checksum rides the URI as a `ck=` query
/// parameter, so legacy entries without one parse fine (absent checksum =
/// unverified legacy field, never an error). The `Null` sink never
/// carries one — its reads regenerate synthetic bytes, not the archived
/// payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FieldLocation {
    PosixFile {
        path: String,
        offset: u64,
        length: u64,
        checksum: Option<u64>,
    },
    DaosArray {
        pool: String,
        cont: String,
        oid: Oid,
        length: u64,
        checksum: Option<u64>,
    },
    RadosObj {
        pool: String,
        ns: String,
        name: String,
        offset: u64,
        length: u64,
        checksum: Option<u64>,
    },
    S3Obj {
        bucket: String,
        key: String,
        length: u64,
        checksum: Option<u64>,
    },
    /// zero-cost sink used by the "dummy" client-overhead experiments
    Null { length: u64 },
}

impl FieldLocation {
    pub fn length(&self) -> u64 {
        match self {
            FieldLocation::PosixFile { length, .. }
            | FieldLocation::DaosArray { length, .. }
            | FieldLocation::RadosObj { length, .. }
            | FieldLocation::S3Obj { length, .. }
            | FieldLocation::Null { length } => *length,
        }
    }

    /// The content checksum recorded at archive time, if any.
    pub fn checksum(&self) -> Option<u64> {
        match self {
            FieldLocation::PosixFile { checksum, .. }
            | FieldLocation::DaosArray { checksum, .. }
            | FieldLocation::RadosObj { checksum, .. }
            | FieldLocation::S3Obj { checksum, .. } => *checksum,
            FieldLocation::Null { .. } => None,
        }
    }

    /// Attach a content checksum. A no-op for `Null` locations — the
    /// sink regenerates bytes on read, so a payload checksum would only
    /// report false corruption.
    pub fn with_checksum(mut self, ck: u64) -> FieldLocation {
        match &mut self {
            FieldLocation::PosixFile { checksum, .. }
            | FieldLocation::DaosArray { checksum, .. }
            | FieldLocation::RadosObj { checksum, .. }
            | FieldLocation::S3Obj { checksum, .. } => *checksum = Some(ck),
            FieldLocation::Null { .. } => {}
        }
        self
    }

    /// The physical container this location lives in, without offset,
    /// length, or checksum — the identity scrub uses to match catalogue
    /// references against a store's object inventory.
    pub fn container_uri(&self) -> String {
        match self {
            FieldLocation::PosixFile { path, .. } => format!("posix://{path}"),
            FieldLocation::DaosArray {
                pool, cont, oid, ..
            } => format!("daos://{pool}/{cont}?oid={}.{}", oid.hi, oid.lo),
            FieldLocation::RadosObj { pool, ns, name, .. } => {
                format!("rados://{pool}/{ns}/{name}")
            }
            FieldLocation::S3Obj { bucket, key, .. } => format!("s3://{bucket}/{key}"),
            FieldLocation::Null { .. } => "null://".to_string(),
        }
    }

    /// Serialize as a URI string.
    pub fn to_uri(&self) -> String {
        let ck = |c: &Option<u64>| c.map(|v| format!("&ck={v}")).unwrap_or_default();
        match self {
            FieldLocation::PosixFile {
                path,
                offset,
                length,
                checksum,
            } => format!("posix://{path}?off={offset}&len={length}{}", ck(checksum)),
            FieldLocation::DaosArray {
                pool,
                cont,
                oid,
                length,
                checksum,
            } => format!(
                "daos://{pool}/{cont}?oid={}.{}&len={length}{}",
                oid.hi,
                oid.lo,
                ck(checksum)
            ),
            FieldLocation::RadosObj {
                pool,
                ns,
                name,
                offset,
                length,
                checksum,
            } => format!(
                "rados://{pool}/{ns}/{name}?off={offset}&len={length}{}",
                ck(checksum)
            ),
            FieldLocation::S3Obj {
                bucket,
                key,
                length,
                checksum,
            } => format!("s3://{bucket}/{key}?len={length}{}", ck(checksum)),
            FieldLocation::Null { length } => format!("null://?len={length}"),
        }
    }

    /// Parse a URI string produced by [`FieldLocation::to_uri`]. Unknown
    /// query keys are ignored, so URIs written by both older (no `ck=`)
    /// and newer code parse.
    pub fn parse_uri(uri: &str) -> Option<FieldLocation> {
        let (scheme, rest) = uri.split_once("://")?;
        let (path, query) = rest.split_once('?').unwrap_or((rest, ""));
        let mut off = 0u64;
        let mut len = 0u64;
        let mut oid = (0u64, 0u64);
        let mut ck = None;
        for kv in query.split('&') {
            if let Some((k, v)) = kv.split_once('=') {
                match k {
                    "off" => off = v.parse().ok()?,
                    "len" => len = v.parse().ok()?,
                    "ck" => ck = Some(v.parse().ok()?),
                    "oid" => {
                        let (hi, lo) = v.split_once('.')?;
                        oid = (hi.parse().ok()?, lo.parse().ok()?);
                    }
                    _ => {}
                }
            }
        }
        match scheme {
            "posix" => Some(FieldLocation::PosixFile {
                path: path.to_string(),
                offset: off,
                length: len,
                checksum: ck,
            }),
            "daos" => {
                let (pool, cont) = path.split_once('/')?;
                Some(FieldLocation::DaosArray {
                    pool: pool.to_string(),
                    cont: cont.to_string(),
                    oid: Oid::new(oid.0, oid.1),
                    length: len,
                    checksum: ck,
                })
            }
            "rados" => {
                let mut parts = path.splitn(3, '/');
                Some(FieldLocation::RadosObj {
                    pool: parts.next()?.to_string(),
                    ns: parts.next()?.to_string(),
                    name: parts.next()?.to_string(),
                    offset: off,
                    length: len,
                    checksum: ck,
                })
            }
            "s3" => {
                let (bucket, key) = path.split_once('/')?;
                Some(FieldLocation::S3Obj {
                    bucket: bucket.to_string(),
                    key: key.to_string(),
                    length: len,
                    checksum: ck,
                })
            }
            "null" => Some(FieldLocation::Null { length: len }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uri_roundtrip_all_variants() {
        let locs = vec![
            FieldLocation::PosixFile {
                path: "/ds/data.0".into(),
                offset: 4096,
                length: 1 << 20,
                checksum: None,
            },
            FieldLocation::DaosArray {
                pool: "fdb".into(),
                cont: "ds1".into(),
                oid: Oid::new(1, 42),
                length: 1 << 20,
                checksum: Some(0xdead_beef),
            },
            FieldLocation::RadosObj {
                pool: "fdb".into(),
                ns: "ds1".into(),
                name: "abc123".into(),
                offset: 0,
                length: 512,
                checksum: Some(u64::MAX),
            },
            FieldLocation::S3Obj {
                bucket: "fdb-ds1".into(),
                key: "h-p-1".into(),
                length: 7,
                checksum: None,
            },
            FieldLocation::Null { length: 9 },
        ];
        for loc in locs {
            let uri = loc.to_uri();
            let back = FieldLocation::parse_uri(&uri).unwrap();
            assert_eq!(loc, back, "uri {uri}");
            assert_eq!(loc.length(), back.length());
            assert_eq!(loc.checksum(), back.checksum());
        }
    }

    #[test]
    fn legacy_uri_without_checksum_parses_as_unverified() {
        // a pre-integrity catalogue entry: no ck= parameter
        let loc = FieldLocation::parse_uri("posix:///ds/data.0?off=4096&len=1048576").unwrap();
        assert_eq!(loc.checksum(), None);
        assert_eq!(loc.length(), 1 << 20);
    }

    #[test]
    fn with_checksum_attaches_except_on_null() {
        let loc = FieldLocation::PosixFile {
            path: "/f".into(),
            offset: 0,
            length: 8,
            checksum: None,
        };
        assert_eq!(loc.with_checksum(7).checksum(), Some(7));
        let null = FieldLocation::Null { length: 8 };
        assert_eq!(null.with_checksum(7).checksum(), None);
    }

    #[test]
    fn container_uri_strips_range_and_checksum() {
        let a = FieldLocation::PosixFile {
            path: "/ds/data.0".into(),
            offset: 0,
            length: 10,
            checksum: Some(1),
        };
        let b = FieldLocation::PosixFile {
            path: "/ds/data.0".into(),
            offset: 4096,
            length: 99,
            checksum: None,
        };
        assert_eq!(a.container_uri(), b.container_uri());
        assert_eq!(a.container_uri(), "posix:///ds/data.0");
    }

    #[test]
    fn bad_uris_rejected() {
        assert!(FieldLocation::parse_uri("garbage").is_none());
        assert!(FieldLocation::parse_uri("ftp://x/y").is_none());
    }
}
