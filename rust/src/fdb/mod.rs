//! The FDB: a domain-specific object store for meteorological data
//! (thesis Chapters 2–3), with POSIX/Lustre, DAOS, Ceph-RADOS, and S3
//! backends behind abstract Store/Catalogue interfaces.

pub mod admin;
pub mod datahandle;
pub mod fdb;
pub mod key;
pub mod location;
pub mod request;
pub mod schema;
pub mod wire;

pub mod posix {
    pub mod catalogue;
    pub mod index;
    pub mod store;
    pub mod toc;
}

pub mod daos {
    pub mod catalogue;
    pub mod store;
}

pub mod rados {
    pub mod catalogue;
    pub mod store;
}

pub mod s3 {
    pub mod store;
}

pub use datahandle::DataHandle;
pub use fdb::{CatalogueBackend, Fdb, StoreBackend};
pub use key::Key;
pub use location::FieldLocation;
pub use request::Request;
pub use schema::Schema;

/// FDB error surface.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FdbError {
    Schema(schema::SchemaError),
    UnderspecifiedRequest,
}

impl From<schema::SchemaError> for FdbError {
    fn from(e: schema::SchemaError) -> FdbError {
        FdbError::Schema(e)
    }
}

impl std::fmt::Display for FdbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FdbError::Schema(e) => write!(f, "schema: {e}"),
            FdbError::UnderspecifiedRequest => {
                write!(f, "request lacks dataset/collocation dims for axis expansion")
            }
        }
    }
}
impl std::error::Error for FdbError {}

/// Convenience constructors wiring an [`Fdb`] to each backend pair.
pub mod setup {
    use std::rc::Rc;

    use super::fdb::{CatalogueBackend, Fdb, StoreBackend};
    use super::schema::Schema;
    use crate::ceph::{Ceph, CephPool};
    use crate::daos::Daos;
    use crate::hw::node::Node;
    use crate::lustre::Lustre;
    use crate::s3::MemS3;
    use crate::sim::exec::Sim;

    /// FDB over the POSIX backends on a Lustre mount.
    pub fn posix_fdb(sim: &Sim, fs: &Rc<Lustre>, node: &Rc<Node>, root: &str) -> Fdb {
        let schema = Schema::default_posix();
        let store = super::posix::store::PosixStore::new(fs.client(node), root);
        let catalogue =
            super::posix::catalogue::PosixCatalogue::new(fs.client(node), root, schema.clone());
        Fdb::new(
            sim,
            schema,
            StoreBackend::Posix(store),
            CatalogueBackend::Posix(catalogue),
        )
    }

    /// FDB over the DAOS backends (pool must exist; root container label
    /// fixed by the administrator — thesis §3.1.2).
    pub fn daos_fdb(sim: &Sim, daos: &Rc<Daos>, node: &Rc<Node>, pool: &str) -> Fdb {
        let schema = Schema::daos_variant();
        let store = super::daos::store::DaosStore::new(daos.client(node), pool);
        let catalogue = super::daos::catalogue::DaosCatalogue::new(
            daos.client(node),
            pool,
            "fdb_root",
            schema.clone(),
        );
        Fdb::new(
            sim,
            schema,
            StoreBackend::Daos(store),
            CatalogueBackend::Daos(catalogue),
        )
    }

    /// FDB over the Ceph/RADOS backends (default Fig 3.5 configuration:
    /// namespace per dataset, object per archive, blocking I/O).
    ///
    /// Omaps cannot live in erasure-coded pools (librados restriction,
    /// thesis §2.4) — when `pool` is EC, the Catalogue automatically uses
    /// a separate replicated metadata pool, the standard Ceph deployment
    /// pattern (data EC + metadata replicated).
    pub fn rados_fdb(sim: &Sim, ceph: &Rc<Ceph>, pool: &Rc<CephPool>, node: &Rc<Node>) -> Fdb {
        let schema = Schema::daos_variant();
        let store = super::rados::store::RadosStore::new(ceph, ceph.client(node), pool);
        let meta_pool = if matches!(pool.redundancy, crate::ceph::Redundancy::Erasure(..)) {
            ceph.meta_pool()
        } else {
            pool.clone()
        };
        let catalogue = super::rados::catalogue::RadosCatalogue::new(
            ceph.client(node),
            &meta_pool,
            schema.clone(),
        );
        Fdb::new(
            sim,
            schema,
            StoreBackend::Rados(store),
            CatalogueBackend::Rados(catalogue),
        )
    }

    /// FDB with the S3 Store (paired with a process-local Null catalogue;
    /// the thesis discarded an S3 Catalogue for lack of atomic append).
    pub fn s3_fdb(sim: &Sim, s3: &Rc<MemS3>, client_tag: &str) -> Fdb {
        let schema = Schema::daos_variant();
        let store = super::s3::store::S3Store::new(s3, client_tag);
        Fdb::new(
            sim,
            schema,
            StoreBackend::S3(store),
            CatalogueBackend::Null(std::collections::HashMap::new()),
        )
    }
}

#[cfg(test)]
mod tests {
    use std::rc::Rc;

    use super::*;
    use crate::ceph::{Ceph, CephConfig, Redundancy};
    use crate::daos::{Daos, DaosConfig};
    use crate::hw::profiles::{build_cluster, Testbed};
    use crate::lustre::{Lustre, LustreConfig};
    use crate::sim::exec::Sim;

    fn ids(n_steps: u32, n_params: u32) -> Vec<Key> {
        let mut out = Vec::new();
        for step in 1..=n_steps {
            for p in 0..n_params {
                out.push(
                    schema::example_identifier()
                        .with("step", step.to_string())
                        .with("param", format!("p{p}")),
                );
            }
        }
        out
    }

    fn field_bytes(id: &Key) -> Vec<u8> {
        format!("FIELD::{}", id.canonical()).into_bytes()
    }

    async fn writer_reader_roundtrip(mut w: Fdb, mut r: Fdb) {
        let ids = ids(3, 4);
        for id in &ids {
            w.archive(id, field_bytes(id)).await.unwrap();
        }
        w.flush().await;
        w.close().await;
        // reader sees every field with exact bytes
        for id in &ids {
            let h = r
                .retrieve(id)
                .await
                .unwrap()
                .unwrap_or_else(|| panic!("missing {id}"));
            let bytes = r.read(&h).await.to_vec();
            assert_eq!(bytes, field_bytes(id), "bytes for {id}");
        }
        // absent field: no error, no handle
        let missing = schema::example_identifier().with("step", "999");
        assert!(r.retrieve(&missing).await.unwrap().is_none());
        // list the whole dataset
        let ds = schema::example_identifier()
            .project(&r.schema.dataset.clone())
            .unwrap();
        let listed = r.list(&ds, &Request::parse("").unwrap()).await;
        assert_eq!(listed.len(), ids.len());
    }

    #[test]
    fn posix_end_to_end() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, true, true));
        let fs = Lustre::deploy(&sim, &cluster, LustreConfig::default());
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let w = setup::posix_fdb(&sim, &fs, &wnode, "/fdb");
        let r = setup::posix_fdb(&sim, &fs, &rnode, "/fdb");
        sim.spawn(async move { writer_reader_roundtrip(w, r).await });
        sim.run();
    }

    #[test]
    fn daos_end_to_end() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        daos.create_pool("fdb");
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let w = setup::daos_fdb(&sim, &daos, &wnode, "fdb");
        let r = setup::daos_fdb(&sim, &daos, &rnode, "fdb");
        sim.spawn(async move { writer_reader_roundtrip(w, r).await });
        sim.run();
    }

    #[test]
    fn rados_end_to_end() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::Gcp, 4, 2, true, true));
        let ceph = Ceph::deploy(&sim, &cluster, CephConfig::default());
        let pool = ceph.create_pool("fdb", 512, Redundancy::None);
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let w = setup::rados_fdb(&sim, &ceph, &pool, &wnode);
        let r = setup::rados_fdb(&sim, &ceph, &pool, &rnode);
        sim.spawn(async move { writer_reader_roundtrip(w, r).await });
        sim.run();
    }

    #[test]
    fn s3_store_roundtrip_same_process() {
        // No S3 catalogue: the Null catalogue is process-local, so the
        // writer retrieves its own fields (the thesis verified the S3
        // Store with local deployments the same way).
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::Gcp, 1, 1, false, true));
        let server = cluster.storage_nodes().next().unwrap().clone();
        let cnode = cluster.client_nodes().next().unwrap().clone();
        let s3 = Rc::new(crate::s3::MemS3::new(&sim, &server, &cnode));
        let mut w = setup::s3_fdb(&sim, &s3, "p0");
        sim.spawn(async move {
            let ids = ids(2, 3);
            for id in &ids {
                w.archive(id, field_bytes(id)).await.unwrap();
            }
            w.flush().await;
            for id in &ids {
                let h = w.retrieve(id).await.unwrap().unwrap();
                assert_eq!(w.read(&h).await.to_vec(), field_bytes(id));
            }
        });
        sim.run();
    }

    #[test]
    fn posix_visibility_requires_flush() {
        // ACID semantics item 3: data visible only after flush() on POSIX
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, true, true));
        let fs = Lustre::deploy(&sim, &cluster, LustreConfig::default());
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut w = setup::posix_fdb(&sim, &fs, &wnode, "/fdb");
        let fs2 = fs.clone();
        let sim2 = sim.clone();
        sim.spawn(async move {
            let id = schema::example_identifier();
            w.archive(&id, b"payload").await.unwrap();
            // reader BEFORE flush: index not yet persisted
            let mut r1 = setup::posix_fdb(&sim2, &fs2, &rnode, "/fdb");
            assert!(r1.retrieve(&id).await.unwrap().is_none());
            w.flush().await;
            // fresh reader AFTER flush: visible
            let mut r2 = setup::posix_fdb(&sim2, &fs2, &rnode, "/fdb");
            assert!(r2.retrieve(&id).await.unwrap().is_some());
        });
        sim.run();
    }

    #[test]
    fn daos_visible_immediately_without_flush() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        daos.create_pool("fdb");
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut w = setup::daos_fdb(&sim, &daos, &wnode, "fdb");
        let mut r = setup::daos_fdb(&sim, &daos, &rnode, "fdb");
        sim.spawn(async move {
            let id = schema::example_identifier();
            w.archive(&id, b"now").await.unwrap();
            // NO flush — still visible (thesis §3.1 immediate persistence)
            let h = r.retrieve(&id).await.unwrap().unwrap();
            assert_eq!(r.read(&h).await.to_vec(), b"now");
        });
        sim.run();
    }

    #[test]
    fn rearchive_replaces_transactionally() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        daos.create_pool("fdb");
        let node = cluster.client_nodes().next().unwrap().clone();
        let mut w = setup::daos_fdb(&sim, &daos, &node, "fdb");
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut r = setup::daos_fdb(&sim, &daos, &rnode, "fdb");
        sim.spawn(async move {
            let id = schema::example_identifier();
            w.archive(&id, b"old-data").await.unwrap();
            w.archive(&id, b"new-data").await.unwrap();
            let h = r.retrieve(&id).await.unwrap().unwrap();
            assert_eq!(r.read(&h).await.to_vec(), b"new-data");
        });
        sim.run();
    }

    #[test]
    fn wildcard_request_expands_from_axes() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        daos.create_pool("fdb");
        let node = cluster.client_nodes().next().unwrap().clone();
        let mut w = setup::daos_fdb(&sim, &daos, &node, "fdb");
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut r = setup::daos_fdb(&sim, &daos, &rnode, "fdb");
        sim.spawn(async move {
            for step in 1..=5u32 {
                let id = schema::example_identifier().with("step", step.to_string());
                w.archive(&id, format!("s{step}").as_bytes()).await.unwrap();
            }
            // request step=* for the same (ds, colloc, param)
            let base = schema::example_identifier();
            let mut req = Request::from_key(&base);
            req.bind("step", vec![]); // wildcard
            let handles = r.retrieve_request(&req).await.unwrap();
            let total: u64 = handles.iter().map(|h| h.total_len()).sum();
            assert_eq!(total, 10); // "s1".."s5" → 2 bytes each
        });
        sim.run();
    }

    #[test]
    fn posix_datahandle_merging_reduces_io_ops() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, true, true));
        let fs = Lustre::deploy(&sim, &cluster, LustreConfig::default());
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut w = setup::posix_fdb(&sim, &fs, &wnode, "/fdb");
        let sim2 = sim.clone();
        let fs2 = fs.clone();
        sim.spawn(async move {
            let mut ids = Vec::new();
            for step in 1..=6u32 {
                let id = schema::example_identifier().with("step", step.to_string());
                w.archive(&id, vec![step as u8; 128]).await.unwrap();
                ids.push(id);
            }
            w.flush().await;
            w.close().await;
            let mut r = setup::posix_fdb(&sim2, &fs2, &rnode, "/fdb");
            let mut req = Request::from_key(&ids[0]);
            req.bind("step", (1..=6).map(|s| s.to_string()).collect());
            let handles = r.retrieve_request(&req).await.unwrap();
            // all 6 fields were appended to one data file consecutively →
            // one handle, one coalesced range
            assert_eq!(handles.len(), 1);
            assert_eq!(handles[0].io_ops(), 1);
            assert_eq!(handles[0].total_len(), 6 * 128);
            let bytes = r.read(&handles[0]).await;
            assert_eq!(bytes.len(), 6 * 128);
        });
        sim.run();
    }
}
